"""Fault injection: outages, throttling and WAN jitter as a Scenario
component, with failover-aware routing closing the loop."""
import json

import numpy as np

from repro.core.faults import FaultSchedule
from repro.core.scenario import Scenario, Sweep, records, run
from repro.serving import ServingPlane

# 1. A FaultSchedule scripts device outages and draws stochastic faults
#    (flapping, throttling bursts, WAN RTT/bandwidth jitter) from
#    fold_in-keyed RNG, so realizations are bitwise invariant to window
#    partitioning, user blocks and sharding. faults=None (the default)
#    is the fault-free engine — bit-identical to the pre-fault seed
#    (tests/golden_faults_pr9.json).
outage = FaultSchedule(outages=((3, 40, 80),), timeout_ms=2000.0)
res = run(Scenario(n_users=7, n_requests=120, faults=outage))
print("p99 under outage:", round(float(res.scalar("latency_p99_ms")), 1))

# 2. visible=True (default) masks down pairs out of Algorithm 1's
#    accuracy-feasibility stage, so the router fails over to healthy
#    pairs; if no healthy pair clears the accuracy bar, the engine
#    degrades gracefully to the healthy argmin-latency pair and counts
#    an SLO violation. visible=False keeps the router blind — requests
#    dispatched into the outage stall and fail at the timeout.
aware = records(Scenario(n_users=7, n_requests=120, faults=outage))
blind = records(Scenario(n_users=7, n_requests=120,
                         faults=FaultSchedule(outages=((3, 40, 80),),
                                              timeout_ms=2000.0,
                                              visible=False)))
assert not np.any(np.asarray(aware["server"])[40:80] == 3)
print("failed requests: aware", int(np.asarray(aware["failed"]).sum()),
      "vs blind", int(np.asarray(blind["failed"]).sum()))

# 3. The schedule is a sweepable component axis (like cloud=) and
#    serializes only-when-set: a fault-free spec carries no "faults"
#    key and hashes unchanged. Mixed axes zero-fill the fault metrics
#    on the fault-free slice.
grid = run(Scenario(n_users=7, n_requests=120),
           Sweep(faults=[None, FaultSchedule(down_rate=0.1, epoch=25)]))
print("p99 by axis entry (fault-free slice zero-fills):",
      np.round(np.asarray(grid["latency_p99_ms"]), 1))
back = Scenario.from_json(json.dumps(
    Scenario(faults=outage).to_json()))
assert back.faults == outage
assert "faults" not in Scenario().to_json()

# 4. The serving plane closes the loop: on an outage the executor pool
#    fails the in-flight work on the down pairs, the plane re-routes it
#    through the (health-masked) gateway with bounded attempts, and the
#    summary reports availability alongside latency. Offer well below
#    capacity — failover needs spare capacity to absorb re-routed work
#    (at the default 90% load, losing a pair tips the fleet into a
#    retry storm).
sc = Scenario(policy="MO", n_users=48, seed=0,
              faults=FaultSchedule(outages=((4, 256, 1280),),
                                   timeout_ms=10_000.0, max_attempts=3))
plane = ServingPlane.build(sc, window=64)
plane.offered_rps = 0.5 * plane.capacity_rps()
recs = plane.run(n_requests=2048)
summ = ServingPlane.summarize(recs)
print(f"retried {summ['retried_share']:.1%}, "
      f"failed {summ['failed_share']:.1%}, "
      f"p99 {summ['latency_p99_ms']:.0f} ms under faults")
