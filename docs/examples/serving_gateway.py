"""The windowed request plane: batched routing under a drifting fleet."""
import numpy as np

from repro.core.dispatch import OnlineDispatch
from repro.core.scenario import Scenario
from repro.serving import ServingPlane

# 1. One Scenario builds the whole plane: the windowed gateway (jitted
#    batched routing, device-resident estimator + belief state), the
#    async executor pool, and the Markov scene workload.
sc = Scenario(policy="MO", n_users=64, seed=0,
              dispatch=OnlineDispatch(window=64))
plane = ServingPlane.build(sc, window=128)

# 2. Requests are admitted 128 at a time; each window is ONE jitted
#    device call, and completions polled between windows feed the belief
#    tables and the detection-count estimator.
recs = plane.run(n_requests=2048)
rps = 128 / float(np.median(recs["router_window_s"]))
print(f"router throughput: {rps:,.0f} routed req/s (steady windows)")
share_before = float(np.mean(recs["pair"] == 4))

# 3. Mid-run drift: the fleet's energy favourite (n5, orin/ssd_v1)
#    throttles 4x. Nobody tells the balancer — the pool just slows down,
#    and the gateway's windowed observations re-learn the profile.
P = plane.gateway.prof.n_pairs
t_scale = np.where(np.arange(P)[:, None] == 4, 4.0, 1.0)
plane.pool.apply_drift(t_scale)
plane.run(n_requests=1024)                       # re-convergence window
recs2 = plane.run(n_requests=2048)
share_after = float(np.mean(recs2["pair"] == 4))
print(f"pair-4 traffic share: {share_before:.2f} before drift, "
      f"{share_after:.2f} after re-learning")
assert share_after < share_before               # traffic rerouted
