"""The user axis at scale: 10^4+ users as one fused device program."""
import numpy as np

from repro.core.profiles import paper_fleet
from repro.core.scenario import Scenario, Sweep, run
from repro.core.simulator import SimConfig, _make_user_grid
from repro.core.useraxis import grid_nbytes, n_user_blocks

# 1. user_block=C decomposes a config with n_users = N > C into
#    ceil(N / C) balancer-replica blocks — independent replicas of <= C
#    users riding the fused config axis (vmapped, shardable), segment-
#    reduced back to one metrics row per config. 10^4 users, ONE program:
big = run(Scenario(n_users=10_000, n_requests=32, user_block=512,
                   warmup_frac=0.25))
print("10^4 users:", round(big.scalar("latency_ms")), "ms mean latency,",
      round(big.scalar("throughput_rps"), 1), "rps fleet throughput")

# 2. A config that fits one block (n_users <= user_block) is the
#    IDENTICAL program — bit-identical to the un-blocked engine (the
#    golden fixtures pin this in tests/test_useraxis.py).
sw = Sweep(policy=("MO", "LT"), n_users=(5, 15), seed=(0, 1))
a = run(Scenario(n_requests=200), sw)
b = run(Scenario(n_requests=200, user_block=16), sw)
assert all(np.array_equal(a[k], b[k]) for k in a.metric_names)

# 3. user_block is a static axis like n_requests (it fixes compiled
#    shapes and enters the scenario hash): sweep the replica granularity
#    itself to pick a block size.
g = run(Scenario(n_users=64, n_requests=200), Sweep(user_block=(16, 64)))
print("granularity axis (16 vs 64 users/replica):",
      g["latency_ms"].round(0))

# 4. Workload draws stream in bounded chunks (per-user fold_in keys make
#    chunking bitwise-invariant), so grid-build memory is O(total users)
#    — a 10^6-user fleet is ~8 MB of int32 leaves, not a dense
#    (configs, widest-config) pad. Engine internals, shown for the
#    memory model:
grid, segments = _make_user_grid(paper_fleet(),
                                 [SimConfig(n_users=100_000)], 1024,
                                 chunk=8192)
print("10^5-user grid:", n_user_blocks(100_000, 1024), "block rows,",
      grid_nbytes(grid) // 1024, "KiB of leaves")
