"""Dispatch engines: online adaptation vs a mid-run profile drift."""
from repro.core.dispatch import DriftSchedule, OnlineDispatch
from repro.core.profiles import paper_fleet
from repro.core.scenario import Scenario, Sweep, run

prof = paper_fleet()

# 1. A drift scenario: at dispatch step 400 the fleet's energy-favourite
#    pair (n5) loses its low-power state — 3x slower, 8x the energy. The
#    schedule perturbs the TRUE fleet only; policies never see it.
drift = DriftSchedule.throttle(prof, pair=4, at_step=400,
                               t_mult=3.0, e_mult=8.0)

# 2. dispatch and drift are named sweep axes like any other: the whole
#    {static, online} x {no drift, drift} cube is one declarative sweep.
sc = Scenario(policy="MO", n_users=10, n_requests=2000,
              oracle_estimator=True)
res = run(sc, Sweep(dispatch=(None, OnlineDispatch()),
                    drift=(None, drift)))
for name, disp in (("static", None), ("online", OnlineDispatch())):
    lat = float(res.sel("latency_ms", dispatch=disp, drift=drift))
    en = float(res.sel("energy_mwh", dispatch=disp, drift=drift))
    print(f"{name}: latency {lat:.0f} ms, energy {en:.4f} mWh")
# online-MO re-converges and wins BOTH metrics; with no drift the two
# are identical (observations equal the prior).

# 3. OnlineDispatch(window=W) swaps the annealed EWMA for a sliding
#    window over the last W observations per cell: stale evidence is
#    discarded outright, so beliefs are fully post-drift within W
#    observations of a cell — faster re-convergence after large drifts.
win = run(Scenario(policy="MO", n_users=10, n_requests=2000,
                   oracle_estimator=True, drift=drift,
                   dispatch=OnlineDispatch(window=16)))
print("windowed online latency:", round(win.scalar("latency_ms")))

# 4. A drift axis over same-shape schedules fuses into ONE device
#    program (an extra vmapped batch axis): sweep the throttle severity.
drifts = tuple(DriftSchedule.throttle(prof, pair=4, at_step=400,
                                      t_mult=tm, e_mult=8.0)
               for tm in (1.5, 3.0, 6.0))
sev = run(sc, Sweep(drift=drifts, seed=(0, 1)))
print("latency vs throttle severity:",
      sev.mean("latency_ms", over="seed").round(0))
