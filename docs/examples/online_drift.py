"""Dispatch engines: online adaptation vs a mid-run profile drift."""
from repro.core.dispatch import (DriftSchedule, OnlineDispatch,
                                 StaticDispatch)
from repro.core.profiles import paper_fleet
from repro.core.simulator import sweep_grid

prof = paper_fleet()

# 1. A drift scenario: at dispatch step 400 the fleet's energy-favourite
#    pair (n5) loses its low-power state — 3x slower, 8x the energy. The
#    schedule perturbs the TRUE fleet only; policies never see it.
drift = DriftSchedule.throttle(prof, pair=4, at_step=400,
                               t_mult=3.0, e_mult=8.0)

# 2. The same grid under static tables vs the online-EWMA engine. Both
#    are one fused device program; dispatch= composes with mesh= sharding,
#    workload= sources and stacked fleets unchanged.
kw = dict(policies=("MO",), user_levels=(10,), seeds=(0,),
          n_requests=2000, oracle=(True,))
static = sweep_grid(prof, drift=drift, **kw)
online = sweep_grid(prof, drift=drift, dispatch=OnlineDispatch(), **kw)
for name, m in (("static", static), ("online", online)):
    print(f"{name}: latency {m['latency_ms'].mean():.0f} ms, "
          f"energy {m['energy_mwh'].mean():.4f} mWh")
# online-MO re-converges and wins BOTH metrics; with no drift the two
# sweeps are identical (observations equal the prior).

# 3. StaticDispatch is the default and bit-identical to passing nothing.
a = sweep_grid(prof, **kw)
b = sweep_grid(prof, dispatch=StaticDispatch(), **kw)
assert all((a[k] == b[k]).all() for k in a)
print("static default OK:", a["latency_ms"].round(1).ravel())
