"""Sweep-engine quickstart: a Fig. 4-style grid, three ways."""
import jax

from repro.core.profiles import paper_fleet, stack_profiles, synthetic_fleet
from repro.core.simulator import grid_cache_info, sweep_grid
from repro.launch.mesh import make_sweep_mesh

prof = paper_fleet()

# 1. A policy x users x seed grid as ONE device program. Axis order of
#    every returned metric: (policy, users, gamma, delta, oracle, seed).
m = sweep_grid(prof, policies=("MO", "LT", "HA"), user_levels=(5, 15),
               seeds=(0, 1), n_requests=300)
print("latency grid shape:", m["latency_ms"].shape)      # (3, 2, 1, 1, 1, 2)
print("MO @15users latency:", m["latency_ms"][0, 1, 0, 0, 0, :].mean())
print("draw cache:", grid_cache_info())                  # 4 distinct draws

# 2. Same grid, sharded across every local device — bit-identical results.
sharded = sweep_grid(prof, policies=("MO", "LT", "HA"), user_levels=(5, 15),
                     seeds=(0, 1), n_requests=300, mesh=make_sweep_mesh())
assert (sharded["latency_ms"] == m["latency_ms"]).all()

# 3. A fleet ensemble: 3 synthetic fleets fused into the same program.
ens = stack_profiles([synthetic_fleet(jax.random.PRNGKey(i), n_pairs=5)
                      for i in range(3)])
e = sweep_grid(ens, policies=("MO",), user_levels=(10,), seeds=(0,),
               n_requests=300)
print("ensemble latency per fleet:", e["latency_ms"][:, 0, 0, 0, 0, 0, 0])
