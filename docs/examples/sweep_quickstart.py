"""Sweep-engine quickstart: a Fig. 4-style grid, three ways."""
import jax

from repro.core.profiles import stack_profiles, synthetic_fleet
from repro.core.scenario import Scenario, Sweep, run
from repro.core.simulator import grid_cache_info

# 1. A policy x users x seed grid as ONE device program. Results carry
#    named axes in declaration order — no positional index bookkeeping.
sw = Sweep(policy=("MO", "LT", "HA"), n_users=(5, 15), seed=(0, 1))
m = run(Scenario(n_requests=300), sw)
print("latency grid shape:", m["latency_ms"].shape)      # (3, 2, 2)
print("MO @15users latency:",
      m.sel("latency_ms", policy="MO", n_users=15).mean())
print("draw cache:", grid_cache_info())                  # 4 distinct draws

# 2. Same grid, sharded across every local device — the mesh is part of
#    the scenario spec, and results are bit-identical.
sharded = run(Scenario(n_requests=300, mesh="local"), sw)
assert (sharded["latency_ms"] == m["latency_ms"]).all()

# 3. A fleet ensemble: 3 synthetic fleets fused into the same program
#    (a stacked profile adds a leading named "fleet" axis).
ens = stack_profiles([synthetic_fleet(jax.random.PRNGKey(i), n_pairs=5)
                      for i in range(3)])
e = run(Scenario(profile=ens, n_requests=300),
        Sweep(policy=("MO",), n_users=(10,)))
print("ensemble axes:", e.axes)                # ('fleet', 'policy', 'n_users')
print("ensemble latency per fleet:", e["latency_ms"][:, 0, 0])
