"""moscore backends: bit-identical fp32 hoisting, bounded-error int8."""
import numpy as np

from repro.core.profiles import paper_fleet
from repro.core.quant import QuantProfileTable
from repro.kernels.moscore import moscore_route, resolve_backend

prof = paper_fleet()
rng = np.random.default_rng(0)
gs = rng.integers(0, prof.n_groups, 256)          # estimated groups
q0 = np.zeros(prof.n_pairs, np.float32)           # live queue depths

# 1. The fp32 backends are interchangeable BIT FOR BIT: the hoisted
#    variants precompute the queue-independent half of Algorithm 1
#    (feasibility mask, normalised energy) once per table instead of
#    once per request — same decisions, same final queue, less work.
ref_p, ref_q = moscore_route(prof.T, prof.E, prof.mAP, gs, q0,
                             delta=15.0, gamma=0.5, backend="xla")
for backend in ("pallas", "hoisted", "pallas_hoisted"):
    p, q = moscore_route(prof.T, prof.E, prof.mAP, gs, q0,
                         delta=15.0, gamma=0.5, backend=backend)
    assert (np.asarray(p) == np.asarray(ref_p)).all(), backend
    assert (np.asarray(q) == np.asarray(ref_q)).all(), backend

# 2. 'auto' — what the serving gateway uses — resolves per platform
#    (hoisted Pallas kernel on TPU, hoisted XLA scan elsewhere); the
#    REPRO_MOSCORE_BACKEND env var overrides it process-wide.
print("auto ->", resolve_backend("auto"))

# 3. The int8 backend routes on quantized tables: T and E drop to int8
#    with one fp32 scale per group column (~4x smaller hot payload), mAP
#    stays fp32 so the accuracy-feasibility set is EXACT. Decisions may
#    differ from fp32 only between near-tied candidates (the bounded-
#    mismatch contract, tested in tests/test_quant_route.py).
qt = QuantProfileTable.from_profile(prof)
fp32_bytes = 2 * 4 * prof.n_pairs * prof.n_groups
print(f"hot tables: {fp32_bytes} B fp32 -> {qt.nbytes_hot} B int8")
p8, _ = moscore_route(prof.T, prof.E, prof.mAP, gs, q0,
                      delta=15.0, gamma=0.5, backend="int8")
thr = np.asarray(prof.mAP).max(axis=0) - 15.0     # still feasible, always
assert (np.asarray(prof.mAP)[np.asarray(p8), gs] >= thr[gs]).all()
agree = float(np.mean(np.asarray(p8) == np.asarray(ref_p)))
print(f"int8 vs fp32 decision agreement: {agree:.0%}")
