"""The Scenario API: one spec object through sim, serving and benchmarks."""
from repro.core.scenario import Scenario, Sweep, run
from repro.serving.gateway import WindowedGateway

# 1. A Scenario bundles everything one configuration needs — fleet
#    profile, workload, dispatch engine, drift, mesh spec, and the
#    per-config knobs. Defaults reproduce the paper's testbed.
sc = Scenario(policy="MO", n_users=15, n_requests=300)

# 2. Sweep ANY field by name — not just the six axes the legacy tuple
#    hardcoded. Config-leaf axes fuse into ONE batched device program.
res = run(sc, Sweep(policy=("MO", "LT", "HA"), n_users=(5, 15),
                    seed=(0, 1)))
print("axes:", res.axes)                       # ('policy', 'n_users', 'seed')
print("MO @15 users:",
      res.sel("latency_ms", policy="MO", n_users=15).mean().round(1))
print("per-policy latency:", res.mean("latency_ms", over="seed").round(1))

# 3. stickiness was never sweepable before — now it's an axis like any
#    other, still one fused program (it is a traced grid leaf).
st = run(sc, Sweep(stickiness=(0.5, 0.85, 0.99)))
print("stickiness axis:", st["latency_ms"].round(1))

# 4. Scenarios serialize: to_json/from_json round-trip exactly, and the
#    hash fingerprints the spec (benchmark artifacts embed it, so the CI
#    gate refuses to compare different scenarios).
spec = sc.to_json()
assert Scenario.from_json(spec) == sc
print("scenario hash:", sc.hash)

# 5. Serving shares the SAME object: a windowed gateway built from the
#    scenario routes with its policy, gamma, delta and dispatch engine.
gw = WindowedGateway(sc)
print("gateway policy:", gw.policy, "- one spec, sim AND serving")
