"""Workload sources: trace-driven scene complexity through the engine."""
import numpy as np

from repro.core.scenario import Scenario, Sweep, run
from repro.data.traces import TraceWorkload, bundled_trace, synthetic_trace

# 1. The bundled recorded trace: 8 streams x 512 frames of object counts.
trace = bundled_trace()
print("trace:", trace)                       # streams, frames, name

# 2. The same grid as the quickstart, driven by the trace instead of the
#    Markov chain — the workload is a Scenario field (and a sweepable
#    axis), one fused device program per source either way.
sw = Sweep(policy=("MO", "LT", "HA"), n_users=(5, 15), seed=(0, 1))
t = run(Scenario(workload=trace, n_requests=300), sw)
m = run(Scenario(n_requests=300), sw)        # Markov default
print("trace latency grid shape:", t["latency_ms"].shape)   # (3, 2, 2)
print("MO @15users, trace vs markov latency:",
      t.sel("latency_ms", policy="MO", n_users=15).mean().round(1),
      m.sel("latency_ms", policy="MO", n_users=15).mean().round(1))

# 3. Bring your own data: any (S, T) int array of per-frame object counts
#    (or a seeded synthetic one with busy-crossing statistics for CI).
#    A workload axis compares sources side by side — one fused program
#    per source, one named axis in the results.
mine = TraceWorkload(np.tile([0, 1, 2, 4, 6, 3], (2, 10)), name="mine")
ci = synthetic_trace(seed=7, n_streams=4, n_steps=128)
r = run(Scenario(policy="MO", n_users=5, n_requests=200),
        Sweep(workload=(mine, ci)))
for tw in (mine, ci):
    print(tw.name, "mean latency:",
          round(float(r.sel("latency_ms", workload=tw)), 1))
