"""Workload sources: trace-driven scene complexity through the engine."""
import numpy as np

from repro.core.profiles import paper_fleet
from repro.core.simulator import sweep_grid
from repro.data.traces import TraceWorkload, bundled_trace, synthetic_trace

prof = paper_fleet()

# 1. The bundled recorded trace: 8 streams x 512 frames of object counts.
trace = bundled_trace()
print("trace:", trace)                       # streams, frames, name

# 2. The same grid as the quickstart, driven by the trace instead of the
#    Markov chain — one fused device program either way, and workload=
#    composes with mesh= sharding and stacked fleets unchanged.
t = sweep_grid(prof, policies=("MO", "LT", "HA"), user_levels=(5, 15),
               seeds=(0, 1), n_requests=300, workload=trace)
m = sweep_grid(prof, policies=("MO", "LT", "HA"), user_levels=(5, 15),
               seeds=(0, 1), n_requests=300)          # Markov default
print("trace latency grid shape:", t["latency_ms"].shape)  # (3, 2, 1, 1, 1, 2)
print("MO @15users, trace vs markov latency:",
      t["latency_ms"][0, 1, 0, 0, 0, :].mean().round(1),
      m["latency_ms"][0, 1, 0, 0, 0, :].mean().round(1))

# 3. Bring your own data: any (S, T) int array of per-frame object counts
#    (or a seeded synthetic one with busy-crossing statistics for CI).
mine = TraceWorkload(np.tile([0, 1, 2, 4, 6, 3], (2, 10)), name="mine")
ci = synthetic_trace(seed=7, n_streams=4, n_steps=128)
for tw in (mine, ci):
    r = sweep_grid(prof, policies=("MO",), user_levels=(5,), seeds=(0,),
                   n_requests=200, workload=tw)
    print(tw.name, "mean latency:", r["latency_ms"].mean().round(1))
