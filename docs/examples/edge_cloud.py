"""Edge-to-cloud offloading: a CloudTier as a Scenario component."""
import json

from repro.core.cloud import CloudTier
from repro.core.scenario import Scenario, Sweep, run

# 1. A CloudTier extends the edge fleet with remote pairs whose profiled
#    latency/energy fold in the network: RTT, a scene-complexity-
#    dependent payload over a shared uplink, and the radio energy of the
#    transfer. Algorithm 1 then sees offload-vs-local as ordinary pair
#    choice. cloud=None (the default) is the paper's pure-edge fleet —
#    bit-identical to the pre-cloud engine (tests/golden_cloud_pr7.json).
tier = CloudTier(rtt_ms=40.0, bw_mbps=20.0, xfer_energy_mj_per_kb=3.6)
res = run(Scenario(n_users=7, n_requests=150, cloud=tier))
print("offload share at 40 ms RTT:",
      round(float(res.scalar("offload_share")), 3))

# 2. The tier is a sweepable component axis. Sweep the RTT to find where
#    offloading stops paying, with the pure-edge fleet (None) as the
#    baseline entry on the same axis, and restate the Fig. 4 dominance
#    question with a cloud on the table.
rtts = (0.0, 80.0, 640.0)
grid = run(Scenario(n_users=7, n_requests=150),
           Sweep(policy=("MO", "HA"),
                 cloud=[None] + [CloudTier(rtt_ms=r) for r in rtts]))
lat, en, share = (grid[k] for k in
                  ("latency_ms", "energy_mwh", "offload_share"))
for j, label in enumerate(("local",) + rtts):
    dom = bool(lat[0, j] <= lat[1, j] and en[0, j] <= en[1, j])
    print(f"rtt={label}: MO offloads {float(share[0, j]):.0%}, "
          f"MO dominates HA: {dom}")

# 3. Scenarios with a cloud serialize like everything else — the tier
#    rides the spec and the hash, so benchmark artifacts refuse
#    cross-cloud comparisons (scripts/check_bench.py), and a no-cloud
#    spec carries no "cloud" key at all (hashes are unchanged from the
#    pre-cloud engine).
back = Scenario.from_json(json.dumps(Scenario(cloud=tier).to_json()))
assert back.cloud == tier
assert "cloud" not in Scenario().to_json()
