"""Capture ``tests/golden_cloud_pr7.json`` — the pre-offload engine's
record streams and 5-policy sweep metrics, through the Scenario path.

Run ONCE from the tree at PR 7 (before the CloudTier refactor landed);
the fixture pins that every ``cloud=None`` scenario stays bit-identical
through the offload-aware engine. Do NOT regenerate from later code —
that would defeat the regression (same rule as
``scripts``-less ``golden_static_pr3.json`` / ``golden_markov_pr2.json``).

Usage: PYTHONPATH=src python scripts/capture_golden_cloud.py
"""

import json
from pathlib import Path

import numpy as np

from repro.core.dispatch import OnlineDispatch
from repro.core.scenario import Scenario, Sweep, records, run

OUT = Path(__file__).resolve().parent.parent / "tests" / \
    "golden_cloud_pr7.json"

# Varied corners of the scenario space: baseline MO, the RND key stream,
# non-default gamma/delta, the oracle ablation, online-EWMA dispatch, and
# a single-block user_block config (bit-identical passthrough contract).
RECORD_SCENARIOS = [
    Scenario(n_users=5, n_requests=120, policy="MO", seed=3),
    Scenario(n_users=9, n_requests=120, policy="RND", seed=1),
    Scenario(n_users=7, n_requests=120, policy="MO", gamma=0.25,
             delta=10.0, seed=0),
    Scenario(n_users=4, n_requests=120, policy="LT", seed=2,
             oracle_estimator=True),
    Scenario(n_users=6, n_requests=120, policy="LC", seed=5,
             user_block=16),
    Scenario(n_users=5, n_requests=120, policy="MO", seed=7,
             dispatch=OnlineDispatch()),
]

SWEEP = dict(policies=("MO", "RR", "LC", "LT", "HA"),
             user_levels=(3, 7), seeds=(0, 1), n_requests=150)


def main():
    fix = {"captured_at": "PR 7 (pre-CloudTier engine)", "records": [],
           "sweep": None}
    for sc in RECORD_SCENARIOS:
        recs = records(sc)
        fix["records"].append({
            "scenario": sc.to_json(),
            "records": {k: np.asarray(v, np.float64).tolist()
                        for k, v in recs.items()},
        })
    base = Scenario(n_requests=SWEEP["n_requests"])
    res = run(base, Sweep(policy=SWEEP["policies"],
                          n_users=SWEEP["user_levels"],
                          seed=SWEEP["seeds"]))
    fix["sweep"] = {
        "scenario": base.to_json(),
        "policies": list(SWEEP["policies"]),
        "user_levels": list(SWEEP["user_levels"]),
        "seeds": list(SWEEP["seeds"]),
        "n_requests": SWEEP["n_requests"],
        "metrics": {k: res[k].tolist() for k in res.metric_names},
    }
    OUT.write_text(json.dumps(fix))
    print(f"wrote {OUT} ({OUT.stat().st_size} bytes)")


if __name__ == "__main__":
    main()
