"""Capture ``tests/golden_faults_pr9.json`` — the pre-fault-plane engine's
record streams and sweep metrics, through the Scenario path.

Run ONCE from the tree at PR 9 (before the FaultSchedule subsystem
landed); the fixture pins that every ``faults=None`` scenario stays
bit-identical through the fault-aware engine — including cloud-active
scenarios, because the fault plane touches the simulator's cloud branch
(WAN jitter). Do NOT regenerate from later code — that would defeat the
regression (same rule as ``golden_cloud_pr7.json``).

Usage: PYTHONPATH=src python scripts/capture_golden_faults.py
"""

import json
from pathlib import Path

import numpy as np

from repro.core.cloud import CloudTier
from repro.core.dispatch import OnlineDispatch
from repro.core.scenario import Scenario, Sweep, records, run

OUT = Path(__file__).resolve().parent.parent / "tests" / \
    "golden_faults_pr9.json"

# Varied corners of the scenario space: baseline MO, the RND key stream,
# non-default gamma/delta, the oracle ablation, online-EWMA dispatch, a
# single-block user_block config, and two cloud-active scenarios (the
# fault plane rewires the simulator's uplink/RTT branch, so the
# faults=None cloud path must stay bit-identical too).
RECORD_SCENARIOS = [
    Scenario(n_users=5, n_requests=120, policy="MO", seed=3),
    Scenario(n_users=9, n_requests=120, policy="RND", seed=1),
    Scenario(n_users=7, n_requests=120, policy="MO", gamma=0.25,
             delta=10.0, seed=0),
    Scenario(n_users=4, n_requests=120, policy="LT", seed=2,
             oracle_estimator=True),
    Scenario(n_users=6, n_requests=120, policy="LC", seed=5,
             user_block=16),
    Scenario(n_users=5, n_requests=120, policy="MO", seed=7,
             dispatch=OnlineDispatch()),
    Scenario(n_users=6, n_requests=120, policy="MO", seed=4,
             cloud=CloudTier()),
    Scenario(n_users=5, n_requests=120, policy="LT", seed=2,
             cloud=CloudTier(rtt_ms=10.0)),
]

SWEEP = dict(policies=("MO", "RR", "LC", "LT", "HA"),
             user_levels=(3, 7), seeds=(0, 1), n_requests=150)

CLOUD_SWEEP = dict(policies=("MO", "LT"), user_levels=(3, 7), seeds=(0,),
                   n_requests=150)


def _sweep_fixture(base: Scenario, spec: dict) -> dict:
    res = run(base, Sweep(policy=spec["policies"],
                          n_users=spec["user_levels"],
                          seed=spec["seeds"]))
    return {
        "scenario": base.to_json(),
        "policies": list(spec["policies"]),
        "user_levels": list(spec["user_levels"]),
        "seeds": list(spec["seeds"]),
        "n_requests": spec["n_requests"],
        "metrics": {k: res[k].tolist() for k in res.metric_names},
    }


def main():
    fix = {"captured_at": "PR 9 (pre-FaultSchedule engine)", "records": [],
           "sweep": None, "cloud_sweep": None}
    for sc in RECORD_SCENARIOS:
        recs = records(sc)
        fix["records"].append({
            "scenario": sc.to_json(),
            "records": {k: np.asarray(v, np.float64).tolist()
                        for k, v in recs.items()},
        })
    fix["sweep"] = _sweep_fixture(
        Scenario(n_requests=SWEEP["n_requests"]), SWEEP)
    fix["cloud_sweep"] = _sweep_fixture(
        Scenario(n_requests=CLOUD_SWEEP["n_requests"], cloud=CloudTier()),
        CLOUD_SWEEP)
    OUT.write_text(json.dumps(fix))
    print(f"wrote {OUT} ({OUT.stat().st_size} bytes)")


if __name__ == "__main__":
    main()
