"""Perf hillclimb measurements (§Perf): re-lower the three chosen cells with
the current (optimized) code and record the roofline terms next to their
baselines.

  PYTHONPATH=src python scripts/run_hillclimb.py
"""

import json
import os
import sys

sys.path.insert(0, "src")

from repro.launch.dryrun import run_cell  # noqa: E402  (sets XLA_FLAGS first)

OUT = "experiments/perf"
os.makedirs(OUT, exist_ok=True)

CELLS = [
    # (arch, shape, multi_pod, kv_dtype, tag)
    ("arctic-480b", "train_4k", False, None, "it1_rep_pinned"),
    ("stablelm-3b", "train_4k", True, None, "it1_rep_pinned"),
    ("stablelm-3b", "prefill_32k", False, None, "it2_kvhead_shard"),
    ("deepseek-moe-16b", "decode_32k", False, None, "it2_kvhead_shard"),
    ("deepseek-moe-16b", "decode_32k", False, "int8", "it3_int8_kv"),
    ("deepseek-moe-16b", "long_500k", False, "int8", "it3_int8_kv"),
]

for arch, shape, mp, kv, tag in CELLS:
    rec = run_cell(arch, shape, mp, kv_dtype=kv)
    rec["iteration"] = tag
    name = f"{arch}__{shape}__{'multi' if mp else 'single'}__{tag}.json"
    with open(os.path.join(OUT, name), "w") as f:
        json.dump(rec, f, indent=1, default=float)
print("hillclimb measurements complete")

# it4/it5 second wave (FSDP-only rules now active in select_rules)
CELLS2 = [
    ("stablelm-3b", "train_4k", False, None, "it2_fsdp_only"),
    ("stablelm-3b", "train_4k", True, None, "it2_fsdp_only"),
    ("stablelm-3b", "prefill_32k", False, None, "it4_zero_inference"),
]
for arch, shape, mp, kv, tag in CELLS2:
    rec = run_cell(arch, shape, mp, kv_dtype=kv)
    rec["iteration"] = tag
    name = f"{arch}__{shape}__{'multi' if mp else 'single'}__{tag}.json"
    with open(os.path.join(OUT, name), "w") as f:
        json.dump(rec, f, indent=1, default=float)
print("second-wave measurements complete")
