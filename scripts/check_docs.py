#!/usr/bin/env python
"""Docs link check: every relative markdown link in the repo's documents
must resolve to a real file.

  python scripts/check_docs.py

Scans README.md and all ``docs/**/*.md`` plus in-tree READMEs for
``[text](target)`` links, skips absolute URLs and pure anchors, and
resolves each target against the linking file's directory. Exit 0 = all
links resolve; 1 = at least one dangling link (each printed). Wired into
CI's lint job and ``tests/test_docs.py`` so docs can't rot silently.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE = re.compile(r"```.*?(?:```|\Z)", re.DOTALL)
INLINE_CODE = re.compile(r"`[^`\n]*`")


def doc_files() -> list[Path]:
    docs = [REPO / "README.md"]
    docs += sorted((REPO / "docs").rglob("*.md"))
    docs += sorted((REPO / "src").rglob("README.md"))
    return [d for d in docs if d.exists()]


def dangling_links(doc: Path) -> list[str]:
    bad = []
    # drop fenced blocks and inline code spans first: `x[key](arg)` in a
    # code sample is Python, not a markdown link (FENCE also swallows an
    # unterminated final fence)
    text = INLINE_CODE.sub("", FENCE.sub("", doc.read_text()))
    for target in LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not (doc.parent / path).resolve().exists():
            bad.append(f"{doc.relative_to(REPO)}: dangling link -> {target}")
    return bad


def main() -> int:
    errs = []
    for doc in doc_files():
        errs += dangling_links(doc)
    for e in errs:
        print(f"check_docs: FAIL: {e}")
    if not errs:
        print(f"check_docs: OK: {len(doc_files())} documents, all relative "
              f"links resolve")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
