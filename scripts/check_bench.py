#!/usr/bin/env python
"""Validate a benchmark JSON artifact and gate on wall-clock regressions.

  python scripts/check_bench.py NEW.json [BASELINE.json]
         [--threshold 0.20] [--threshold sweep_sharded=0.35]
         [--min-abs 0.5] [--strict]

Always validates NEW.json against the ``repro-bench/v1`` schema emitted by
``benchmarks/run.py --json`` (suites present, no suite errors, numeric
``seconds``). With a baseline, additionally fails when any suite's
``bench.<name>.seconds`` regressed by more than ``--threshold`` (relative,
default 20%) AND more than ``--min-abs`` seconds (absolute floor so
sub-second suites don't flap on scheduler noise). ``--threshold`` repeats:
a bare float sets the global budget, ``SUITE=FLOAT`` overrides one suite
(e.g. ``--threshold sweep_sharded=0.35`` loosens only the timing-sensitive
sharded suite, so runner variance on it can't flap the blocking gate).

Like-for-like: artifacts record the base :class:`repro.core.scenario
.Scenario` they ran under (``scenario`` spec + ``scenario_hash``). When
both artifacts carry a hash, a mismatch fails the comparison outright —
different scenarios are different benchmarks; legacy artifacts without a
hash fall back to the old ``workload``/``dispatch`` mode-string check.
Independently of the hash, a ``cloud`` tier or ``faults`` schedule spec
difference between the two scenarios is refused outright — an
offload-aware or fault-injected run can shift every suite's timing
profile.

A suite present in the new run but absent from the baseline is *stale
baseline*: the comparison silently skips it, so the suite goes
unmonitored. That prints a WARN line (an error under ``--strict``) telling
you to regenerate ``benchmarks/bench_baseline.json`` — the failure mode
where a newly added suite never gets a regression gate.

Exit code 0 = artifact valid and within budget; 1 = invalid, regressed, or
(``--strict``) stale baseline. Wired into CI's bench job as an
allow-failure step until runner timing baselines stabilise.
"""

from __future__ import annotations

import argparse
import json
import sys

SCHEMA = "repro-bench/v1"


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def validate(art: dict, label: str) -> list[str]:
    errs = []
    if art.get("schema") != SCHEMA:
        errs.append(f"{label}: schema is {art.get('schema')!r}, "
                    f"expected {SCHEMA!r}")
        return errs
    suites = art.get("suites")
    if not isinstance(suites, dict) or not suites:
        errs.append(f"{label}: no suites recorded")
        return errs
    for name, s in suites.items():
        if s.get("error"):
            errs.append(f"{label}: suite {name} errored: {s['error']}")
        if not isinstance(s.get("seconds"), (int, float)):
            errs.append(f"{label}: suite {name} has no numeric seconds")
        if not s.get("error") and not s.get("rows"):
            errs.append(f"{label}: suite {name} produced no rows")
    return errs


def parse_thresholds(specs, default: float = 0.20) -> dict:
    """``--threshold`` values -> ``{"*": global, suite: override, ...}``.
    Each spec is either a bare float (sets the global budget) or
    ``SUITE=FLOAT`` (overrides one suite)."""
    out = {"*": default}
    for spec in specs or ():
        name, sep, val = str(spec).partition("=")
        try:
            if sep:
                if not name:
                    raise ValueError
                out[name] = float(val)
            else:
                out["*"] = float(name)
        except ValueError:
            raise SystemExit(f"check_bench: bad --threshold {spec!r} "
                             "(want FLOAT or SUITE=FLOAT)")
    return out


def compare(new: dict, base: dict, threshold,
            min_abs: float) -> list[str]:
    thresholds = threshold if isinstance(threshold, dict) \
        else {"*": threshold}
    errs = []
    if new.get("scenario_hash") and base.get("scenario_hash"):
        if new["scenario_hash"] != base["scenario_hash"]:
            errs.append(
                f"artifacts not comparable: scenario_hash is "
                f"{new['scenario_hash']} (new) vs "
                f"{base['scenario_hash']} (baseline) — different "
                f"scenarios are different benchmarks")
        mode_keys = ("fast", "backend")     # hash covers the scenario
    else:
        mode_keys = ("fast", "backend", "workload", "dispatch")
    # an offload-aware run is a different benchmark even when a legacy
    # artifact carries no hash: refuse cloud-spec mismatches explicitly
    n_cloud = (new.get("scenario") or {}).get("cloud")
    b_cloud = (base.get("scenario") or {}).get("cloud")
    if n_cloud != b_cloud:
        errs.append(f"artifacts not comparable: cloud tier spec is "
                    f"{n_cloud!r} (new) vs {b_cloud!r} (baseline)")
    # the same rule for the fault plane: a fault-injected run is a
    # different benchmark, never a timing regression
    n_faults = (new.get("scenario") or {}).get("faults")
    b_faults = (base.get("scenario") or {}).get("faults")
    if n_faults != b_faults:
        errs.append(f"artifacts not comparable: faults schedule spec is "
                    f"{n_faults!r} (new) vs {b_faults!r} (baseline)")
    for key in mode_keys:
        if key in new and key in base and new[key] != base[key]:
            errs.append(f"artifacts not comparable: {key} is "
                        f"{new[key]!r} (new) vs {base[key]!r} (baseline)")
    if errs:
        return errs
    for name, b in base["suites"].items():
        n = new["suites"].get(name)
        if n is None:
            errs.append(f"suite {name} present in baseline but missing "
                        f"from new run")
            continue
        th = thresholds.get(name, thresholds["*"])
        t_new, t_base = n["seconds"], b["seconds"]
        if t_new > t_base * (1 + th) and t_new - t_base > min_abs:
            errs.append(f"bench.{name}.seconds regressed: "
                        f"{t_base:.2f}s -> {t_new:.2f}s "
                        f"(+{100 * (t_new / max(t_base, 1e-9) - 1):.0f}%, "
                        f"threshold {100 * th:.0f}%)")
    return errs


def stale_suites(new: dict, base: dict) -> list[str]:
    """Suites recorded in the new run but absent from the baseline — they
    bypass ``compare`` entirely, so regressions in them go unnoticed until
    the baseline is regenerated."""
    return [name for name in new.get("suites", {})
            if name not in base.get("suites", {})]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="fresh artifact from benchmarks.run --json")
    ap.add_argument("baseline", nargs="?", default=None,
                    help="committed baseline to diff against")
    ap.add_argument("--threshold", action="append", default=None,
                    metavar="FLOAT | SUITE=FLOAT",
                    help="max relative slowdown (default 0.20); repeat "
                         "with SUITE=FLOAT for per-suite overrides, e.g. "
                         "--threshold sweep_sharded=0.35")
    ap.add_argument("--min-abs", type=float, default=0.5,
                    help="ignore regressions smaller than this many "
                         "seconds (default 0.5)")
    ap.add_argument("--strict", action="store_true",
                    help="treat a stale baseline (new suites without a "
                         "baseline entry) as a failure, not a warning")
    args = ap.parse_args(argv)

    try:
        new = load(args.new)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_bench: FAIL: cannot read {args.new}: {e}")
        return 1
    errs = validate(new, "new")
    warns: list[str] = []
    if args.baseline and not errs:
        try:
            base = load(args.baseline)
        except (OSError, json.JSONDecodeError) as e:
            print(f"check_bench: FAIL: cannot read {args.baseline}: {e}")
            return 1
        errs += validate(base, "baseline")
        if not errs:
            thresholds = parse_thresholds(args.threshold)
            errs += compare(new, base, thresholds, args.min_abs)
            warns = [f"suite {s} has no baseline entry — unmonitored; "
                     f"regenerate {args.baseline}"
                     for s in stale_suites(new, base)]
            # a typoed per-suite override would silently fall back to
            # the global budget — surface it like a stale suite
            warns += [f"--threshold override for unknown suite {s!r} "
                      f"is inoperative (suites: "
                      f"{', '.join(sorted(new['suites']))})"
                      for s in sorted(thresholds)
                      if s != "*" and s not in new["suites"]]
            if args.strict:
                errs += warns
                warns = []

    for w in warns:
        print(f"check_bench: WARN: {w}")
    for e in errs:
        print(f"check_bench: FAIL: {e}")
    if not errs:
        n = len(new["suites"])
        total = sum(s["seconds"] for s in new["suites"].values())
        print(f"check_bench: OK: {n} suites, {total:.1f}s total"
              + (", within budget of baseline" if args.baseline else ""))
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
