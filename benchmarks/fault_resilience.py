"""Fault resilience: what does failover-aware routing buy when the
fleet's busiest device goes down, and how does WAN jitter move the
edge/cloud split?

Part A scripts an outage on the *busiest* pair (argmax of the no-fault
MO run's per-pair request counts) over the middle third of the run and
replays it under MO/LT/HA with a health-mask-aware router, plus MO with
``visible=False`` — the static-table strawman that keeps dispatching
into the outage and only learns via timeouts. Per policy it reports
mean/p99 latency and the failed/SLO-violation shares, then a
recovery-time row: the number of post-outage steps until the
seed-averaged rolling-mean latency returns to within 10% of the
pre-outage baseline. The ``aware_recovers_faster`` verdict row is the
PR's acceptance criterion — failover-aware MO must recover at least as
fast as the blind static router (and strictly faster unless both are
instant).

Part B adds a cloud tier and sweeps stochastic WAN RTT jitter in one
fused scenario-engine run (policy x faults x seed): as the RTT spread
grows, offloading gets riskier and the offload share + tail latency
rows show the router hedging back toward the edge."""

from dataclasses import replace

import numpy as np

from repro.core import scenario as SC
from repro.core.cloud import CloudTier
from repro.core.faults import FaultSchedule
from repro.core.scenario import Scenario, Sweep

POLICIES = ["MO", "LT", "HA"]
JITTERS = [0.0, 20.0, 60.0, 150.0]
TIMEOUT_MS = 2000.0
RECOVERY_TOL = 1.10


def _recovery_steps(lat: np.ndarray, end: int, base_mean: float,
                    window: int) -> int:
    """First post-outage step where the forward ``window``-step mean of
    the seed-averaged latency series is back within ``RECOVERY_TOL`` of
    the pre-outage baseline; -1 = never within the run."""
    for i in range(end, lat.size - window + 1):
        if lat[i:i + window].mean() <= RECOVERY_TOL * base_mean:
            return i - end
    return -1


def run(scenario: Scenario | None = None, n_requests: int = 600,
        n_users: int = 9, seeds=(0, 1, 2)) -> list[str]:
    scenario = scenario if scenario is not None else Scenario()
    base = replace(scenario, n_requests=n_requests, n_users=n_users,
                   policy="MO", cloud=None, faults=None)
    sw = Sweep(seed=list(seeds))
    n = n_requests
    start, end = n // 3, 2 * n // 3
    window = max(10, n // 12)

    # -- Part A: scripted outage on the busiest pair -------------------
    r0 = SC.records(base, sw)
    lat0 = np.asarray(r0["latency"]).mean(axis=0)
    base_mean = float(lat0[n // 6:start].mean())
    busy = int(np.bincount(np.asarray(r0["server"]).ravel()).argmax())

    rows = [f"fault_resilience.outage_pair,{busy},{start},{end},,",
            "fault_resilience.policy,mode,latency_ms,latency_p99_ms,"
            "failed_share,slo_share"]
    variants = [(pol, True) for pol in POLICIES] + [("MO", False)]
    recov: dict[str, int] = {}
    for pol, visible in variants:
        fs = FaultSchedule(outages=((busy, start, end),),
                           timeout_ms=TIMEOUT_MS, visible=visible)
        r = SC.records(replace(base, policy=pol, faults=fs), sw)
        lat = np.asarray(r["latency"])
        mode = "aware" if visible else "blind"
        rows.append(
            f"fault_resilience.{pol},{mode},"
            f"{1e3 * lat.mean():.1f},"
            f"{1e3 * np.percentile(lat, 99):.1f},"
            f"{np.asarray(r['failed']).mean():.4f},"
            f"{np.asarray(r['slo_violation']).mean():.4f}")
        if pol == "MO":
            recov[mode] = _recovery_steps(lat.mean(axis=0), end,
                                          base_mean, window)

    for mode in ("aware", "blind"):
        rows.append(f"fault_resilience.recovery_steps,{mode},"
                    f"{recov[mode]},,,")
    # -1 = never recovered within the run: score it as the full run
    eff = {m: (v if v >= 0 else n) for m, v in recov.items()}
    faster = int(eff["aware"] < eff["blind"]
                 or (eff["aware"] == 0 and eff["blind"] == 0))
    rows.append(f"fault_resilience.aware_recovers_faster,{faster},"
                f"{eff['aware']},{eff['blind']},,")

    # -- Part B: WAN RTT jitter with a cloud tier ----------------------
    tiers = [None] + [FaultSchedule(rtt_jitter_ms=j, bw_jitter=0.5)
                      for j in JITTERS[1:]]
    res = SC.run(replace(base, cloud=CloudTier()),
                 Sweep(policy=["MO", "LT"], faults=tiers,
                       seed=list(seeds)))
    mean = {m: res.mean(m, over="seed")
            for m in ("latency_ms", "latency_p90_ms", "offload_share")}
    rows.append("fault_resilience.wan.policy,rtt_jitter_ms,latency_ms,"
                "latency_p90_ms,offload_share,")
    for i, pol in enumerate(["MO", "LT"]):
        for j, jit in enumerate(JITTERS):
            rows.append(f"fault_resilience.wan.{pol},{jit:g},"
                        f"{mean['latency_ms'][i, j]:.3f},"
                        f"{mean['latency_p90_ms'][i, j]:.3f},"
                        f"{mean['offload_share'][i, j]:.3f},")
    return rows
