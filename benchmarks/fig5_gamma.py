"""Fig. 5: the gamma sweep (MO_gamma_{0,25,50,75,1}). All gammas × user
levels × seeds run as ONE batched device program via ``sweep_grid``
(previously one ``sweep`` per gamma, each a Python loop of jits)."""

import numpy as np

from repro.core.profiles import paper_fleet
from repro.core.simulator import sweep_grid

GAMMAS = [0.0, 0.25, 0.5, 0.75, 1.0]
USERS = [1, 5, 10, 15]
METRICS = ["latency_ms", "latency_p90_ms", "throughput_rps", "energy_mwh",
           "map"]


def run(n_requests: int = 1500, seeds=(0, 1), mesh=None,
        workload=None, dispatch=None) -> list[str]:
    prof = paper_fleet()
    grid = sweep_grid(prof, policies=("MO",), user_levels=USERS,
                      gammas=GAMMAS, seeds=seeds, n_requests=n_requests,
                      mesh=mesh, workload=workload, dispatch=dispatch)
    # (policy, users, gamma, delta, oracle, seed) -> mean over seeds
    res = {k: np.mean(v[0, :, :, 0, 0, :], axis=-1)
           for k, v in grid.items()}
    rows = ["fig5.gamma,users," + ",".join(METRICS)]
    for gi, g in enumerate(GAMMAS):
        for ui, u in enumerate(USERS):
            vals = ",".join(f"{res[m][ui, gi]:.3f}" for m in METRICS)
            rows.append(f"fig5.MO_gamma_{int(g * 100)},{u},{vals}")
    return rows
