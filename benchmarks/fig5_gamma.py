"""Fig. 5: the gamma sweep (MO_gamma_{0,25,50,75,1}). All gammas × user
levels × seeds run as ONE batched device program — ``gamma`` is just
another named sweep axis on the scenario engine."""

from dataclasses import replace

from repro.core import scenario as SC
from repro.core.scenario import Scenario, Sweep

GAMMAS = [0.0, 0.25, 0.5, 0.75, 1.0]
USERS = [1, 5, 10, 15]
METRICS = ["latency_ms", "latency_p90_ms", "throughput_rps", "energy_mwh",
           "map"]


def run(scenario: Scenario | None = None, n_requests: int = 1500,
        seeds=(0, 1)) -> list[str]:
    scenario = scenario if scenario is not None else Scenario()
    res = SC.run(replace(scenario, policy="MO", n_requests=n_requests),
                 Sweep(n_users=USERS, gamma=GAMMAS, seed=seeds))
    mean = {m: res.mean(m, over="seed") for m in res.metric_names}
    rows = ["fig5.gamma,users," + ",".join(METRICS)]
    for gi, g in enumerate(GAMMAS):
        for ui, u in enumerate(USERS):
            vals = ",".join(f"{mean[m][ui, gi]:.3f}" for m in METRICS)
            rows.append(f"fig5.MO_gamma_{int(g * 100)},{u},{vals}")
    return rows
