"""Fig. 5: the gamma sweep (MO_gamma_{0,25,50,75,1})."""

from repro.core.profiles import paper_fleet
from repro.core.simulator import sweep

GAMMAS = [0.0, 0.25, 0.5, 0.75, 1.0]
USERS = [1, 5, 10, 15]
METRICS = ["latency_ms", "latency_p90_ms", "throughput_rps", "energy_mwh",
           "map"]


def run(n_requests: int = 1500, seeds=(0, 1)) -> list[str]:
    prof = paper_fleet()
    rows = ["fig5.gamma,users," + ",".join(METRICS)]
    for g in GAMMAS:
        res = sweep(prof, ["MO"], USERS, n_requests=n_requests, gamma=g,
                    seeds=seeds)["MO"]
        for i, u in enumerate(USERS):
            vals = ",".join(f"{res[m][i]:.3f}" for m in METRICS)
            rows.append(f"fig5.MO_gamma_{int(g * 100)},{u},{vals}")
    return rows
