"""Large-scale posture: decision latency and simulator behaviour as the
fleet grows from the paper's 5 nodes toward thousands (the regime the
multi-pod deployment targets; paper §V names this as the open problem),
plus the scaled USER axis: one fused ``run()`` at 10^5 users via
``Scenario(user_block=...)`` block decomposition, reporting both
configs/sec (block rows through the device program) and users/sec — the
numbers the user-scaling regression gate watches."""

import time

import jax
import jax.numpy as jnp

from repro.core import scenario as SC
from repro.core.hierarchy import hierarchical_select, pod_aggregate
from repro.core.policies import mo_select
from repro.core.profiles import stack_profiles, synthetic_fleet
from repro.core.scenario import Scenario, Sweep
from repro.core.useraxis import n_user_blocks
from repro.kernels.moscore import moscore_route


def _time_us(fn, *args, n=20):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run() -> list[str]:
    rows = ["scale.fleet_size,decision_us,window256_us_per_req,"
            "sim_latency_ms,sim_map"]
    rng = jax.random.PRNGKey(0)
    for n_pairs in (5, 64, 256, 1024):
        prof = synthetic_fleet(rng, n_pairs)
        q = jnp.zeros((n_pairs,))
        one = jax.jit(lambda T, E, M, qq: mo_select(
            type(prof)(T, E, M), 3, qq, delta=20.0, gamma=0.5)[0])
        t_one = _time_us(one, prof.T, prof.E, prof.mAP, q)
        gs = jax.random.randint(rng, (256,), 0, 5)
        t_win = _time_us(
            lambda T, E, M, g, qq: moscore_route(T, E, M, g, qq,
                                                 delta=20.0, gamma=0.5),
            prof.T, prof.E, prof.mAP, gs, q) / 256.0
        s = SC.run(Scenario(profile=prof, n_users=min(4 * n_pairs, 256),
                            n_requests=1200))
        rows.append(f"scale.{n_pairs},{t_one:.1f},{t_win:.2f},"
                    f"{s.scalar('latency_ms'):.0f},{s.scalar('map'):.1f}")

    # hierarchical vs flat at 256 pairs / 8 pods (staleness regret)
    prof = synthetic_fleet(rng, 256)
    pod_of = jnp.asarray([i // 32 for i in range(256)])
    pods = pod_aggregate(prof, pod_of)
    h = jax.jit(lambda q, qp: hierarchical_select(
        prof, pods, pod_of, 3, q, qp, delta=20.0, gamma=0.5)[0])
    t_h = _time_us(h, jnp.zeros(256), jnp.zeros(8))
    rows.append(f"scale.hierarchical_256p_us,{t_h:.1f},,,")

    # batched sweep engine: a 63-config Fig.4-style grid (7 policies x 3
    # user levels x 3 seeds) as ONE fused device program. cold = trace +
    # compile + run; warm = cached-trace rerun plus the host-side grid
    # build (make_grid's per-config init draws) — the steady-state
    # end-to-end cost the CI regression gate watches.
    sc = Scenario(n_requests=400)
    sw = Sweep(policy=("MO", "RR", "RND", "LC", "LE", "LT", "HA"),
               n_users=(5, 10, 15), seed=(0, 1, 2))
    t0 = time.perf_counter()
    SC.run(sc, sw)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    SC.run(sc, sw)
    t_warm = time.perf_counter() - t0
    rows.append(f"scale.batched_sweep_63cfg_cold_s,{t_cold:.2f},,,")
    rows.append(f"scale.batched_sweep_63cfg_warm_s,{t_warm:.2f},,,")

    # fleet-axis batching: a 4-fleet synthetic robustness ensemble fused
    # with the 63-config grid into ONE device program (252 fleet x config
    # cells) — previously one sweep per fleet.
    ensemble = stack_profiles([synthetic_fleet(jax.random.fold_in(rng, i), 5)
                               for i in range(4)])
    ens_sc = Scenario(profile=ensemble, n_requests=400)
    SC.run(ens_sc, sw)
    t0 = time.perf_counter()
    SC.run(ens_sc, sw)
    t_ens = time.perf_counter() - t0
    rows.append(f"scale.fleet_ensemble_4x63cfg_warm_s,{t_ens:.2f},,,")

    # user axis: n_users=10^5 as ONE fused program (98 balancer-replica
    # block rows of 1024 users riding the config axis, segment-reduced
    # back to one config's metrics). users/sec is the headline the
    # user-scaling test suite pins at >= 10x the looped dense path;
    # 10^6 runs the same way (tests/test_useraxis.py, opt-in marker).
    N, C = 100_000, 1024
    sc_u = Scenario(n_users=N, n_requests=32, user_block=C,
                    warmup_frac=0.25)
    SC.run(sc_u)                                      # compile
    t0 = time.perf_counter()
    u = SC.run(sc_u)
    t_user = time.perf_counter() - t0
    k = n_user_blocks(N, C)
    rows.append(f"scale.user_axis_1e5_warm_s,{t_user:.2f},,"
                f"{u.scalar('latency_ms'):.0f},{u.scalar('map'):.1f}")
    rows.append(f"scale.user_axis_1e5_users_per_sec,{N / t_user:.0f},,,")
    rows.append(f"scale.user_axis_1e5_configs_per_sec,"
                f"{k / t_user:.1f},,,")
    return rows
