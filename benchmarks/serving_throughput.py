"""Routed-request throughput of the windowed serving plane.

The request plane's hot path is ``WindowedGateway.route_window``: one
jitted device program per admission window (estimator gather + belief
tables + the fused ``moscore`` routing kernel, backend-aware). This
suite measures it warm, per window size and dispatch engine:

  * ``routed_rps`` — routed requests/sec sustained over the run (the
    acceptance bar is 1e5+ on the default fleet);
  * ``p50_ms`` / ``p99_ms`` — router tail latency per WINDOW (the wait a
    request pays for its window's routing decision).

``per_request`` is the deprecated per-request path (windows of one) for
contrast — the gap is the point of the windowed redesign. ``plane_e2e``
runs the full :class:`~repro.serving.engine.ServingPlane` loop (poll ->
observe -> route -> submit) and reports end-to-end req/s including the
host-side executor-pool accounting."""

import time
from dataclasses import replace

import numpy as np

from repro.core.dispatch import OnlineDispatch
from repro.core.scenario import Scenario
from repro.serving.engine import ServingPlane
from repro.serving.gateway import WindowedGateway

N_STREAMS = 1024


def _throughput(gw: WindowedGateway, window: int, n_requests: int):
    rng = np.random.default_rng(0)
    ids = rng.integers(0, N_STREAMS, size=n_requests + window)
    q0 = np.zeros(gw.prof.n_pairs, np.float32)
    gw.route_window(ids[:window], q0)[0].block_until_ready()   # warm/compile
    times, done = [], 0
    t_all = time.perf_counter()
    while done < n_requests:
        t0 = time.perf_counter()
        pairs, _gs, _q = gw.route_window(ids[done:done + window], q0)
        pairs.block_until_ready()
        times.append(time.perf_counter() - t0)
        done += window
    elapsed = time.perf_counter() - t_all
    t = np.asarray(times) * 1000.0
    return done / elapsed, float(np.percentile(t, 50)), \
        float(np.percentile(t, 99))


def run(scenario: Scenario | None = None, n_requests: int = 200_000,
        window: int = 1024) -> list[str]:
    base = replace(scenario if scenario is not None else Scenario(),
                   policy="MO", dispatch=None)
    rows = ["serving_throughput.case,routed_rps,p50_ms,p99_ms"]
    cases = [(f"static_w{w}", None, w, "auto")
             for w in (window // 4, window, window * 4)]
    cases.append((f"online_w{window}", OnlineDispatch(), window, "auto"))
    # the quantized belief-table path (bounded-mismatch contract): the
    # gateway quantizes the tables handed to the kernel each window
    cases.append((f"int8_w{window}", None, window, "int8"))
    best = 0.0
    for name, disp, w, backend in cases:
        gw = WindowedGateway(replace(base, dispatch=disp),
                             n_streams=N_STREAMS, backend=backend)
        rps, p50, p99 = _throughput(gw, w, n_requests)
        if backend == "auto":    # best tracks the bit-exact fp32 paths
            best = max(best, rps)
        rows.append(f"serving_throughput.{name},{rps:.0f},{p50:.3f},"
                    f"{p99:.3f}")

    # the deprecated per-request path, for contrast (much smaller run —
    # one device program per request is exactly what it costs)
    gw1 = WindowedGateway(base, n_streams=N_STREAMS, backend="auto")
    rps1, p50, p99 = _throughput(gw1, 1, max(2000, n_requests // 100))
    rows.append(f"serving_throughput.per_request,{rps1:.0f},{p50:.3f},"
                f"{p99:.3f}")

    # full plane loop: admission + routing + async pool + observation
    plane = ServingPlane.build(replace(base, n_users=N_STREAMS),
                               window=window)
    n_e2e = max(window * 8, n_requests // 8)
    t0 = time.perf_counter()
    recs = plane.run(n_e2e)
    e2e_rps = n_e2e / (time.perf_counter() - t0)
    # steady-state router rate inside the plane: median per-window time
    # (the mean would charge the first window's compile to every window)
    router_rps = window / float(np.median(recs["router_window_s"]))
    rows.append(f"serving_throughput.plane_e2e,{e2e_rps:.0f},,")
    rows.append(f"serving_throughput.plane_router_steady,{router_rps:.0f},,")
    rows.append(f"serving_throughput.routed_rps_best,{best:.0f},,")
    rows.append(f"serving_throughput.windowed_vs_per_request,"
                f"{best / rps1:.1f},,")
    return rows
