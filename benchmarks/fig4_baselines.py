"""Fig. 4: all seven policies across concurrency levels, 3 seeds each
(the paper's main comparison)."""

import numpy as np

from repro.core.profiles import paper_fleet
from repro.core.simulator import sweep

POLICIES = ["MO", "RR", "RND", "LC", "LE", "LT", "HA"]
USERS = [1, 3, 5, 7, 9, 11, 13, 15]
METRICS = ["latency_ms", "latency_p90_ms", "throughput_rps", "energy_mwh",
           "map"]


def run(n_requests: int = 1500, seeds=(0, 1, 2)) -> list[str]:
    prof = paper_fleet()
    res = sweep(prof, POLICIES, USERS, n_requests=n_requests, seeds=seeds)
    rows = ["fig4.policy,users," + ",".join(METRICS)]
    for pol in POLICIES:
        for i, u in enumerate(USERS):
            vals = ",".join(f"{res[pol][m][i]:.3f}" for m in METRICS)
            rows.append(f"fig4.{pol},{u},{vals}")
    # headline ratios at 15 users (paper §IV-C)
    i15 = USERS.index(15)
    mo, ha, lt, le = (res[p] for p in ("MO", "HA", "LT", "LE"))
    rows.append(f"fig4.headline_mo_vs_ha_latency,15,"
                f"{mo['latency_ms'][i15] / ha['latency_ms'][i15]:.3f},,,,")
    rows.append(f"fig4.headline_mo_vs_ha_energy,15,"
                f"{mo['energy_mwh'][i15] / ha['energy_mwh'][i15]:.3f},,,,")
    rows.append(f"fig4.headline_map_gap_pct,15,"
                f"{100 * (ha['map'][i15] - mo['map'][i15]) / ha['map'][i15]:.2f},,,,")
    rows.append(f"fig4.headline_mo_vs_lt_latency,15,"
                f"{mo['latency_ms'][i15] / lt['latency_ms'][i15]:.3f},,,,")
    return rows
