"""Fig. 4: all seven policies across concurrency levels, 3 seeds each
(the paper's main comparison). The whole policy × users × seed grid runs
as ONE batched device program via the scenario engine
(``repro.core.scenario.run``) — a single jitted vmap(simulate +
summarize) instead of one trace per configuration."""

from dataclasses import replace

from repro.core import scenario as SC
from repro.core.scenario import Scenario, Sweep

POLICIES = ["MO", "RR", "RND", "LC", "LE", "LT", "HA"]
USERS = [1, 3, 5, 7, 9, 11, 13, 15]
METRICS = ["latency_ms", "latency_p90_ms", "throughput_rps", "energy_mwh",
           "map"]


def run(scenario: Scenario | None = None, n_requests: int = 1500,
        seeds=(0, 1, 2)) -> list[str]:
    scenario = scenario if scenario is not None else Scenario()
    res = SC.run(replace(scenario, n_requests=n_requests),
                 Sweep(policy=POLICIES, n_users=USERS, seed=seeds))
    mean = {m: res.mean(m, over="seed") for m in res.metric_names}
    rows = ["fig4.policy,users," + ",".join(METRICS)]
    for i, pol in enumerate(POLICIES):
        for j, u in enumerate(USERS):
            vals = ",".join(f"{mean[m][i, j]:.3f}" for m in METRICS)
            rows.append(f"fig4.{pol},{u},{vals}")
    # headline ratios at 15 users (paper §IV-C)
    j15 = USERS.index(15)
    mo, ha, lt = (POLICIES.index(p) for p in ("MO", "HA", "LT"))
    lat, en, mp = mean["latency_ms"], mean["energy_mwh"], mean["map"]
    rows.append(f"fig4.headline_mo_vs_ha_latency,15,"
                f"{lat[mo, j15] / lat[ha, j15]:.3f},,,,")
    rows.append(f"fig4.headline_mo_vs_ha_energy,15,"
                f"{en[mo, j15] / en[ha, j15]:.3f},,,,")
    rows.append(f"fig4.headline_map_gap_pct,15,"
                f"{100 * (mp[ha, j15] - mp[mo, j15]) / mp[ha, j15]:.2f},,,,")
    rows.append(f"fig4.headline_mo_vs_lt_latency,15,"
                f"{lat[mo, j15] / lat[lt, j15]:.3f},,,,")
    return rows
