"""Benchmark harness: one module per paper table/figure + kernels +
roofline. Prints CSV: name,<columns...>.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only SUITE]
                                          [--json PATH] [--sharded]
                                          [--workload {markov,trace}]
                                          [--dispatch {static,online}]

Each suite is documented in ``docs/benchmarks.md``.

Running benchmarks / CI
-----------------------
``--fast`` shrinks seeds/requests to CI size. ``--sharded`` is the
multi-device fast path: it routes every sweep suite (fig4/fig5/ablation)
through ``sweep_grid(..., mesh=make_sweep_mesh())``, sharding the config
axis across all local devices — results are bit-identical to the default
path, only faster on >1 device. ``--workload trace`` swaps the sweep
suites' scene-complexity source from the synthetic Markov chain to the
bundled recorded trace (``repro.data.traces.bundled_trace``) — same
grids, real video statistics; the dedicated ``workload_trace`` suite
times the trace path against the Markov default either way.
``--dispatch online`` swaps the sweep suites' dispatch-state engine from
static offline tables to the online-EWMA adaptive engine
(``repro.core.dispatch.OnlineDispatch``); the dedicated ``online_drift``
suite compares the two under a mid-run profile drift either way.
``--json PATH`` additionally writes a
``BENCH_*.json``-style artifact: per-suite CSV rows plus wall-clock
seconds (``suites.<name>.seconds``) and environment metadata — the format
``scripts/check_bench.py`` validates and diffs against the committed
baseline (``benchmarks/bench_baseline.json``), failing on >20% slowdown
per suite and warning (``--strict``: failing) when a suite has no baseline
entry. The GitHub workflow (``.github/workflows/ci.yml``) runs three jobs:
ruff lint + docs link check, the tier-1 pytest suite, and this runner in
``--fast --json`` mode, uploading the JSON as a build artifact so every
commit leaves a benchmark trajectory point:

  PYTHONPATH=src python -m benchmarks.run --fast --json bench.json
  python scripts/check_bench.py bench.json benchmarks/bench_baseline.json

The sweep suites (fig4/fig5/ablation/scale/sweep_sharded) run on the
batched engine (``repro.core.simulator.sweep_grid``): each grid is ONE
jitted vmap(simulate + summarize) device program, so a full Fig. 4 sweep
costs one compile + one launch instead of ~150. ``sweep_sharded`` reports
the engine's configs/sec single-device vs sharded, and the
memoized/vectorised ``make_grid`` build rate — the headline throughput
numbers the regression gate tracks. See ``docs/sweep_engine.md``.
"""

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer seeds/requests (CI mode)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write a JSON artifact (per-suite rows + "
                         "wall-clock) for CI / scripts/check_bench.py")
    ap.add_argument("--sharded", action="store_true",
                    help="run the sweep suites sharded across all local "
                         "devices (sweep_grid mesh= fast path; "
                         "bit-identical results)")
    ap.add_argument("--workload", choices=("markov", "trace"),
                    default="markov",
                    help="scene-complexity source for the sweep suites: "
                         "the synthetic Markov chain (default) or the "
                         "bundled recorded trace")
    ap.add_argument("--dispatch", choices=("static", "online"),
                    default="static",
                    help="dispatch-state engine for the sweep suites: "
                         "static offline tables (default) or the "
                         "online-EWMA adaptive engine")
    args = ap.parse_args()

    from benchmarks import (ablation_delta, bench_kernels, bench_scale,
                            fig2_motivation, fig4_baselines, fig5_gamma,
                            online_drift, roofline_summary, sweep_sharded,
                            table1_pairs, workload_trace)

    mesh = None
    if args.sharded:
        from repro.launch.mesh import make_sweep_mesh
        mesh = make_sweep_mesh()
    workload = None
    if args.workload == "trace":
        from repro.data.traces import bundled_trace
        workload = bundled_trace()
    dispatch = None
    if args.dispatch == "online":
        from repro.core.dispatch import OnlineDispatch
        dispatch = OnlineDispatch()

    suites = {
        "fig2": lambda: fig2_motivation.run(),
        "table1": lambda: table1_pairs.run(),
        "fig4": lambda: fig4_baselines.run(
            n_requests=600 if args.fast else 1500,
            seeds=(0,) if args.fast else (0, 1, 2), mesh=mesh,
            workload=workload, dispatch=dispatch),
        "fig5": lambda: fig5_gamma.run(
            n_requests=600 if args.fast else 1500,
            seeds=(0,) if args.fast else (0, 1), mesh=mesh,
            workload=workload, dispatch=dispatch),
        "ablation": lambda: ablation_delta.run(mesh=mesh,
                                               workload=workload,
                                               dispatch=dispatch),
        "scale": lambda: bench_scale.run(),
        "sweep_sharded": lambda: sweep_sharded.run(),
        "workload_trace": lambda: workload_trace.run(
            n_requests=250 if args.fast else 400),
        "online_drift": lambda: online_drift.run(
            n_requests=800 if args.fast else 2000,
            seeds=(0,) if args.fast else (0, 1)),
        "kernels": lambda: bench_kernels.run(),
        "roofline": lambda: roofline_summary.run(),
    }
    if args.only:
        if args.only not in suites:
            sys.exit(f"benchmarks.run: unknown suite {args.only!r} "
                     f"(choose from: {', '.join(suites)})")
        suites = {args.only: suites[args.only]}

    report: dict[str, dict] = {}
    for name, fn in suites.items():
        t0 = time.time()
        err = None
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            err = f"{type(e).__name__}: {e}"
            rows = []
            print(f"{name},ERROR,{err}", flush=True)
        seconds = time.time() - t0
        for row in rows:
            print(row, flush=True)
        print(f"bench.{name}.seconds,{seconds:.1f}", flush=True)
        report[name] = {"rows": rows, "seconds": round(seconds, 3),
                        "error": err}

    if args.json:
        import jax

        artifact = {
            "schema": "repro-bench/v1",
            "fast": bool(args.fast),
            "workload": args.workload,
            "dispatch": args.dispatch,
            "created_unix": round(time.time(), 1),
            "jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "suites": report,
        }
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"bench.artifact,{args.json}", flush=True)

    # a crashed suite fails the run (CI's bench job is only allow-failure
    # on the *timing* gate, not on the benchmarks themselves)
    errored = [k for k, v in report.items() if v["error"]]
    if errored:
        print(f"bench.errored,{';'.join(errored)}", flush=True)
        sys.exit(1)


if __name__ == "__main__":
    main()
