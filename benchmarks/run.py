"""Benchmark harness: one module per paper table/figure + kernels +
roofline. Prints CSV: name,<columns...>.

  PYTHONPATH=src python -m benchmarks.run [--fast]
"""

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer seeds/requests (CI mode)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (ablation_delta, bench_kernels, bench_scale,
                            fig2_motivation, fig4_baselines, fig5_gamma,
                            roofline_summary, table1_pairs)

    suites = {
        "fig2": lambda: fig2_motivation.run(),
        "table1": lambda: table1_pairs.run(),
        "fig4": lambda: fig4_baselines.run(
            n_requests=600 if args.fast else 1500,
            seeds=(0,) if args.fast else (0, 1, 2)),
        "fig5": lambda: fig5_gamma.run(
            n_requests=600 if args.fast else 1500,
            seeds=(0,) if args.fast else (0, 1)),
        "ablation": lambda: ablation_delta.run(),
        "scale": lambda: bench_scale.run(),
        "kernels": lambda: bench_kernels.run(),
        "roofline": lambda: roofline_summary.run(),
    }
    if args.only:
        suites = {k: v for k, v in suites.items() if k == args.only}

    for name, fn in suites.items():
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
            continue
        for row in rows:
            print(row, flush=True)
        print(f"bench.{name}.seconds,{time.time() - t0:.1f}", flush=True)


if __name__ == "__main__":
    main()
