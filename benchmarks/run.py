"""Benchmark harness: one module per paper table/figure + kernels +
roofline. Prints CSV: name,<columns...>.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only SUITE]
                                          [--json PATH] [--sharded]
                                          [--workload {markov,trace}]
                                          [--dispatch {static,online}]
                                          [--scenario SPEC.json]

Each suite is documented in ``docs/benchmarks.md``.

Scenarios
---------
The sweep suites run against ONE base
:class:`repro.core.scenario.Scenario` assembled from the flags:
``--workload trace`` swaps the scene-complexity source to the bundled
recorded trace, ``--dispatch online`` swaps static offline tables for
the online-EWMA adaptive engine, ``--sharded`` sets the scenario's mesh
spec to ``"local"`` (shard the config axis across all local devices —
bit-identical results, only faster on >1 device). ``--scenario PATH``
loads a full ``Scenario.to_json`` spec instead (the other three flags
then layer on top only when explicitly given). Each suite overrides the
per-suite knobs (``n_requests``, sweep axes) via ``dataclasses.replace``
— the scenario is the single config object the whole harness shares.

Running benchmarks / CI
-----------------------
``--fast`` shrinks seeds/requests to CI size. ``--json PATH``
additionally writes a ``BENCH_*.json``-style artifact: per-suite CSV
rows plus wall-clock seconds (``suites.<name>.seconds``), environment
metadata, and the base scenario (``scenario`` spec + ``scenario_hash``)
— the format ``scripts/check_bench.py`` validates and diffs against the
committed baseline (``benchmarks/bench_baseline.json``), failing on >20%
slowdown per suite (per-suite ``--threshold`` overrides supported) and
refusing to compare artifacts whose scenario hashes differ. The GitHub
workflow (``.github/workflows/ci.yml``) runs three jobs: ruff lint +
docs link check, the tier-1 pytest suite, and this runner in ``--fast
--json`` mode, uploading the JSON as a build artifact so every commit
leaves a benchmark trajectory point:

  PYTHONPATH=src python -m benchmarks.run --fast --json bench.json
  python scripts/check_bench.py bench.json benchmarks/bench_baseline.json

The sweep suites (fig4/fig5/ablation/scale/sweep_sharded) run on the
scenario engine (``repro.core.scenario.run``): each grid is ONE jitted
vmap(simulate + summarize) device program, so a full Fig. 4 sweep costs
one compile + one launch instead of ~150. ``sweep_sharded`` reports the
engine's configs/sec single-device vs sharded, and the memoized/
vectorised grid-build rate — the headline throughput numbers the
regression gate tracks. See ``docs/sweep_engine.md``.
"""

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer seeds/requests (CI mode)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write a JSON artifact (per-suite rows + "
                         "wall-clock + scenario hash) for CI / "
                         "scripts/check_bench.py")
    ap.add_argument("--sharded", action="store_true",
                    help="run the sweep suites sharded across all local "
                         "devices (Scenario mesh='local'; bit-identical "
                         "results)")
    ap.add_argument("--workload", choices=("markov", "trace"),
                    default=None,
                    help="scene-complexity source for the sweep suites: "
                         "the synthetic Markov chain (default) or the "
                         "bundled recorded trace")
    ap.add_argument("--dispatch", choices=("static", "online"),
                    default=None,
                    help="dispatch-state engine for the sweep suites: "
                         "static offline tables (default) or the "
                         "online-EWMA adaptive engine")
    ap.add_argument("--scenario", default=None, metavar="SPEC.json",
                    help="load the base scenario from a Scenario.to_json "
                         "spec file instead of assembling it from flags")
    args = ap.parse_args()

    from dataclasses import replace

    from repro.core.scenario import Scenario

    if args.scenario:
        with open(args.scenario) as f:
            base = Scenario.from_json(json.load(f))
    else:
        base = Scenario()
    if args.workload == "trace":
        from repro.data.traces import bundled_trace
        base = replace(base, workload=bundled_trace())
    elif args.workload == "markov":
        base = replace(base, workload=None)
    if args.dispatch == "online":
        from repro.core.dispatch import OnlineDispatch
        base = replace(base, dispatch=OnlineDispatch())
    elif args.dispatch == "static":
        base = replace(base, dispatch=None)
    if args.sharded:
        base = replace(base, mesh="local")

    from benchmarks import (ablation_delta, bench_kernels, bench_scale,
                            edge_cloud, fault_resilience, fig2_motivation,
                            fig4_baselines, fig5_gamma, online_drift,
                            roofline_summary, serving_throughput,
                            sweep_sharded, table1_pairs, workload_trace)

    suites = {
        "fig2": lambda: fig2_motivation.run(),
        "table1": lambda: table1_pairs.run(),
        "fig4": lambda: fig4_baselines.run(
            base, n_requests=600 if args.fast else 1500,
            seeds=(0,) if args.fast else (0, 1, 2)),
        "fig5": lambda: fig5_gamma.run(
            base, n_requests=600 if args.fast else 1500,
            seeds=(0,) if args.fast else (0, 1)),
        "ablation": lambda: ablation_delta.run(base),
        "edge_cloud": lambda: edge_cloud.run(
            base, n_requests=400 if args.fast else 1500,
            seeds=(0,) if args.fast else (0, 1, 2)),
        "fault_resilience": lambda: fault_resilience.run(
            base, n_requests=150 if args.fast else 600,
            seeds=(0, 1) if args.fast else (0, 1, 2)),
        "scale": lambda: bench_scale.run(),
        "sweep_sharded": lambda: sweep_sharded.run(),
        "workload_trace": lambda: workload_trace.run(
            n_requests=250 if args.fast else 400),
        "online_drift": lambda: online_drift.run(
            n_requests=800 if args.fast else 2000,
            seeds=(0,) if args.fast else (0, 1)),
        "serving_throughput": lambda: serving_throughput.run(
            base, n_requests=50_000 if args.fast else 200_000,
            window=512 if args.fast else 1024),
        "kernels": lambda: bench_kernels.run(),
        "roofline": lambda: roofline_summary.run(),
    }
    if args.only:
        if args.only not in suites:
            sys.exit(f"benchmarks.run: unknown suite {args.only!r} "
                     f"(choose from: {', '.join(suites)})")
        suites = {args.only: suites[args.only]}

    report: dict[str, dict] = {}
    for name, fn in suites.items():
        t0 = time.time()
        err = None
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            err = f"{type(e).__name__}: {e}"
            rows = []
            print(f"{name},ERROR,{err}", flush=True)
        seconds = time.time() - t0
        for row in rows:
            print(row, flush=True)
        print(f"bench.{name}.seconds,{seconds:.1f}", flush=True)
        report[name] = {"rows": rows, "seconds": round(seconds, 3),
                        "error": err}

    if args.json:
        import jax

        from repro.core.dispatch import OnlineDispatch as _OD
        from repro.core.workload import MarkovWorkload as _MW

        artifact = {
            "schema": "repro-bench/v1",
            "fast": bool(args.fast),
            # mode strings kept for readability / legacy baselines; the
            # scenario spec + hash are the authoritative identity
            "workload": "markov" if base.workload is None
                        or isinstance(base.workload, _MW) else "trace",
            "dispatch": "online" if isinstance(base.dispatch, _OD)
                        else "static",
            "scenario": base.to_json(),
            "scenario_hash": base.hash,
            "created_unix": round(time.time(), 1),
            "jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "suites": report,
        }
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"bench.artifact,{args.json}", flush=True)

    # a crashed suite fails the run (CI's bench job is only allow-failure
    # on the *timing* gate, not on the benchmarks themselves)
    errored = [k for k, v in report.items() if v["error"]]
    if errored:
        print(f"bench.errored,{';'.join(errored)}", flush=True)
        sys.exit(1)


if __name__ == "__main__":
    main()
