"""Table I: best device-model pair per metric / group."""

import numpy as np

from repro.core.profiles import paper_fleet


def run() -> list[str]:
    prof = paper_fleet()
    rows = ["table1.metric,winner"]
    best_e = int(np.argmin(np.asarray(prof.E).mean(1)))
    best_t = int(np.argmin(np.asarray(prof.T).mean(1)))
    rows.append(f"table1.best_energy,{prof.names[best_e]}")
    rows.append(f"table1.best_time,{prof.names[best_t]}")
    for g in range(prof.n_groups):
        w = int(np.argmax(np.asarray(prof.mAP)[:, g]))
        rows.append(f"table1.best_map_group{g + 1},{prof.names[w]}")
    return rows
