"""Kernel micro-benchmarks (interpret mode on CPU: correctness-grade
timings; real-TPU numbers come from the same harness with interpret=False)."""

import time

import jax
import jax.numpy as jnp

from repro.core.policies import mo_select_batch
from repro.core.profiles import ProfileTable, paper_fleet, synthetic_fleet
from repro.kernels.decode_attention import (decode_attention,
                                            ref_decode_attention)
from repro.kernels.flash_attention import flash_attention, ref_attention
from repro.kernels.moscore import moscore_route


def _time(fn, *args, n=5):
    # block the warmup result: the compile call is async-dispatched, and
    # un-drained warmup work would leak into the timed region below,
    # polluting every us_per_call row
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run() -> list[str]:
    rows = ["kernel.name,us_per_call,vs_ref_speedup"]
    rng = jax.random.PRNGKey(0)

    q = jax.random.normal(rng, (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(rng, (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(rng, (1, 256, 2, 64), jnp.float32)
    t_k = _time(lambda *a: flash_attention(*a, block_q=64, block_k=128), q, k, v)
    t_r = _time(jax.jit(lambda *a: ref_attention(*a)), q, k, v)
    rows.append(f"kernel.flash_attention_256,{t_k:.0f},{t_r / t_k:.2f}")

    qd = jax.random.normal(rng, (2, 8, 64), jnp.float32)
    kd = jax.random.normal(rng, (2, 1024, 2, 64), jnp.float32)
    vd = jax.random.normal(rng, (2, 1024, 2, 64), jnp.float32)
    t_k = _time(lambda *a: decode_attention(*a, n_splits=4), qd, kd, vd)
    t_r = _time(jax.jit(ref_decode_attention), qd, kd, vd)
    rows.append(f"kernel.decode_attention_1k,{t_k:.0f},{t_r / t_k:.2f}")

    # moscore: every backend vs the unhoisted XLA reference scan, on the
    # paper fleet (P=5 — scan-overhead bound) and a 200-pair synthetic
    # fleet (reduction bound, where hoisting pays most)
    def _moscore_rows(prof, tag):
        gs = jax.random.randint(rng, (256,), 0, prof.n_groups)
        q0 = jnp.zeros((prof.n_pairs,))
        ref = jax.jit(lambda T, E, M, g, q: mo_select_batch(
            ProfileTable(T, E, M), g, q, delta=20.0, gamma=0.5))
        t_r = _time(ref, prof.T, prof.E, prof.mAP, gs, q0)
        out = []
        for backend in ("pallas", "hoisted", "pallas_hoisted", "int8"):
            t_k = _time(lambda *a, b=backend: moscore_route(
                *a, delta=20.0, gamma=0.5, backend=b),
                prof.T, prof.E, prof.mAP, gs, q0)
            name = "" if backend == "pallas" else f"_{backend}"
            out.append(f"kernel.moscore{name}_{tag}window256,"
                       f"{t_k:.0f},{t_r / t_k:.2f}")
            if backend == "hoisted" and tag == "":
                out.append(f"kernel.moscore_us_per_decision,"
                           f"{t_k / 256:.2f},")
        return out

    rows += _moscore_rows(paper_fleet(), "")
    rows += _moscore_rows(synthetic_fleet(jax.random.PRNGKey(7), 200),
                          "p200_")
    return rows
