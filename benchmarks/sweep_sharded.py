"""Sharded-sweep throughput: the headline configs/sec of the batched
engine, single-device vs sharded across the local mesh (the scenario's
``mesh="local"`` spec), plus the memoized/vectorised grid-build rate. On
a 1-device host both paths still run — the sharded row then measures the
``shard_map`` overhead itself, which is what the CI regression gate
watches; on a real mesh the sharded row scales with the device count
(the grid is embarrassingly parallel)."""

import time

import jax

from repro.core import scenario as SC
from repro.core.profiles import paper_fleet
from repro.core.scenario import Scenario, Sweep
# the grid-build benchmark times the engine internal directly (the
# public path is Scenario/Sweep; _make_grid is the engine layer underneath)
from repro.core.simulator import SimConfig, _make_grid, grid_cache_clear

POLICIES = ("MO", "RR", "RND", "LC", "LE", "LT", "HA")


def _configs_per_sec(fn, n_configs: int) -> tuple[float, float]:
    fn()                                   # compile + cache the trace
    t0 = time.perf_counter()
    fn()
    dt = time.perf_counter() - t0
    return dt, n_configs / dt


def run(n_requests: int = 400) -> list[str]:
    sc = Scenario(n_requests=n_requests)
    sw = Sweep(policy=POLICIES, n_users=(5, 10, 15), seed=(0, 1, 2))
    n_cfg = len(POLICIES) * 3 * 3
    rows = ["sweep_sharded.path,devices,configs,warm_s,configs_per_sec"]

    t, cps = _configs_per_sec(lambda: SC.run(sc, sw), n_cfg)
    rows.append(f"sweep_sharded.single,1,{n_cfg},{t:.3f},{cps:.1f}")

    sharded = Scenario(n_requests=n_requests, mesh="local")
    n_dev = len(jax.devices())
    t, cps = _configs_per_sec(lambda: SC.run(sharded, sw), n_cfg)
    rows.append(f"sweep_sharded.sharded,{n_dev},{n_cfg},{t:.3f},{cps:.1f}")

    # grid-build rate: 10^4 configs sharing 9 distinct initial draws
    # (3 user levels x 3 seeds; gamma is not part of the draw key);
    # cold = miss-and-batch-draw, warm = pure cache hits
    prof = paper_fleet()
    cfgs = [SimConfig(n_users=u, n_requests=n_requests, policy="MO",
                      gamma=g / 60.0, seed=s)
            for u in (5, 10, 15) for s in (0, 1, 2) for g in range(60)]
    cfgs = cfgs * 19                       # 10_260 configs, 9 distinct draws
    grid_cache_clear()
    t0 = time.perf_counter()
    _make_grid(prof, cfgs)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    _make_grid(prof, cfgs)
    t_warm = time.perf_counter() - t0
    rows.append(f"sweep_sharded.grid_build_cold,1,{len(cfgs)},{t_cold:.3f},"
                f"{len(cfgs) / t_cold:.0f}")
    rows.append(f"sweep_sharded.grid_build_warm,1,{len(cfgs)},{t_warm:.3f},"
                f"{len(cfgs) / t_warm:.0f}")
    return rows
