"""Edge-to-cloud offloading: does the paper's Fig. 4 MO-dominance survive
when a cloud tier joins the fleet, and at what RTT does offloading stop
paying?

One fused scenario-engine run sweeps policy × cloud × seed, where the
cloud axis is ``[None] + [CloudTier(rtt_ms=r) for r in RTTS]`` — the
``None`` entry is the paper's pure-edge fleet, the baseline every tier
is judged against. Reported per RTT: the mean metrics + offload share
for MO/LT/HA, the MO-vs-HA dominance verdict (lower latency AND lower
energy, the Fig. 4 headline restated with a cloud option on the table),
and the break-even RTT — the largest swept RTT at which the cloud still
improves MO's mean latency over the pure-edge baseline."""

from dataclasses import replace

from repro.core import scenario as SC
from repro.core.cloud import CloudTier
from repro.core.scenario import Scenario, Sweep

RTTS = [0.0, 20.0, 40.0, 80.0, 160.0, 320.0, 640.0]
POLICIES = ["MO", "LT", "HA"]
METRICS = ["latency_ms", "latency_p90_ms", "energy_mwh", "map",
           "offload_share"]


def run(scenario: Scenario | None = None, n_requests: int = 1500,
        n_users: int = 11, seeds=(0, 1, 2), rtts=RTTS) -> list[str]:
    scenario = scenario if scenario is not None else Scenario()
    tiers = [None] + [CloudTier(rtt_ms=r) for r in rtts]
    res = SC.run(replace(scenario, n_requests=n_requests,
                         n_users=n_users, cloud=None),
                 Sweep(policy=POLICIES, cloud=tiers, seed=seeds))
    mean = {m: res.mean(m, over="seed") for m in res.metric_names}
    labels = ["local"] + [f"{r:g}" for r in rtts]

    rows = ["edge_cloud.policy,rtt_ms," + ",".join(METRICS)]
    for i, pol in enumerate(POLICIES):
        for j, lab in enumerate(labels):
            vals = ",".join(f"{mean[m][i, j]:.3f}" for m in METRICS)
            rows.append(f"edge_cloud.{pol},{lab},{vals}")

    # Fig. 4 dominance verdict with a cloud on the table: MO dominates HA
    # when it is at-or-below HA on BOTH mean latency and energy.
    mo, ha = POLICIES.index("MO"), POLICIES.index("HA")
    lat, en = mean["latency_ms"], mean["energy_mwh"]
    for j, lab in enumerate(labels):
        dom = int(lat[mo, j] <= lat[ha, j] and en[mo, j] <= en[ha, j])
        rows.append(f"edge_cloud.mo_dominates_ha,{lab},{dom},"
                    f"{lat[mo, j] / lat[ha, j]:.3f},"
                    f"{en[mo, j] / en[ha, j]:.3f},,")

    # break-even: largest swept RTT where the cloud still beats pure edge
    # on MO mean latency (-1 = never pays at any swept RTT)
    paying = [r for j, r in enumerate(rtts)
              if lat[mo, j + 1] < lat[mo, 0]]
    break_even = max(paying) if paying else -1.0
    rows.append(f"edge_cloud.break_even_rtt_ms,{break_even:g},,,,,")
    share = mean["offload_share"]
    rows.append(f"edge_cloud.offload_share_rtt0,{share[mo, 1]:.3f},,,,,")
    return rows
