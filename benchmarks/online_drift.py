"""Online vs static dispatch under profile drift (paper §VII, implemented
via ``repro.core.dispatch``).

Scenario: mid-run the fleet's energy-favourite pair (n5, orin/ssd_v1)
loses its low-power state — true service time 3x, true energy 8x the
offline profile (``DriftSchedule.throttle``). Static-MO keeps routing on
the stale offline table; online-MO (annealed-EWMA belief tables,
``OnlineDispatch``) re-converges from observations and reroutes. The
suite reports mean latency / energy for {static, online} x {no drift,
drift}: under drift online should win BOTH metrics (the acceptance
criterion ``tests/test_dispatch.py`` asserts); with no drift the two
match (with an oracle estimator every observation equals the prior, so
the belief tables never move). The whole 2 × 2 × seeds cube is ONE
scenario sweep — ``dispatch`` and ``drift`` are named axes like any
other (``Sweep(dispatch=..., drift=..., seed=...)``), each cell a fused
device program."""

from dataclasses import replace

from repro.core import scenario as SC
from repro.core.dispatch import DriftSchedule, OnlineDispatch
from repro.core.scenario import Scenario, Sweep

DRIFT_PAIR = 4          # n5 orin/ssd_v1 — the fleet's energy favourite
T_MULT, E_MULT = 3.0, 8.0


def run(scenario: Scenario | None = None, n_requests: int = 2000,
        seeds=(0, 1)) -> list[str]:
    scenario = scenario if scenario is not None else Scenario()
    prof = scenario.resolve_profile()
    drift = DriftSchedule.throttle(prof, DRIFT_PAIR,
                                   at_step=n_requests // 5,
                                   t_mult=T_MULT, e_mult=E_MULT)
    base = replace(scenario, policy="MO", n_users=10,
                   n_requests=n_requests, oracle_estimator=True,
                   workload=None, dispatch=None, drift=None)
    res = SC.run(base, Sweep(dispatch=(None, OnlineDispatch()),
                             drift=(None, drift), seed=tuple(seeds)))
    cells = {}
    for dname, disp in (("static", None), ("online", OnlineDispatch())):
        for sname, sched in (("nodrift", None), ("drift", drift)):
            cells[dname, sname] = {
                m: float(res.sel(m, dispatch=disp, drift=sched).mean())
                for m in res.metric_names}

    rows = ["online_drift.cell,latency_ms,energy_mwh,map"]
    for (dname, sname), c in cells.items():
        rows.append(f"online_drift.{dname}_{sname},"
                    f"{c['latency_ms']:.1f},{c['energy_mwh']:.4f},"
                    f"{c['map']:.2f}")
    # headline ratios: the price of stale tables, and the online recovery
    for metric in ("latency_ms", "energy_mwh"):
        stale = cells["static", "drift"][metric] \
            / cells["static", "nodrift"][metric]
        rec = cells["online", "drift"][metric] \
            / cells["static", "drift"][metric]
        rows.append(f"online_drift.{metric}_stale_cost,{stale:.3f},,")
        rows.append(f"online_drift.{metric}_online_vs_static,{rec:.3f},,")
    return rows
