"""Ablations beyond the paper's figures:

  (a) the accuracy-tolerance knob Δ_mAP (the parameter the paper leaves to
      the operator): sweeps the full latency/energy/accuracy frontier;
  (b) the output-based estimator vs an oracle (g_est == g_true): quantifies
      how much accuracy the paper's zero-cost estimator gives up.
"""

from dataclasses import replace

from repro.core.profiles import paper_fleet
from repro.core.simulator import SimConfig, simulate, summarize


def _run(prof, **kw):
    cfg = SimConfig(n_users=15, n_requests=1500, policy="MO", **kw)
    recs = simulate(prof, cfg)
    return {k: float(v) for k, v in summarize(recs, prof, cfg).items()}


def run() -> list[str]:
    prof = paper_fleet()
    rows = ["ablation.delta,latency_ms,energy_mwh,map,estimator_acc"]
    for delta in (0.0, 5.0, 10.0, 20.0, 30.0, 45.0):
        r = _run(prof, delta=delta)
        rows.append(f"ablation.delta_{int(delta)},{r['latency_ms']:.0f},"
                    f"{r['energy_mwh']:.4f},{r['map']:.1f},"
                    f"{r['estimator_acc']:.3f}")
    # estimator ablation at the headline operating point
    for name, oracle in (("output_based", False), ("oracle", True)):
        r = _run(prof, delta=20.0, oracle_estimator=oracle)
        rows.append(f"ablation.estimator_{name},{r['latency_ms']:.0f},"
                    f"{r['energy_mwh']:.4f},{r['map']:.1f},"
                    f"{r['estimator_acc']:.3f}")
    return rows
