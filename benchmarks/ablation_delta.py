"""Ablations beyond the paper's figures:

  (a) the accuracy-tolerance knob Δ_mAP (the parameter the paper leaves to
      the operator): sweeps the full latency/energy/accuracy frontier;
  (b) the output-based estimator vs an oracle (g_est == g_true): quantifies
      how much accuracy the paper's zero-cost estimator gives up.

Both ablations share ONE batched device program: the Δ × {output-based,
oracle} grid is a single ``sweep_grid`` call instead of eight separate
simulator runs."""

from repro.core.profiles import paper_fleet
from repro.core.simulator import sweep_grid

DELTAS = (0.0, 5.0, 10.0, 20.0, 30.0, 45.0)


def run(mesh=None, workload=None, dispatch=None) -> list[str]:
    prof = paper_fleet()
    grid = sweep_grid(prof, policies=("MO",), user_levels=(15,),
                      deltas=DELTAS, oracle=(False, True), seeds=(0,),
                      n_requests=1500, mesh=mesh, workload=workload,
                      dispatch=dispatch)

    def at(metric, di, oi):
        # (policy, users, gamma, delta, oracle, seed)
        return float(grid[metric][0, 0, 0, di, oi, 0])

    rows = ["ablation.delta,latency_ms,energy_mwh,map,estimator_acc"]
    for di, delta in enumerate(DELTAS):
        rows.append(f"ablation.delta_{int(delta)},"
                    f"{at('latency_ms', di, 0):.0f},"
                    f"{at('energy_mwh', di, 0):.4f},"
                    f"{at('map', di, 0):.1f},"
                    f"{at('estimator_acc', di, 0):.3f}")
    # estimator ablation at the headline operating point (delta = 20)
    d20 = DELTAS.index(20.0)
    for name, oi in (("output_based", 0), ("oracle", 1)):
        rows.append(f"ablation.estimator_{name},"
                    f"{at('latency_ms', d20, oi):.0f},"
                    f"{at('energy_mwh', d20, oi):.4f},"
                    f"{at('map', d20, oi):.1f},"
                    f"{at('estimator_acc', d20, oi):.3f}")
    return rows
