"""Ablations beyond the paper's figures:

  (a) the accuracy-tolerance knob Δ_mAP (the parameter the paper leaves to
      the operator): sweeps the full latency/energy/accuracy frontier;
  (b) the output-based estimator vs an oracle (g_est == g_true): quantifies
      how much accuracy the paper's zero-cost estimator gives up.

Both ablations share ONE batched device program: the Δ ×
{output-based, oracle} grid is a single scenario sweep
(``Sweep(delta=..., oracle_estimator=...)``) instead of eight separate
simulator runs."""

from dataclasses import replace

from repro.core import scenario as SC
from repro.core.scenario import Scenario, Sweep

DELTAS = (0.0, 5.0, 10.0, 20.0, 30.0, 45.0)


def run(scenario: Scenario | None = None) -> list[str]:
    scenario = scenario if scenario is not None else Scenario()
    res = SC.run(replace(scenario, policy="MO", n_users=15,
                         n_requests=1500, seed=0),
                 Sweep(delta=DELTAS, oracle_estimator=(False, True)))

    def at(metric, delta, oracle):
        return float(res.sel(metric, delta=delta,
                             oracle_estimator=oracle))

    rows = ["ablation.delta,latency_ms,energy_mwh,map,estimator_acc"]
    for delta in DELTAS:
        rows.append(f"ablation.delta_{int(delta)},"
                    f"{at('latency_ms', delta, False):.0f},"
                    f"{at('energy_mwh', delta, False):.4f},"
                    f"{at('map', delta, False):.1f},"
                    f"{at('estimator_acc', delta, False):.3f}")
    # estimator ablation at the headline operating point (delta = 20)
    for name, orc in (("output_based", False), ("oracle", True)):
        rows.append(f"ablation.estimator_{name},"
                    f"{at('latency_ms', 20.0, orc):.0f},"
                    f"{at('energy_mwh', 20.0, orc):.4f},"
                    f"{at('map', 20.0, orc):.1f},"
                    f"{at('estimator_acc', 20.0, orc):.3f}")
    return rows
