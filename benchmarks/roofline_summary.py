"""Summarise the dry-run roofline records (experiments/dryrun/*.json) into
the 40-cell table reported in EXPERIMENTS.md §Roofline."""

import glob
import json
import os


def run(dryrun_dir: str = "experiments/dryrun") -> list[str]:
    rows = ["roofline.arch,shape,mesh,t_compute_s,t_memory_s,"
            "t_collective_s,dominant,useful_flops_frac,hbm_frac,ok"]
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        r = json.load(open(path))
        if not r.get("ok"):
            rows.append(f"roofline.{r['arch']},{r['shape']},{r['mesh']}"
                        f",,,,FAILED,,,False")
            continue
        rows.append(
            f"roofline.{r['arch']},{r['shape']},{r['mesh']},"
            f"{r['t_compute_s']:.3e},{r['t_memory_s']:.3e},"
            f"{r['t_collective_s']:.3e},{r['dominant']},"
            f"{r['useful_flops_frac']:.3f},"
            f"{r.get('hbm_frac_analytic', 0):.3f},True")
    return rows
