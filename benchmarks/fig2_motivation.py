"""Fig. 2: accuracy / energy / inference-time trade-offs across models for
simple vs complex scenes (the motivation experiment)."""


from repro.core.profiles import paper_fleet


def run() -> list[str]:
    prof = paper_fleet()
    rows = ["fig2.pair,group,mAP,energy_mwh,time_ms"]
    for p in range(prof.n_pairs):
        for g in (1, 4):  # single-object vs 4+ objects
            rows.append(f"fig2.{prof.names[p]},{g},"
                        f"{float(prof.mAP[p, g]):.1f},"
                        f"{float(prof.E[p, g]):.3f},"
                        f"{float(prof.T[p, g]):.1f}")
    # headline: the paper's SSD-Lite vs YOLOv8s comparison
    ssd, yolo = 1, 3
    rows.append(f"fig2.map_ratio_complex,4,"
                f"{float(prof.mAP[yolo, 4] / prof.mAP[ssd, 4]):.2f},,")
    rows.append(f"fig2.energy_ratio,4,,"
                f"{float(prof.E[ssd, 4] / prof.E[yolo, 4]):.2f},")
    return rows
