"""Trace-driven workload suite: the cost of playing recorded object-count
traces through the sweep engine vs the synthetic Markov default.

Both paths run the same policy × users × seed grid as one fused device
program; the trace path swaps the in-scan Markov categorical draw for a
gather from the device-resident trace (``repro.data.traces
.TraceWorkload``), so the warm configs/sec ratio is the engine-level
price of real-data workloads (expected ~parity — a gather is cheaper
than a categorical). The two runs differ ONLY in the scenario's
``workload`` field. Also reports the bundled trace's shape and
busy-crossing statistics, and the trace grid-build rate (phase-offset
draws instead of stationary-distribution draws).
"""

import time

import numpy as np

from repro.core import scenario as SC
from repro.core.profiles import paper_fleet
from repro.core.scenario import Scenario, Sweep
from repro.core.simulator import SimConfig, _make_grid
from repro.data.traces import bundled_trace

POLICIES = ("MO", "RR", "LC", "LT", "HA")


def _warm_seconds(fn) -> float:
    fn()                                   # compile + cache the trace
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(n_requests: int = 400) -> list[str]:
    tw = bundled_trace()
    c = np.asarray(tw.counts)
    rows = ["workload_trace.metric,value,extra"]
    rows.append(f"workload_trace.trace_shape,{tw.n_streams},{tw.length}")
    rows.append(f"workload_trace.trace_busy_frac,"
                f"{float((c >= 3).mean()):.3f},")

    sw = Sweep(policy=POLICIES, n_users=(5, 10, 15), seed=(0, 1, 2))
    n_cfg = len(POLICIES) * 3 * 3
    t_markov = _warm_seconds(
        lambda: SC.run(Scenario(n_requests=n_requests), sw))
    t_trace = _warm_seconds(
        lambda: SC.run(Scenario(n_requests=n_requests, workload=tw), sw))
    rows.append(f"workload_trace.markov_warm_s,{t_markov:.3f},"
                f"{n_cfg / t_markov:.1f}")
    rows.append(f"workload_trace.trace_warm_s,{t_trace:.3f},"
                f"{n_cfg / t_trace:.1f}")
    rows.append(f"workload_trace.trace_vs_markov,"
                f"{t_trace / t_markov:.2f},")

    prof = paper_fleet()
    cfgs = [SimConfig(n_users=u, n_requests=n_requests, policy="MO", seed=s)
            for u in (5, 10, 15) for s in range(32)]
    t0 = time.perf_counter()
    _make_grid(prof, cfgs, workload=tw)
    dt = time.perf_counter() - t0
    rows.append(f"workload_trace.grid_build_s,{dt:.3f},"
                f"{len(cfgs) / dt:.0f}")
    return rows
