"""Tests for the two-level hierarchical balancer (core/hierarchy.py).

Pins the three load-bearing properties promised in the module docstring:
pod-aggregate correctness vs a NumPy reference, a staleness-regret bound
for the level-1 pod choice, and permutation invariance of the selection
within a pod."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.hierarchy import hierarchical_select, pod_aggregate
from repro.core.policies import mo_scores, mo_select
from repro.core.profiles import ProfileTable, paper_fleet


def _random_case(rng, P, G, n_pods):
    T = rng.uniform(10, 500, (P, G))
    E = rng.uniform(0.01, 0.5, (P, G))
    mAP = rng.uniform(1, 99, (P, G))
    # every pod non-empty: first n_pods pairs cover each pod once
    pod = np.concatenate([np.arange(n_pods),
                          rng.integers(0, n_pods, P - n_pods)]).astype(np.int32)
    prof = ProfileTable(jnp.asarray(T), jnp.asarray(E), jnp.asarray(mAP))
    return prof, pod


@st.composite
def hierarchy_case(draw):
    n_pods = draw(st.integers(2, 5))
    P = draw(st.integers(n_pods, 24))
    G = draw(st.integers(2, 6))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    prof, pod = _random_case(rng, P, G, n_pods)
    g = draw(st.integers(0, G - 1))
    q = jnp.asarray(rng.integers(0, 10, P).astype(np.float32))
    delta = draw(st.floats(0.0, 60.0))
    gamma = draw(st.floats(0.0, 1.0))
    return prof, pod, g, q, delta, gamma, rng


# ------------------------------------------------------- pod aggregation --

def test_pod_aggregate_matches_numpy_reference():
    rng = np.random.default_rng(0)
    prof, pod = _random_case(rng, P=13, G=5, n_pods=4)
    agg = pod_aggregate(prof, jnp.asarray(pod))
    T, E, mAP = (np.asarray(x) for x in (prof.T, prof.E, prof.mAP))
    for k in range(4):
        m = pod == k
        np.testing.assert_array_equal(np.asarray(agg.T)[k], T[m].min(0))
        np.testing.assert_array_equal(np.asarray(agg.E)[k], E[m].min(0))
        np.testing.assert_array_equal(np.asarray(agg.mAP)[k], mAP[m].max(0))
    assert agg.n_pairs == 4 and agg.names == ("pod0", "pod1", "pod2", "pod3")


def test_pod_aggregate_usable_inside_jit():
    """Regression: n_pods is host-side shape math, so pod_aggregate must
    stay callable from jitted code closing over a concrete pod map."""
    prof = paper_fleet()
    pod = jnp.asarray([0, 0, 1, 1, 2], jnp.int32)

    @jax.jit
    def f(q):
        agg = pod_aggregate(prof, pod)
        J, _ = mo_scores(agg.T[:, 0], agg.E[:, 0], agg.mAP[:, 0],
                         q, delta=20.0, gamma=0.5)
        return jnp.argmin(J)

    q = jax.ops.segment_sum(jnp.arange(5.0), pod, num_segments=3)
    assert 0 <= int(f(q)) < 3


# -------------------------------------------------------- staleness regret

@settings(max_examples=40, deadline=None)
@given(hierarchy_case())
def test_stale_pod_choice_regret_bounded(case):
    """Level 1 picks a pod from *stale* queue totals. The realized pod
    score under the true totals exceeds the true optimum by at most twice
    the score perturbation the staleness induced (standard argmin
    perturbation bound — holds for any staleness magnitude)."""
    prof, pod, g, q, delta, gamma, rng = case
    n_pods = int(pod.max()) + 1
    agg = pod_aggregate(prof, jnp.asarray(pod))
    q_true = jax.ops.segment_sum(q, jnp.asarray(pod), num_segments=n_pods)
    stale = q_true + jnp.asarray(
        rng.integers(-3, 6, n_pods).astype(np.float32))
    stale = jnp.maximum(stale, 0.0)

    def pod_scores(qp):
        J, _ = mo_scores(agg.T[:, g], agg.E[:, g], agg.mAP[:, g], qp,
                         delta=delta, gamma=gamma)
        return np.asarray(J)

    J_stale, J_true = pod_scores(stale), pod_scores(q_true)
    picked = int(np.argmin(J_stale))
    eps = float(np.max(np.abs(J_stale - J_true)))
    regret = float(J_true[picked] - J_true.min())
    assert regret <= 2.0 * eps + 1e-5


def test_zero_staleness_singleton_pods_reduce_to_flat_select():
    """Each pair its own pod + fresh queue totals == flat Algorithm 1."""
    rng = np.random.default_rng(7)
    for g in range(3):
        prof, _ = _random_case(rng, P=9, G=3, n_pods=9)
        pod = jnp.arange(9, dtype=jnp.int32)
        agg = pod_aggregate(prof, pod)
        np.testing.assert_array_equal(np.asarray(agg.T), np.asarray(prof.T))
        q = jnp.asarray(rng.integers(0, 8, 9).astype(np.float32))
        p_h, pod_h = hierarchical_select(prof, agg, pod, g, q, q,
                                         delta=15.0, gamma=0.4)
        p_f, _, _ = mo_select(prof, g, q, delta=15.0, gamma=0.4)
        assert int(p_h) == int(p_f) == int(pod_h)


# -------------------------------------------------- permutation invariance

@settings(max_examples=40, deadline=None)
@given(hierarchy_case())
def test_pod_selection_invariant_to_within_pod_permutation(case):
    """Shuffling pairs *within* pods changes nothing the balancer can
    observe: same pod is chosen, and the chosen pair has identical
    profile columns (mo_scores is built from permutation-equivariant
    reductions, so scores permute bitwise with the rows)."""
    prof, pod, g, q, delta, gamma, rng = case
    P = prof.n_pairs
    perm = np.arange(P)
    for k in range(int(pod.max()) + 1):
        idx = np.flatnonzero(pod == k)
        perm[idx] = rng.permutation(idx)
    prof2 = ProfileTable(prof.T[perm], prof.E[perm], prof.mAP[perm])
    pod2, q2 = jnp.asarray(pod[perm]), q[perm]

    agg1 = pod_aggregate(prof, jnp.asarray(pod))
    agg2 = pod_aggregate(prof2, pod2)
    for a, b in ((agg1.T, agg2.T), (agg1.E, agg2.E), (agg1.mAP, agg2.mAP)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    n_pods = int(pod.max()) + 1
    q_pod = jax.ops.segment_sum(q, jnp.asarray(pod), num_segments=n_pods)
    p1, k1 = hierarchical_select(prof, agg1, jnp.asarray(pod), g, q, q_pod,
                                 delta=delta, gamma=gamma)
    p2, k2 = hierarchical_select(prof2, agg2, pod2, g, q2, q_pod,
                                 delta=delta, gamma=gamma)
    assert int(k1) == int(k2)
    # identity-robust to score ties: compare the chosen pair's columns
    for tbl, tbl2 in ((prof.T, prof2.T), (prof.E, prof2.E),
                      (prof.mAP, prof2.mAP)):
        np.testing.assert_array_equal(np.asarray(tbl)[int(p1)],
                                      np.asarray(tbl2)[int(p2)])
