"""Simulator invariants (property-based where it pays). Engine-facing
tests drive the Scenario API (``repro.core.scenario``); grid-level
plumbing tests exercise the internal ``_make_grid``/``_simulate_batch``
layer directly (the scenario engine's substrate); the deprecated kwarg
shims are pinned in ``test_scenario.py`` and via the marked legacy test
at the bottom."""

import time

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.estimator import markov_transition, stationary
from repro.core.policies import mo_select_batch
from repro.core.profiles import paper_fleet, stack_profiles, synthetic_fleet
from repro.core.scenario import Scenario, Sweep, records, run
from repro.core.simulator import (SimConfig, _init_draws, _make_grid,
                                  _simulate_batch, grid_cache_clear,
                                  grid_cache_info, summarize,
                                  summarize_batch)


def test_littles_law():
    """Closed-loop: concurrency = throughput x mean latency (±10%)."""
    for users in (3, 10):
        s = run(Scenario(n_users=users, n_requests=2500, policy="MO"))
        n_eff = float(s.scalar("throughput_rps")
                      * s.scalar("latency_ms") / 1000.0)
        assert abs(n_eff - users) / users < 0.12, (users, n_eff)


def test_fifo_no_overlap():
    """Per-server: service intervals never overlap (single-server FIFO)."""
    prof = paper_fleet()
    recs = records(Scenario(n_users=8, n_requests=1200, policy="RND",
                            seed=3))
    arr = np.asarray(recs["t_arrival"])
    lat = np.asarray(recs["latency"])
    srv = np.asarray(recs["server"])
    g = np.asarray(recs["g_true"])
    T = np.asarray(prof.T) / 1000.0
    finish = arr + lat
    start = finish - T[srv, g]
    for p in range(prof.n_pairs):
        m = srv == p
        if m.sum() < 2:
            continue
        order = np.argsort(start[m])
        s, f = start[m][order], finish[m][order]
        assert (s[1:] >= f[:-1] - 1e-6).all(), f"overlap on server {p}"


def test_latency_at_least_service_time():
    prof = paper_fleet()
    recs = records(Scenario(n_users=15, n_requests=1500))
    T = np.asarray(prof.T) / 1000.0
    tmin = T[np.asarray(recs["server"]), np.asarray(recs["g_true"])]
    # 1 ms tolerance: sim times are f32, so latency = finish - arrival
    # cancels to ~1e-4 s granularity late in long runs
    assert (np.asarray(recs["latency"]) >= tmin - 1e-3).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 30))
def test_synthetic_fleet_scales(seed, n_pairs):
    prof = synthetic_fleet(jax.random.PRNGKey(seed), n_pairs)
    s = run(Scenario(profile=prof, n_users=6, n_requests=300,
                     policy="MO", seed=seed))
    assert np.isfinite(s.scalar("latency_ms")) \
        and s.scalar("latency_ms") > 0
    assert 0 < s.scalar("map") <= 100


def test_markov_chain_is_stochastic():
    P = np.asarray(markov_transition(5))
    np.testing.assert_allclose(P.sum(1), 1.0, rtol=1e-6)
    assert (P >= 0).all()
    pi = np.asarray(stationary(markov_transition(5)))
    np.testing.assert_allclose(pi.sum(), 1.0, rtol=1e-5)
    assert pi[3] > pi[0]     # busy-crossing skew


def test_simulate_batch_matches_per_config_runs():
    """Batched engine == per-config single runs, bit-for-bit, on a
    heterogeneous 3-config grid (records are bit-identical, so per-row
    `summarize` metrics are too)."""
    prof = paper_fleet()
    cfgs = [SimConfig(n_users=9, n_requests=500, policy="MO", gamma=0.25,
                      seed=0),
            SimConfig(n_users=9, n_requests=500, policy="LT", gamma=0.5,
                      seed=1),
            SimConfig(n_users=9, n_requests=500, policy="RR", gamma=0.75,
                      seed=2)]
    grid = _make_grid(prof, cfgs)
    recs = _simulate_batch(prof, grid, n_requests=500)
    for i, cfg in enumerate(cfgs):
        row = {k: v[i] for k, v in recs.items()}
        got = {k: float(v) for k, v in summarize(row, prof, cfg).items()}
        one = records(Scenario(n_users=9, n_requests=500,
                               policy=cfg.policy, gamma=cfg.gamma,
                               seed=cfg.seed))
        want = {k: float(v)
                for k, v in summarize(one, prof, cfg).items()}
        assert got == want, (cfg.policy, got, want)


def test_simulate_batch_padding_is_exact():
    """Mixed n_users levels share one padded trace; every row still equals
    its own unpadded single run bit-for-bit (masked users never dispatch)."""
    prof = paper_fleet()
    cfgs = [SimConfig(n_users=u, n_requests=400, policy="MO", seed=u)
            for u in (3, 7, 15)]
    grid = _make_grid(prof, cfgs)
    assert grid.n_users_max == 15 and grid.n_configs == 3
    recs = _simulate_batch(prof, grid, n_requests=400)
    for i, (u, cfg) in enumerate(zip((3, 7, 15), cfgs)):
        ref = records(Scenario(n_users=u, n_requests=400, policy="MO",
                               seed=u))
        for k in ref:
            np.testing.assert_array_equal(np.asarray(recs[k][i]),
                                          np.asarray(ref[k]), err_msg=k)


def test_make_grid_memoizes_and_batches_draws():
    """The 168-config Fig. 4 grid (7 policies x 8 user levels x 3 seeds)
    has only 24 distinct (seed, stickiness, n_users) draws: the first
    build computes exactly those (batched), every other lookup — and every
    rebuild — is a cache hit, and the batched draws are bit-identical to
    the scalar per-config path."""
    prof = paper_fleet()
    cfgs = [SimConfig(n_users=u, n_requests=100, policy=p, seed=s)
            for p in ("MO", "RR", "RND", "LC", "LE", "LT", "HA")
            for u in (1, 3, 5, 7, 9, 11, 13, 15) for s in (0, 1, 2)]
    grid_cache_clear()
    grid = _make_grid(prof, cfgs)
    assert grid_cache_info() == {"hits": 144, "misses": 24, "size": 24}
    again = _make_grid(prof, cfgs)
    assert grid_cache_info() == {"hits": 144 + 168, "misses": 24,
                                 "size": 24}
    for f in grid._fields:
        np.testing.assert_array_equal(np.asarray(getattr(grid, f)),
                                      np.asarray(getattr(again, f)))
    for i in (0, 24, 100, 167):          # vs the scalar reference draw
        c = cfgs[i]
        t0, r = _init_draws(c.seed, c.stickiness,
                            n_groups=prof.n_groups, n_users=c.n_users)
        np.testing.assert_array_equal(
            np.asarray(grid.true0[i, :c.n_users]), np.asarray(t0))
        np.testing.assert_array_equal(np.asarray(grid.rng[i]),
                                      np.asarray(r))


def test_make_grid_mixed_stickiness_bitwise():
    """Varying stickiness reaches the vectorised draw path with distinct
    transition matrices; every row must still match its scalar draw."""
    prof = paper_fleet()
    grid_cache_clear()
    cfgs = [SimConfig(n_users=u, n_requests=100, seed=s, stickiness=st)
            for u in (2, 6) for s in (0, 9) for st in (0.5, 0.85, 0.99)]
    grid = _make_grid(prof, cfgs)
    assert grid_cache_info()["misses"] == len(cfgs)
    for i, c in enumerate(cfgs):
        t0, r = _init_draws(c.seed, c.stickiness,
                            n_groups=prof.n_groups, n_users=c.n_users)
        np.testing.assert_array_equal(
            np.asarray(grid.true0[i, :c.n_users]), np.asarray(t0))
        np.testing.assert_array_equal(np.asarray(grid.rng[i]),
                                      np.asarray(r))


def test_fleet_axis_simulate_batch_and_sweep():
    """A stacked ProfileTable adds a leading fleet axis everywhere, and
    each fleet's rows are bit-identical to running that fleet alone."""
    fleets = [synthetic_fleet(jax.random.PRNGKey(i), 5) for i in range(3)]
    ens = stack_profiles(fleets)
    assert ens.is_stacked and ens.n_fleets == 3 and ens.n_pairs == 5
    cfgs = [SimConfig(n_users=4, n_requests=200, policy="MO", seed=0),
            SimConfig(n_users=7, n_requests=200, policy="LT", seed=1)]
    grid = _make_grid(ens, cfgs)
    recs = _simulate_batch(ens, grid, n_requests=200)
    assert recs["latency"].shape == (3, 2, 200)
    ref = _simulate_batch(fleets[2], grid, n_requests=200)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(recs[k][2]),
                                      np.asarray(ref[k]), err_msg=k)
    s = summarize_batch(recs, ens, warmup=20)
    assert s["latency_ms"].shape == (3, 2)
    s_ref = summarize_batch(ref, fleets[2], warmup=20)
    np.testing.assert_array_equal(np.asarray(s["latency_ms"][2]),
                                  np.asarray(s_ref["latency_ms"]))
    m = run(Scenario(profile=ens, n_requests=200),
            Sweep(policy=("MO", "LT"), n_users=(4,), seed=(0,)))
    m_ref = run(Scenario(profile=fleets[0], n_requests=200),
                Sweep(policy=("MO", "LT"), n_users=(4,), seed=(0,)))
    assert m["latency_ms"].shape == (3, 2, 1, 1)
    np.testing.assert_array_equal(m["latency_ms"][0],
                                  m_ref["latency_ms"])


def test_make_grid_100k_at_least_4x_faster_than_looped():
    """Regression gate: a 10^5-config grid builds >=4x faster than the
    looped seed path. The looped cost is the seed `make_grid` body
    verbatim — one `_init_draws` dispatch plus two device->host transfers
    and a row write per config — extrapolated from 2000 real iterations
    so the test stays fast. Both paths run with warm jits.

    The baseline and the batched build are re-measured as a PAIR on every
    attempt: a one-sided measurement (one looped baseline up front, then
    retrying only the batched side) flaked on loaded runners — host load
    during the baseline window deflates t_loop, and no number of batched
    retries can recover the ratio. Sampling both sides back-to-back puts
    them in the same load window, so a loaded runner slows numerator and
    denominator together; three bounded attempts absorb a GC pause or
    scheduler stall landing inside one window.

    Bar calibration: the original 10x bar was env-sensitive — the
    observed ratio is ~30x on fast hosts but settles at 6-9x on slow /
    loaded CI runners, where BOTH sides are Python-bound (the batched
    build's per-row list comprehensions vs the loop's per-config
    dispatches) and the paired ratio is genuinely below 10, not noisy.
    Reverting the memoized + vectorised draw path drops the ratio below
    1x, so 4x still catches the regression this test exists for, with
    real margin on every host observed."""
    prof = paper_fleet()
    levels = (1, 3, 5, 7, 9, 11, 13, 15)
    cycle = [SimConfig(n_users=u, n_requests=100, policy="MO", seed=s)
             for u in levels for s in range(3)]
    cfgs = cycle * 4167                    # 100_008 configs, 24 draws
    for u in levels:                       # warm the scalar-path jits
        _init_draws(999_983, 0.85, n_groups=prof.n_groups, n_users=u)
    grid_cache_clear()                     # warm the batched-path jits
    _make_grid(prof, [SimConfig(n_users=c.n_users, n_requests=100,
                                seed=c.seed + 1000) for c in cycle])

    n_slice = 2000
    attempts = []
    for _ in range(3):
        true0 = np.zeros((n_slice, max(levels)), np.int32)
        rngs = np.zeros((n_slice, 2), np.uint32)
        t0 = time.perf_counter()
        for i, c in enumerate(cfgs[:n_slice]):
            t, r = _init_draws(c.seed, c.stickiness,
                               n_groups=prof.n_groups, n_users=c.n_users)
            true0[i, :c.n_users] = np.asarray(t)
            rngs[i] = np.asarray(r)
        t_loop = (time.perf_counter() - t0) / n_slice * len(cfgs)

        grid_cache_clear()
        t0 = time.perf_counter()
        grid = _make_grid(prof, cfgs)
        t_batch = time.perf_counter() - t0
        assert grid.n_configs == len(cfgs)
        assert grid_cache_info()["misses"] == 24
        attempts.append((t_batch, t_loop))
        if t_batch * 4 <= t_loop:
            break
    assert any(b * 4 <= lo for b, lo in attempts), attempts


def test_stack_profiles_validates():
    f = synthetic_fleet(jax.random.PRNGKey(0), 5)
    g = synthetic_fleet(jax.random.PRNGKey(1), 6)
    with np.testing.assert_raises(ValueError):
        stack_profiles([])
    with np.testing.assert_raises(ValueError):
        stack_profiles([f, g])
    with np.testing.assert_raises(ValueError):
        stack_profiles([stack_profiles([f]), f])


def test_summarize_batch_close_to_looped():
    """Fused vmap summarize may reassociate reductions; it must stay within
    float32 tolerance of the per-config path."""
    prof = paper_fleet()
    scs = [Scenario(n_users=5, n_requests=400, policy="MO", seed=0),
           Scenario(n_users=15, n_requests=400, policy="HA", seed=1)]
    cfgs = [sc.to_config() for sc in scs]
    grid = _make_grid(prof, cfgs)
    recs = _simulate_batch(prof, grid, n_requests=400)
    batched = summarize_batch(recs, prof, warmup=40)
    for i, sc in enumerate(scs):
        ref = summarize(records(sc), prof, cfgs[i])
        for k in ref:
            np.testing.assert_allclose(float(batched[k][i]), float(ref[k]),
                                       rtol=1e-5, err_msg=k)


@pytest.mark.filterwarnings(
    "ignore::repro.core.scenario.LegacyAPIWarning")
def test_sweep_grid_axes_and_sweep_compat():
    """Legacy contract: sweep() (compat wrapper) agrees with indexing
    sweep_grid directly, and both still produce the historical 6-axis
    layout."""
    from repro.core.simulator import sweep, sweep_grid

    prof = paper_fleet()
    pols, users, seeds = ["MO", "LC"], [3, 7], (0, 1)
    m = sweep_grid(prof, policies=pols, user_levels=users, seeds=seeds,
                   n_requests=300)
    assert m["latency_ms"].shape == (2, 2, 1, 1, 1, 2)
    s = sweep(prof, pols, users, n_requests=300, seeds=seeds)
    for i, p in enumerate(pols):
        for j in range(len(users)):
            np.testing.assert_allclose(
                s[p]["latency_ms"][j],
                np.mean(m["latency_ms"][i, j, 0, 0, 0, :]))


def test_mo_select_batch_matches_moscore_kernel():
    """Algorithm-1 window routing: lax.scan reference == Pallas kernel
    (interpret mode) on a random window, bit-for-bit assignments."""
    from repro.kernels.moscore import moscore_route

    prof = paper_fleet()
    rng = jax.random.PRNGKey(11)
    gs = jax.random.randint(rng, (96,), 0, prof.n_groups)
    q0 = jax.random.randint(jax.random.fold_in(rng, 1), (prof.n_pairs,),
                            0, 3).astype(jax.numpy.float32)
    ps_ref, q_ref = mo_select_batch(prof, gs, q0, delta=20.0, gamma=0.6)
    ps_k, q_k = moscore_route(prof.T, prof.E, prof.mAP, gs, q0,
                              delta=20.0, gamma=0.6)
    np.testing.assert_array_equal(np.asarray(ps_ref), np.asarray(ps_k))
    np.testing.assert_allclose(np.asarray(q_ref), np.asarray(q_k))


def test_estimator_tracks_under_strong_models():
    """With an always-accurate fleet, estimator accuracy ~= chain
    stickiness-bound; with weak fleet it degrades (the paper's dynamic)."""
    res = run(Scenario(n_users=5, n_requests=1500),
              Sweep(policy=("HA", "LT")))
    s_acc = float(res.sel("estimator_acc", policy="HA"))
    w_acc = float(res.sel("estimator_acc", policy="LT"))
    assert s_acc > w_acc
    assert s_acc > 0.6
