"""Simulator invariants (property-based where it pays)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.estimator import markov_transition, stationary
from repro.core.profiles import paper_fleet, synthetic_fleet
from repro.core.simulator import SimConfig, simulate, summarize


def test_littles_law():
    """Closed-loop: concurrency = throughput x mean latency (±10%)."""
    prof = paper_fleet()
    for users in (3, 10):
        cfg = SimConfig(n_users=users, n_requests=2500, policy="MO")
        recs = simulate(prof, cfg)
        s = summarize(recs, prof, cfg)
        n_eff = float(s["throughput_rps"] * s["latency_ms"] / 1000.0)
        assert abs(n_eff - users) / users < 0.12, (users, n_eff)


def test_fifo_no_overlap():
    """Per-server: service intervals never overlap (single-server FIFO)."""
    prof = paper_fleet()
    cfg = SimConfig(n_users=8, n_requests=1200, policy="RND", seed=3)
    recs = simulate(prof, cfg)
    arr = np.asarray(recs["t_arrival"])
    lat = np.asarray(recs["latency"])
    srv = np.asarray(recs["server"])
    g = np.asarray(recs["g_true"])
    T = np.asarray(prof.T) / 1000.0
    finish = arr + lat
    start = finish - T[srv, g]
    for p in range(prof.n_pairs):
        m = srv == p
        if m.sum() < 2:
            continue
        order = np.argsort(start[m])
        s, f = start[m][order], finish[m][order]
        assert (s[1:] >= f[:-1] - 1e-6).all(), f"overlap on server {p}"


def test_latency_at_least_service_time():
    prof = paper_fleet()
    cfg = SimConfig(n_users=15, n_requests=1500)
    recs = simulate(prof, cfg)
    T = np.asarray(prof.T) / 1000.0
    tmin = T[np.asarray(recs["server"]), np.asarray(recs["g_true"])]
    # 1 ms tolerance: sim times are f32, so latency = finish - arrival
    # cancels to ~1e-4 s granularity late in long runs
    assert (np.asarray(recs["latency"]) >= tmin - 1e-3).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 30))
def test_synthetic_fleet_scales(seed, n_pairs):
    prof = synthetic_fleet(jax.random.PRNGKey(seed), n_pairs)
    cfg = SimConfig(n_users=6, n_requests=300, policy="MO", seed=seed)
    recs = simulate(prof, cfg)
    s = summarize(recs, prof, cfg)
    assert np.isfinite(s["latency_ms"]) and s["latency_ms"] > 0
    assert 0 < s["map"] <= 100


def test_markov_chain_is_stochastic():
    P = np.asarray(markov_transition(5))
    np.testing.assert_allclose(P.sum(1), 1.0, rtol=1e-6)
    assert (P >= 0).all()
    pi = np.asarray(stationary(markov_transition(5)))
    np.testing.assert_allclose(pi.sum(), 1.0, rtol=1e-5)
    assert pi[3] > pi[0]     # busy-crossing skew


def test_estimator_tracks_under_strong_models():
    """With an always-accurate fleet, estimator accuracy ~= chain
    stickiness-bound; with weak fleet it degrades (the paper's dynamic)."""
    prof = paper_fleet()
    strong = SimConfig(n_users=5, n_requests=1500, policy="HA")
    weak = SimConfig(n_users=5, n_requests=1500, policy="LT")
    s_acc = summarize(simulate(prof, strong), prof, strong)["estimator_acc"]
    w_acc = summarize(simulate(prof, weak), prof, weak)["estimator_acc"]
    assert s_acc > w_acc
    assert s_acc > 0.6
