"""The fault plane's pinning suite (``repro.core.faults``).

Four layers of protection:

  * golden regression — every ``faults=None`` scenario stays bit-identical
    to ``tests/golden_faults_pr9.json`` (captured from the pre-fault
    engine), on a single device AND a forced 4-device mesh, cloud-active
    scenarios included (the fault plane rewires the simulator's uplink
    branch);
  * routing properties — no policy ever selects a masked-down pair; the
    degraded fallback is the healthy argmin-latency pair and counts an
    SLO violation; every moscore backend agrees bit-identically under a
    mask; fault realizations are invariant to window partitioning and
    user blocks (fold_in-keyed draws, no carried state);
  * request-plane properties — :class:`AsyncExecutorPool` conserves
    requests under any interleaving of submissions, polls and
    ``fail_pairs`` kills; drift and fault throttles compose in the
    documented order ``truth = (prof x drift) x fault``, independent of
    call order;
  * integration — the gateway adopts a scenario's fault schedule and the
    serving plane retries failed work with bounded attempts.
"""

import json
import os
import subprocess
import sys
from dataclasses import replace
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dispatch import DriftSchedule, OnlineDispatch
from repro.core.faults import FaultSchedule
from repro.core.policies import POLICY_CODES, mo_select_batch, select_pair
from repro.core.profiles import ProfileTable, paper_fleet
from repro.core.scenario import Scenario, Sweep, records, run
from repro.kernels.moscore import moscore_route
from repro.serving.executor import AsyncExecutorPool
from repro.serving.gateway import WindowedGateway

REPO = Path(__file__).resolve().parent.parent
GOLDEN = REPO / "tests" / "golden_faults_pr9.json"

PROF = paper_fleet()
P = PROF.n_pairs

f32 = jnp.float32


def _golden():
    return json.loads(GOLDEN.read_text())


# ------------------------------------------------- golden regression --

def test_records_bit_identical_to_pr9_golden():
    """Every record scenario captured pre-FaultSchedule replays
    bit-for-bit through the fault-aware engine with faults=None —
    including the cloud-active scenarios, whose uplink/RTT branch the
    WAN-jitter hook rewired — and its spec is still canonical."""
    for entry in _golden()["records"]:
        sc = Scenario.from_json(entry["scenario"])
        assert sc.to_json() == entry["scenario"]
        recs = records(sc)
        for k, want in entry["records"].items():
            np.testing.assert_array_equal(
                np.asarray(recs[k], np.float64), np.asarray(want),
                err_msg=f"{entry['scenario']}:{k}")


@pytest.mark.parametrize("fixture", ["sweep", "cloud_sweep"])
def test_sweeps_bit_identical_to_pr9_golden(fixture):
    fix = _golden()[fixture]
    base = Scenario.from_json(fix["scenario"])
    assert base.to_json() == fix["scenario"]
    res = run(base, Sweep(policy=tuple(fix["policies"]),
                          n_users=tuple(fix["user_levels"]),
                          seed=tuple(fix["seeds"])))
    for k, want in fix["metrics"].items():
        np.testing.assert_array_equal(np.asarray(res[k], np.float64),
                                      np.asarray(want), err_msg=k)


_SUBPROC_CHECK = """
import json
import jax, numpy as np
from repro.core.faults import FaultSchedule
from repro.core.scenario import Scenario, Sweep, run
from repro.launch.mesh import make_sweep_mesh

assert len(jax.devices()) == 4, jax.devices()
mesh = make_sweep_mesh()

# faults=None sharded across 4 real devices still reproduces the PR 9
# golden sweep; only the percentile metric gets the usual 1-float32-ULP
# allowance (XLA FMA contraction varies with the compiled batch shape).
fix = json.load(open({golden!r}))["sweep"]
res = run(Scenario.from_json(fix["scenario"]),
          Sweep(policy=tuple(fix["policies"]),
                n_users=tuple(fix["user_levels"]),
                seed=tuple(fix["seeds"])), mesh=mesh)
for k, want in fix["metrics"].items():
    if k == "latency_p90_ms":
        np.testing.assert_allclose(np.asarray(res[k], np.float64),
                                   np.asarray(want), rtol=3e-7, err_msg=k)
    else:
        np.testing.assert_array_equal(np.asarray(res[k], np.float64),
                                      np.asarray(want), err_msg=k)

# fault-ACTIVE sweeps shard bitwise too: the FaultMeta replicates to every
# device and the epoch draws key on absolute step indices, so sharded ==
# single for every metric including the availability ones.
fsc = Scenario(n_requests=150,
               faults=FaultSchedule(down_rate=0.08, epoch=25,
                                    outages=((1, 30, 80),)))
fsw = Sweep(policy=("MO", "LT"), n_users=(3, 7), seed=(0,))
ref = run(fsc, fsw)
out = run(fsc, fsw, mesh=mesh)
for k in ref.metric_names:
    if k in ("latency_p90_ms", "latency_p99_ms"):   # percentiles: 1 ULP
        np.testing.assert_allclose(out[k], ref[k], rtol=3e-7, err_msg=k)
    else:
        np.testing.assert_array_equal(out[k], ref[k], err_msg=k)
assert "slo_violation_share" in ref.metric_names
print("OK")
"""


def test_faults_golden_in_forced_4_device_subprocess():
    """PR 9 golden + fault-active sharding on a real 4-device mesh
    (xla_force_host_platform_device_count in a fresh process)."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=str(REPO / "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    src = _SUBPROC_CHECK.format(golden=str(GOLDEN))
    res = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout


# ---------------------------------------------------- spec / hashing --

def test_fault_schedule_json_roundtrip():
    # a default schedule is inert and serializes to the minimal spec
    assert FaultSchedule().to_json() == {}
    assert not FaultSchedule().active
    fs = FaultSchedule(down_rate=0.05, epoch=25, throttle_rate=0.1,
                       rtt_jitter_ms=30.0, bw_jitter=0.5, timeout_ms=500.0,
                       max_attempts=2, visible=False,
                       outages=((2, 40, 90), (0, 10, 20)), seed=7)
    back = FaultSchedule.from_json(json.loads(json.dumps(fs.to_json())))
    assert back == fs and hash(back) == hash(fs)
    assert back.to_json() == fs.to_json()
    assert FaultSchedule.from_json(None) is None
    # only-when-set: defaulted knobs never appear in the spec
    assert set(FaultSchedule(down_rate=0.1).to_json()) == {"down_rate"}


def test_fault_schedule_validation():
    for bad in (dict(down_rate=1.0), dict(down_rate=-0.1),
                dict(throttle_rate=1.5), dict(epoch=0),
                dict(throttle_t_mult=0.0), dict(rtt_jitter_ms=-1.0),
                dict(bw_jitter=-0.5), dict(timeout_ms=-1.0),
                dict(max_attempts=0), dict(outages=((0, 50, 50),)),
                dict(outages=((-1, 0, 10),)), dict(outages=((0, 10),))):
        with pytest.raises(ValueError):
            FaultSchedule(**bad)
    # scripted pair must exist in the (extended) fleet
    with pytest.raises(ValueError, match="pair 9"):
        FaultSchedule(outages=((9, 0, 10),)).resolve(P)


def test_scenario_faults_spec_and_hash():
    """No-fault specs are untouched by the feature: no "faults" key,
    same hash as before; a fault scenario round-trips by value with a
    discriminating hash."""
    assert "faults" not in Scenario().to_json()
    assert Scenario(faults=None).hash == Scenario().hash
    fs = FaultSchedule(down_rate=0.05)
    sc = Scenario(n_users=5, faults=fs)
    back = Scenario.from_json(json.dumps(sc.to_json()))
    assert back == sc and back.hash == sc.hash
    assert back.faults == fs
    assert sc.hash != Scenario(n_users=5).hash
    assert Scenario(faults=FaultSchedule(down_rate=0.1)).hash \
        != Scenario(faults=FaultSchedule(down_rate=0.2)).hash


# ------------------------------------------------ routing properties --

@st.composite
def masked_case(draw):
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    nP = draw(st.integers(2, 12))
    nG = draw(st.integers(2, 6))
    prof = ProfileTable(jnp.asarray(rng.uniform(10, 500, (nP, nG))),
                        jnp.asarray(rng.uniform(0.01, 0.5, (nP, nG))),
                        jnp.asarray(rng.uniform(1, 99, (nP, nG))))
    health = rng.random(nP) > draw(st.floats(0.1, 0.9))
    if not health.any():
        health[int(rng.integers(0, nP))] = True
    gs = rng.integers(0, nG, 32)
    gamma = draw(st.floats(0.0, 1.0))
    delta = draw(st.floats(0.0, 60.0))
    return prof, jnp.asarray(health), jnp.asarray(gs, jnp.int32), \
        gamma, delta, rng


@settings(max_examples=30, deadline=None)
@given(masked_case())
def test_mo_routing_never_selects_masked_pair(case):
    """Property (satellite): under any health mask with at least one
    healthy pair, Algorithm 1 routes every request to a healthy pair —
    feasible-and-healthy when possible, the degraded argmin-latency
    fallback otherwise, never a down pair."""
    prof, health, gs, gamma, delta, _rng = case
    q0 = jnp.zeros((prof.n_pairs,), f32)
    ps, _ = mo_select_batch(prof, gs, q0, delta=delta, gamma=gamma,
                            health=health)
    h = np.asarray(health)
    assert h[np.asarray(ps)].all()


@settings(max_examples=15, deadline=None)
@given(masked_case())
def test_no_policy_selects_masked_pair(case):
    """The post-switch mask in policy_scores covers every baseline too:
    LC/LT/HA/RR/RND route around an outage exactly like MO."""
    prof, health, gs, gamma, delta, rng = case
    q0 = jnp.zeros((prof.n_pairs,), f32)
    key = jax.random.PRNGKey(int(rng.integers(0, 2**31 - 1)))
    h = np.asarray(health)
    for name, code in POLICY_CODES.items():
        p, _ = select_pair(jnp.asarray(code, jnp.int32), prof,
                           gs[0], q0, key, jnp.asarray(0, jnp.int32),
                           jnp.asarray(gamma, f32), jnp.asarray(delta, f32),
                           None, health)
        assert h[int(p)], name


def test_all_backends_agree_under_mask():
    """Every fp32 moscore backend produces the SAME bits under a health
    mask (the plain "pallas" kernel routes via the hoisted precompute);
    int8 stays under its bounded-mismatch contract."""
    rng = np.random.default_rng(3)
    gs = rng.integers(0, PROF.n_groups, 96)
    q0 = np.zeros(P, np.float32)
    for trial in range(4):
        health = jnp.asarray(rng.random(P) > 0.5).at[0].set(True)
        outs = {b: moscore_route(PROF.T, PROF.E, PROF.mAP, gs, q0,
                                 delta=15.0, gamma=0.4, backend=b,
                                 health=health)
                for b in ("pallas", "xla", "hoisted", "pallas_hoisted")}
        for b in ("pallas", "hoisted", "pallas_hoisted"):
            np.testing.assert_array_equal(np.asarray(outs[b][0]),
                                          np.asarray(outs["xla"][0]),
                                          err_msg=f"{trial}:{b}")
            np.testing.assert_array_equal(np.asarray(outs[b][1]),
                                          np.asarray(outs["xla"][1]),
                                          err_msg=f"{trial}:{b}")
        assert np.asarray(health)[np.asarray(outs["xla"][0])].all()
        ps8, _ = moscore_route(PROF.T, PROF.E, PROF.mAP, gs, q0,
                               delta=15.0, gamma=0.4, backend="int8",
                               health=health)
        assert np.asarray(health)[np.asarray(ps8)].all()


def test_degraded_fallback_is_healthy_argmin_latency():
    """When no healthy pair clears the accuracy bar, the defined
    degradation rule routes to the healthy pair with the lowest expected
    latency (gamma > 0): the accuracy term drops out of J."""
    g = 2
    best = int(np.argmax(np.asarray(PROF.mAP[:, g])))
    health = jnp.ones((P,), bool).at[best].set(False)
    # delta=0: only the argmax-mAP pair is feasible, and it is down
    gs = jnp.asarray([g], jnp.int32)
    q0 = jnp.zeros((P,), f32)
    ps, _ = mo_select_batch(PROF, gs, q0, delta=0.0, gamma=0.7,
                            health=health)
    h = np.asarray(health)
    lat = np.asarray(PROF.T[:, g], np.float64)
    lat[~h] = np.inf
    assert int(ps[0]) == int(np.argmin(lat))


def test_all_down_mask_relaxes_to_healthy():
    """A whole-fleet outage relaxes the router's mask to all-true (there
    is nobody else) while down_at still reports the outage for the truth
    model's stall and failed accounting."""
    meta = FaultSchedule(outages=tuple((p, 0, 10) for p in range(P))) \
        .resolve(P)
    assert np.asarray(meta.down_at(5)).all()
    assert np.asarray(meta.health_at(5)).all()
    assert not np.asarray(meta.down_at(10)).any()


def test_records_count_slo_violations_and_failures():
    """records() under a scripted outage reports the availability
    stream: failed marks requests dispatched into the outage (blind
    router), slo_violation marks steps where no healthy pair could clear
    the accuracy bar."""
    # pair 3 is the busiest pair of this scenario — the outage that hurts
    fs = FaultSchedule(outages=((3, 10, 60),), visible=False,
                       timeout_ms=2000.0)
    recs = records(Scenario(n_users=6, n_requests=120, seed=0, faults=fs))
    assert "failed" in recs and "slo_violation" in recs
    failed = np.asarray(recs["failed"])
    srv = np.asarray(recs["server"], np.int64)
    assert failed.sum() > 0                       # blind router pays
    assert (srv[failed > 0] == 3).all()           # only the down pair
    # the aware router avoids the down pair entirely during the window
    aware = records(Scenario(n_users=6, n_requests=120, seed=0,
                             faults=replace(fs, visible=True)))
    assert np.asarray(aware["failed"]).sum() == 0
    # failover-aware routing beats the blind router's stall-laden mean
    assert np.asarray(aware["latency"]).mean() \
        < np.asarray(recs["latency"]).mean()


# --------------------------------------------- realization invariance --

def _route_stream(sc, window, n=126):
    gw = WindowedGateway(sc, backend="hoisted")
    q = np.zeros(gw.prof.n_pairs, np.float32)
    ids = np.arange(n) % 9
    out = []
    for i in range(0, n, window):
        p, _g, q = gw.route_window(ids[i:i + window], q)
        out.append(np.asarray(p))
    return np.concatenate(out)


@pytest.mark.parametrize("policy", ["MO", "LT"])
def test_fault_draws_invariant_to_window_partition(policy):
    """The mask enters the gateway as health_at(absolute request index),
    so no partition of the stream into admission windows can change a
    decision — the same invariance contract as the RND key stream."""
    fs = FaultSchedule(down_rate=0.2, epoch=20, outages=((1, 30, 70),),
                       seed=5)
    sc = Scenario(n_users=9, n_requests=0, seed=2, policy=policy,
                  faults=fs)
    ref = _route_stream(sc, 126)
    for window in (1, 7, 64):
        np.testing.assert_array_equal(ref, _route_stream(sc, window),
                                      err_msg=f"W={window}")
    # the schedule actually bit: some decisions differ from fault-free
    assert (ref != _route_stream(replace(sc, faults=None), 126)).any()


def test_fault_realization_invariant_to_user_block():
    """Fault draws key on the per-user step index, never on the block
    shape or batch position: a single-block run is bit-identical to the
    un-blocked engine, and every block row of a multi-block fault grid
    equals its own solo run (the useraxis contract, extended to the
    availability metrics)."""
    from repro.core.dispatch import StaticDispatch
    from repro.core.simulator import (ConfigGrid, SimConfig,
                                      _make_user_grid, _sweep_summaries)
    from repro.core.workload import MarkovWorkload

    fs = FaultSchedule(down_rate=0.15, epoch=10, throttle_rate=0.2,
                       seed=3)
    base = Scenario(n_users=20, n_requests=80, seed=1, faults=fs)
    ref, one_block = run(base), run(replace(base, user_block=20))
    assert "slo_violation_share" in ref.metric_names
    for k in ref.metric_names:
        np.testing.assert_array_equal(np.asarray(ref[k]),
                                      np.asarray(one_block[k]), err_msg=k)

    meta = fs.resolve(P)
    grid, _seg = _make_user_grid(
        PROF, [SimConfig(n_users=20, n_requests=80, seed=1)], 8)
    wl, de = MarkovWorkload(), StaticDispatch()
    per_block = _sweep_summaries(PROF, wl, de, None, None, meta, grid,
                                 n_requests=80, warmup=12, mesh=None)
    assert "failed_share" in per_block
    for b in range(grid.n_configs):
        row = ConfigGrid(*[leaf[b:b + 1] for leaf in grid])
        solo = _sweep_summaries(PROF, wl, de, None, None, meta, row,
                                n_requests=80, warmup=12, mesh=None)
        for k in per_block:
            np.testing.assert_array_equal(
                np.asarray(per_block[k][b]), np.asarray(solo[k][0]),
                err_msg=f"block {b}: {k}")


def test_faults_sweep_axis_and_mixed_fill():
    """faults is a sweepable Scenario axis; a sweep mixing faults=None
    with live schedules still reports rectangular availability metrics
    (zeros on the no-fault slices)."""
    res = run(Scenario(n_users=5, n_requests=100, seed=0),
              Sweep(faults=[None, FaultSchedule(down_rate=0.3, epoch=10)]))
    slo = np.asarray(res["slo_violation_share"], np.float64).ravel()
    failed = np.asarray(res["failed_share"], np.float64).ravel()
    assert slo.shape == (2,) and failed.shape == (2,)
    assert slo[0] == 0.0 and failed[0] == 0.0
    p99 = np.asarray(res["latency_p99_ms"], np.float64).ravel()
    assert p99[0] == 0.0 and p99[1] > 0.0      # zeros-fill on the None slice


# ------------------------------------------------ request-plane props --

@settings(max_examples=25, deadline=None)
@given(st.integers(1, 40), st.integers(0, 2**31 - 1))
def test_pool_conserves_requests_under_faults(n_ops, seed):
    """Property (satellite): under any interleaving of window
    submissions, out-of-order polls and fail_pairs kills (random down
    masks and timeouts), the pool conserves requests —
    submitted == polled + failed + in_flight — and depths stay
    non-negative; every rid surfaces exactly once (polled XOR failed)."""
    rng = np.random.default_rng(seed)
    pool = AsyncExecutorPool(PROF)
    now, rid = 0.0, 0
    seen_polled, seen_failed = [], []
    for _ in range(n_ops):
        r = rng.random()
        if r < 0.5:
            w = int(rng.integers(1, 9))
            pool.submit_window(rng.integers(0, P, w),
                               rng.integers(0, PROF.n_groups, w), now,
                               rids=np.arange(rid, rid + w))
            rid += w
        elif r < 0.8:
            now += float(rng.uniform(0.0, 2.0))
            done = pool.poll(now)
            assert (done.finish_s <= now).all()
            seen_polled.extend(done.rids.tolist())
        else:
            down = rng.random(P) < 0.4
            t_s = float(rng.uniform(0.5, 3.0)) if rng.random() < 0.5 \
                else None
            failed = pool.fail_pairs(down, now, timeout_s=t_s)
            assert (failed.finish_s > now).all()     # never completions
            if t_s is None:
                assert down[failed.pairs].all()
            seen_failed.extend(failed.rids.tolist())
        assert (pool._depth >= 0).all()
        assert pool.submitted == pool.polled + pool.failed + pool.in_flight
    tail = pool.poll(np.inf)
    seen_polled.extend(tail.rids.tolist())
    assert pool.in_flight == 0 and (pool._depth == 0).all()
    assert sorted(seen_polled + seen_failed) == list(range(rid))


def test_fail_pairs_rebuilds_fifo_frontier():
    """Killing a down pair's backlog frees its FIFO frontier: work
    submitted after recovery is not serialized behind ghost requests."""
    pool = AsyncExecutorPool(PROF)
    svc = float(pool._T_s[0].max())
    pool.submit_window(np.zeros(50, np.int64),
                       np.full(50, PROF.n_groups - 1), 0.0,
                       rids=np.arange(50))
    backlog = float(pool._avail[0])
    down = np.zeros(P, bool)
    down[0] = True
    failed = pool.fail_pairs(down, 0.1)
    assert failed.size == 50 and pool.failed == 50
    assert pool._avail[0] <= 0.1
    # recovered pair: a fresh request finishes in ~one service time
    resp = pool.submit_window(np.zeros(1, np.int64), np.zeros(1, np.int64),
                              0.2, rids=np.asarray([50]))
    assert float(resp.finish_s[0]) <= 0.2 + svc < backlog


def test_drift_and_fault_throttle_compose_order_independent():
    """truth = (prof x drift) x fault, bitwise, whatever order the two
    hooks fire in: drift is a cumulative multiplier, the fault throttle
    SETs its factor (a pure function of the fault step)."""
    drift_t = np.linspace(1.1, 2.0, P * PROF.n_groups).reshape(
        P, PROF.n_groups)
    fault_t = np.where(np.arange(P) % 2 == 0, 3.0, 1.0)[:, None]
    a = AsyncExecutorPool(PROF)
    a.apply_drift(drift_t, 1.5)
    a.set_fault_throttle(fault_t, np.full((P, 1), 1.25))
    b = AsyncExecutorPool(PROF)
    b.set_fault_throttle(fault_t, np.full((P, 1), 1.25))
    b.apply_drift(drift_t, 1.5)
    np.testing.assert_array_equal(a._T_s, b._T_s)
    np.testing.assert_array_equal(a._E, b._E)
    want = (np.asarray(PROF.T, np.float64) / 1000.0 * drift_t) * fault_t
    np.testing.assert_array_equal(a._T_s, want)
    # SET semantics: clearing the throttle restores pure drift
    a.set_fault_throttle(1.0)
    np.testing.assert_array_equal(
        a._T_s, np.asarray(PROF.T, np.float64) / 1000.0 * drift_t)


def test_simulator_composes_drift_and_fault_throttle():
    """The simulator's truth model applies the same order: with a
    fleet-wide deterministic drift and an (epoch-keyed) fault throttle
    active together, observed latencies scale multiplicatively on the
    slowed steps — never less than the drift-only run."""
    drift = DriftSchedule.throttle(PROF, 2, at_step=20, t_mult=1.5)
    base = Scenario(n_users=5, n_requests=100, seed=0, drift=drift)
    both = replace(base, faults=FaultSchedule(throttle_rate=0.6,
                                              epoch=10, seed=2,
                                              throttle_t_mult=4.0))
    lat_d = np.asarray(records(base)["latency"])
    lat_b = np.asarray(records(both)["latency"])
    assert lat_b.mean() > lat_d.mean()


# ------------------------------------------------ serving integration --

def test_gateway_adopts_scenario_faults_and_masks():
    fs = FaultSchedule(outages=((3, 0, 10_000),))
    gw = WindowedGateway(Scenario(n_users=8, faults=fs),
                         backend="hoisted")
    assert gw._fault_meta is not None and gw._fault_meta.visible
    pairs, _, _ = gw.route_window(np.arange(64) % 8, np.zeros(P))
    assert not (np.asarray(pairs) == 3).any()
    # blind schedule: the router keeps the fused no-mask path
    blind = WindowedGateway(
        Scenario(n_users=8, faults=replace(fs, visible=False)))
    assert blind._fault_meta is not None and not blind._fault_meta.visible
    # an inert schedule costs nothing at all
    assert WindowedGateway(paper_fleet(),
                           faults=FaultSchedule())._fault_meta is None


def test_pods_with_faults_raises():
    with pytest.raises(ValueError, match="fault mask"):
        WindowedGateway(paper_fleet(),
                        faults=FaultSchedule(down_rate=0.1),
                        pods=[0, 0, 1, 1, 2])


def test_serving_plane_retries_with_bounded_attempts():
    """End-to-end failover loop: an outage on the busiest pair fails its
    in-flight work, the plane re-routes the victims (at most
    max_attempts tries), the pool conserves every request, and the
    availability metrics surface in summarize()."""
    from repro.serving.engine import ServingPlane

    fs = FaultSchedule(outages=((3, 40, 160),), timeout_ms=400.0,
                       max_attempts=2)
    sc = Scenario(n_users=12, n_requests=0, seed=3, policy="MO", faults=fs)
    plane = ServingPlane.build(sc, window=16, offered_rps=30.0)
    recs = plane.run(240)
    pool = plane.pool
    assert pool.submitted == pool.polled + pool.failed + pool.in_flight
    assert pool.in_flight == 0
    assert plane.retried > 0
    # every offered request either completed or was dropped for good
    assert recs["latency"].size == 240 - plane.failed_requests
    s = ServingPlane.summarize(recs)
    assert {"failed_share", "retried_share", "latency_p99_ms"} <= set(s)
    assert 0.0 <= s["failed_share"] <= 1.0
    assert s["latency_p99_ms"] >= s["latency_p90_ms"]
    # a fault-free plane reports no availability keys (old contract)
    clean = ServingPlane.build(replace(sc, faults=None), window=16,
                               offered_rps=30.0)
    s0 = ServingPlane.summarize(clean.run(96))
    assert "failed_share" not in s0
