"""Per-architecture smoke tests: instantiate the REDUCED config of each
assigned architecture and run one step of every shape kind on CPU, asserting
output shapes and absence of NaNs. (Full configs are dry-run only.)"""

import jax
import jax.numpy as jnp
import pytest

from repro import configs as C
from repro.launch import steps as S

KINDS = {
    "lm": ["train", "prefill", "decode"],
    "diffusion": ["train", "serve"],
    "vision": ["train", "serve"],
}


def _first_shape_of_kind(arch, kind):
    for sh in arch.shapes:
        if sh.kind == kind:
            return sh
    raise AssertionError(kind)


def _finite(tree) -> bool:
    leaves = [l for l in jax.tree.leaves(tree)
              if hasattr(l, "dtype") and jnp.issubdtype(l.dtype, jnp.floating)]
    return all(bool(jnp.all(jnp.isfinite(l.astype(jnp.float32)))) for l in leaves)


CASES = [(a, k) for a in C.ARCH_IDS for k in KINDS[C.get(a).family]]


@pytest.mark.parametrize("arch_id,kind", CASES,
                         ids=[f"{a}-{k}" for a, k in CASES])
def test_smoke(arch_id, kind):
    arch = C.get(arch_id)
    shape = _first_shape_of_kind(arch, kind)
    cell = S.build_cell(arch, shape, mesh=None, reduced=True)
    args = S.init_concrete(cell, jax.random.PRNGKey(0))
    out = jax.jit(cell.step_fn)(*args)

    if shape.kind == "train":
        state, metrics = out
        assert metrics["loss"].shape == ()
        assert _finite(metrics["loss"]), metrics
        assert _finite(state["params"])
        assert int(state["step"]) == 1
    elif shape.kind == "prefill":
        logits, caches = out
        B = cell.shape.global_batch
        assert logits.shape == (B, cell.config.vocab_size)
        assert _finite(logits)
    elif shape.kind == "decode":
        logits, caches = out
        B = cell.shape.global_batch
        assert logits.shape == (B, cell.config.vocab_size)
        assert _finite(logits)
    else:  # serve
        if arch.family == "vision":
            assert out.shape == (cell.shape.global_batch, cell.config.n_classes)
            assert _finite(out)
        else:
            lr = cell.config.latent_res(cell.shape.img_res)
            assert out.shape[:2] == (cell.shape.global_batch, lr)
            assert _finite(out)


def test_full_param_counts():
    """Full (non-reduced) configs match the published parameter counts."""
    expect = {
        "deepseek-moe-16b": 16.4e9,
        "arctic-480b": 482e9,
        "stablelm-12b": 12.1e9,
        "stablelm-3b": 2.8e9,
    }
    for aid, n in expect.items():
        cfg = C.get(aid).config
        got = cfg.n_params()
        assert abs(got - n) / n < 0.15, (aid, got, n)

    from repro.models import convnets
    vis = {"resnet-50": 25.6e6, "resnet-152": 60.2e6,
           "convnext-b": 88.6e6, "efficientnet-b7": 66.3e6}
    for aid, n in vis.items():
        got = convnets.count_params(C.get(aid).config)
        assert abs(got - n) / n < 0.05, (aid, got, n)
