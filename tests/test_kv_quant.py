"""int8 KV-cache quantisation: correctness vs the bf16 cache path."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.common.configs import LMConfig
from repro.models import transformer as T


@pytest.fixture(scope="module")
def setup():
    cfg = LMConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                   d_ff=128, vocab_size=128, dtype="float32")
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    tok = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 128)
    return cfg, cfg8, params, tok


def test_cache_layout(setup):
    _, cfg8, _, _ = setup
    c8 = T.init_cache(cfg8, 2, 32)
    assert c8["k"].dtype == jnp.int8
    assert c8["k_scale"].shape == (2, 2, 32, 2, 1)


def test_prefill_decode_close_to_bf16(setup):
    cfg, cfg8, params, tok = setup
    c16 = T.init_cache(cfg, 2, 32)
    c8 = T.init_cache(cfg8, 2, 32)
    l16, c16 = T.prefill(cfg, params, tok, c16)
    l8, c8 = T.prefill(cfg8, params, tok, c8)
    rel = float(jnp.max(jnp.abs(l16 - l8))) / float(jnp.max(jnp.abs(l16)))
    assert rel < 0.05, rel
    nxt = jnp.argmax(l16, -1)[:, None].astype(jnp.int32)
    d16, _ = T.decode_step(cfg, params, nxt, c16, 16)
    d8, _ = T.decode_step(cfg8, params, nxt, c8, 16)
    rel2 = float(jnp.max(jnp.abs(d16 - d8))) / float(jnp.max(jnp.abs(d16)))
    assert rel2 < 0.05, rel2
    # greedy next-token agreement
    assert jnp.array_equal(jnp.argmax(d16, -1), jnp.argmax(d8, -1))


def test_cache_bytes_halved():
    from repro.roofline.memtraffic import lm_capacity
    from repro.common.configs import ShapeSpec, TrainingConfig

    cfg = LMConfig(name="t", n_layers=4, d_model=256, n_heads=8, n_kv_heads=8,
                   d_ff=512, vocab_size=1000)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    sh = ShapeSpec("decode", "decode", global_batch=8, seq_len=1024)
    t = TrainingConfig()
    c16 = lm_capacity(cfg, sh, t, 256, 16)["kv_cache"]
    c8 = lm_capacity(cfg8, sh, t, 256, 16)["kv_cache"]
    assert c8 / c16 < 0.58          # 0.5 + scale overhead
