"""The edge-to-cloud offloading tier's pinning suite.

Three layers of protection around ``repro.core.cloud``:

  * golden regression — every ``cloud=None`` scenario stays bit-identical
    to ``tests/golden_cloud_pr7.json`` (captured from the pre-CloudTier
    engine), on a single device AND a forced 4-device mesh;
  * properties — a zero-cost cloud pair (rtt=0, bw=inf, xfer-energy=0)
    scores bitwise like a local pair with the same profile; offload share
    is monotone non-increasing in RTT; CloudTier round-trips through
    JSON; specs/hashes without a cloud are untouched by the feature;
  * integration — the serving gateway adopts a scenario's cloud, the
    pods= hierarchical router gets its auto-appended cloud pod, and the
    no-cloud gateway keeps the fused kernel path.
"""

import json
import os
import subprocess
import sys
from dataclasses import replace
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cloud import (CloudTier, default_cloud_pairs,
                              default_payload_kb)
from repro.core.policies import mo_scores
from repro.core.profiles import ProfileTable, paper_fleet, synthetic_fleet
from repro.core.scenario import Scenario, Sweep, records, run

REPO = Path(__file__).resolve().parent.parent
GOLDEN = REPO / "tests" / "golden_cloud_pr7.json"

f32 = jnp.float32


def _golden():
    return json.loads(GOLDEN.read_text())


# ------------------------------------------------- golden regression --

def test_records_bit_identical_to_pr7_golden():
    """Every record scenario captured pre-CloudTier replays bit-for-bit
    through the offload-aware engine with cloud=None, and its spec is
    still canonical (same JSON in == same JSON out, hence same hash)."""
    for entry in _golden()["records"]:
        sc = Scenario.from_json(entry["scenario"])
        assert sc.to_json() == entry["scenario"]
        recs = records(sc)
        for k, want in entry["records"].items():
            np.testing.assert_array_equal(
                np.asarray(recs[k], np.float64), np.asarray(want),
                err_msg=f"{entry['scenario']}:{k}")


def test_sweep_bit_identical_to_pr7_golden():
    fix = _golden()["sweep"]
    base = Scenario.from_json(fix["scenario"])
    assert base.to_json() == fix["scenario"]
    res = run(base, Sweep(policy=tuple(fix["policies"]),
                          n_users=tuple(fix["user_levels"]),
                          seed=tuple(fix["seeds"])))
    for k, want in fix["metrics"].items():
        np.testing.assert_array_equal(np.asarray(res[k], np.float64),
                                      np.asarray(want), err_msg=k)


_SUBPROC_CHECK = """
import json
import jax, numpy as np
from repro.core.cloud import CloudTier
from repro.core.scenario import Scenario, Sweep, run
from repro.launch.mesh import make_sweep_mesh

assert len(jax.devices()) == 4, jax.devices()
mesh = make_sweep_mesh()

# cloud=None sharded across 4 real devices still reproduces the PR 7
# golden sweep; only the percentile metric gets the usual 1-float32-ULP
# allowance (XLA FMA contraction varies with the compiled batch shape).
fix = json.load(open({golden!r}))["sweep"]
res = run(Scenario.from_json(fix["scenario"]),
          Sweep(policy=tuple(fix["policies"]),
                n_users=tuple(fix["user_levels"]),
                seed=tuple(fix["seeds"])), mesh=mesh)
for k, want in fix["metrics"].items():
    if k == "latency_p90_ms":
        np.testing.assert_allclose(np.asarray(res[k], np.float64),
                                   np.asarray(want), rtol=3e-7, err_msg=k)
    else:
        np.testing.assert_array_equal(np.asarray(res[k], np.float64),
                                      np.asarray(want), err_msg=k)

# cloud-ACTIVE sweeps shard bitwise too: same CloudMeta replicated to
# every device, sharded == single for each metric including the share.
csc = Scenario(n_requests=150, cloud=CloudTier())
csw = Sweep(policy=("MO", "LT"), n_users=(3, 7), seed=(0,))
ref = run(csc, csw)
out = run(csc, csw, mesh=mesh)
for k in ref.metric_names:
    if k == "latency_p90_ms":
        np.testing.assert_allclose(out[k], ref[k], rtol=3e-7, err_msg=k)
    else:
        np.testing.assert_array_equal(out[k], ref[k], err_msg=k)
assert "offload_share" in ref.metric_names
print("OK")
"""


def test_cloud_golden_in_forced_4_device_subprocess():
    """PR 7 golden + cloud-active sharding on a real 4-device mesh
    (xla_force_host_platform_device_count in a fresh process)."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=str(REPO / "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    src = _SUBPROC_CHECK.format(golden=str(GOLDEN))
    res = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout


# ------------------------------------------------ offload properties --

@st.composite
def zero_cost_case(draw):
    P = draw(st.integers(2, 10))
    G = draw(st.integers(2, 6))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    prof = ProfileTable(jnp.asarray(rng.uniform(10, 500, (P, G))),
                        jnp.asarray(rng.uniform(0.01, 0.5, (P, G))),
                        jnp.asarray(rng.uniform(1, 99, (P, G))))
    i = draw(st.integers(0, P - 1))          # local pair the cloud mirrors
    g = draw(st.integers(0, G - 1))
    q = rng.integers(0, 10, P + 1).astype(np.float32)
    q[P] = q[i]                              # same queue depth both sides
    gamma = draw(st.floats(0.0, 1.0))
    delta = draw(st.floats(0.0, 60.0))
    return prof, i, g, jnp.asarray(q), gamma, delta


@settings(max_examples=40, deadline=None)
@given(zero_cost_case())
def test_zero_cost_cloud_pair_scores_bitwise_like_local(case):
    """rtt=0, bw=inf, xfer-energy=0: the extension is free, so a cloud
    pair mirroring a local pair's profile gets the SAME bits out of
    Algorithm 1 — extension rows, congestion penalty (identically zero)
    and scores included. Offload-vs-local is then pure profile economics,
    which is the design invariant the tier rests on."""
    prof, i, g, q, gamma, delta = case
    mirror = ProfileTable(prof.T[i:i + 1], prof.E[i:i + 1],
                          prof.mAP[i:i + 1], ("cloud/mirror",))
    tier = CloudTier(rtt_ms=0.0, bw_mbps=float("inf"),
                     xfer_energy_mj_per_kb=0.0, cloud_pairs=mirror)
    ext, meta = tier.extend(prof)
    P = prof.n_pairs
    np.testing.assert_array_equal(np.asarray(ext.T[P]), np.asarray(prof.T[i]))
    np.testing.assert_array_equal(np.asarray(ext.E[P]), np.asarray(prof.E[i]))
    pen = meta.penalty(g, q)
    np.testing.assert_array_equal(np.asarray(pen), 0.0)
    J, _ = mo_scores(ext.T[:, g], ext.E[:, g], ext.mAP[:, g], q,
                     delta=delta, gamma=gamma, penalty=pen)
    Jn = np.asarray(J)
    assert Jn[P].tobytes() == Jn[i].tobytes()


def test_offload_share_monotone_non_increasing_in_rtt():
    """Raising the round-trip time can only make offloading less
    attractive: the MO policy's offload share never increases with RTT,
    and a far-away cloud (1 s RTT) is mostly ignored."""
    rtts = (0.0, 40.0, 200.0, 1000.0)
    res = run(Scenario(n_users=7, n_requests=200, seed=0),
              Sweep(cloud=[CloudTier(rtt_ms=r) for r in rtts]))
    share = np.asarray(res["offload_share"], np.float64).ravel()
    assert share.shape == (4,)
    assert share[0] > 0.3                  # a free-ish cloud gets used
    assert np.all(np.diff(share) <= 1e-6)  # monotone non-increasing
    assert share[-1] < share[0]


def test_records_offload_routes_to_extended_pairs():
    sc = Scenario(n_users=6, n_requests=150, seed=1, cloud=CloudTier())
    recs = records(sc)
    srv = np.asarray(recs["server"], np.int64)
    P = paper_fleet().n_pairs
    assert srv.max() >= P            # some requests actually offloaded
    assert srv.max() < P + default_cloud_pairs().n_pairs
    # the same scenario minus the cloud never leaves the local fleet
    srv0 = np.asarray(records(replace(sc, cloud=None))["server"])
    assert srv0.max() < P


# --------------------------------------------------- JSON round-trip --

def test_cloud_tier_json_roundtrip():
    # defaults serialize to the minimal spec (shared hash rule)
    t = CloudTier()
    spec = t.to_json()
    assert set(spec) == {"rtt_ms", "bw_mbps", "xfer_energy_mj_per_kb"}
    assert CloudTier.from_json(json.loads(json.dumps(spec))) == t
    # custom pairs + payload + infinite bandwidth survive the string form
    pairs = synthetic_fleet(jax.random.PRNGKey(0), 5)
    t2 = CloudTier(rtt_ms=12.5, bw_mbps=float("inf"),
                   xfer_energy_mj_per_kb=0.0,
                   cloud_pairs=ProfileTable(pairs.T[:2], pairs.E[:2],
                                            pairs.mAP[:2],
                                            ("cloud/a", "cloud/b")),
                   payload_kb=np.linspace(30, 90, 5))
    back = CloudTier.from_json(json.loads(json.dumps(t2.to_json())))
    assert back == t2 and back.bw_mbps == float("inf")
    np.testing.assert_array_equal(back.payload_kb, t2.payload_kb)
    np.testing.assert_array_equal(np.asarray(back.cloud_pairs.T),
                                  np.asarray(t2.cloud_pairs.T))
    assert CloudTier.from_json(None) is None


def test_cloud_tier_validation():
    with pytest.raises(ValueError):
        CloudTier(rtt_ms=-1.0)
    with pytest.raises(ValueError):
        CloudTier(bw_mbps=0.0)
    with pytest.raises(ValueError):
        CloudTier(xfer_energy_mj_per_kb=-0.1)
    with pytest.raises(ValueError):
        CloudTier(payload_kb=np.array([-1.0, 2.0]))
    with pytest.raises(ValueError):
        default_cloud_pairs(n_groups=3)
    with pytest.raises(ValueError):
        CloudTier(payload_kb=np.ones(3)).extend(paper_fleet())


def test_scenario_cloud_spec_and_hash():
    """No-cloud specs are untouched by the feature: no "cloud" key, same
    hash as before; a cloud scenario round-trips by value with a
    discriminating hash."""
    assert "cloud" not in Scenario().to_json()
    assert Scenario(cloud=None).hash == Scenario().hash
    sc = Scenario(n_users=5, cloud=CloudTier(rtt_ms=80.0))
    back = Scenario.from_json(json.dumps(sc.to_json()))
    assert back == sc and back.hash == sc.hash
    assert back.to_json() == sc.to_json()
    assert back.cloud == CloudTier(rtt_ms=80.0)
    assert sc.hash != Scenario(n_users=5).hash
    assert Scenario(cloud=CloudTier(rtt_ms=10.0)).hash \
        != Scenario(cloud=CloudTier(rtt_ms=20.0)).hash


def test_cloud_rejects_stacked_profiles():
    profs = [synthetic_fleet(jax.random.PRNGKey(k), 5) for k in (0, 1)]
    with pytest.raises(ValueError, match="stacked"):
        run(Scenario(n_requests=60, cloud=CloudTier()),
            Sweep(profile=profs))


def test_mixed_cloud_axis_fills_offload_share():
    """A sweep mixing cloud=None with real tiers still reports one
    rectangular offload_share array: the no-cloud slices are zero."""
    res = run(Scenario(n_users=5, n_requests=120, seed=0),
              Sweep(cloud=[None, CloudTier(rtt_ms=40.0)]))
    share = np.asarray(res["offload_share"], np.float64).ravel()
    assert share.shape == (2,)
    assert share[0] == 0.0 and share[1] > 0.0


# ------------------------------------------------ serving integration --

def test_gateway_adopts_scenario_cloud_and_pods():
    from repro.serving.gateway import WindowedGateway

    sc = Scenario(n_users=8, n_requests=120, cloud=CloudTier(rtt_ms=0.0))
    gw = WindowedGateway(sc)
    P = paper_fleet().n_pairs
    assert gw.prof.n_pairs == P + default_cloud_pairs().n_pairs
    pairs, _, _ = gw.route_window(np.arange(16), np.zeros(gw.prof.n_pairs))
    assert int(np.max(np.asarray(pairs))) >= P    # cheap cloud gets picked

    # pods: a local-only pod vector gets the cloud pod appended
    gw2 = WindowedGateway(sc, pods=[0, 0, 1, 1, 2])
    assert np.asarray(gw2._pod_of_pair).tolist() == [0, 0, 1, 1, 2, 3, 3]
    p2, _, _ = gw2.route_window(np.arange(8), np.zeros(gw2.prof.n_pairs))
    assert p2.shape == (8,)

    with pytest.raises(ValueError, match="MO"):
        WindowedGateway(paper_fleet(), policy="LC", pods=[0, 0, 1, 1, 2])


def test_no_cloud_gateway_keeps_fused_path():
    from repro.serving.gateway import WindowedGateway

    gw = WindowedGateway(paper_fleet())
    assert gw._cloud_meta is None and gw._pod_of_pair is None
    pairs, _, _ = gw.route_window(np.arange(4), np.zeros(5))
    assert int(np.max(np.asarray(pairs))) < 5


def test_serving_plane_offloads_with_cloud_scenario():
    from repro.serving.engine import ServingPlane

    sc = Scenario(n_users=10, n_requests=200, cloud=CloudTier(), seed=0)
    plane = ServingPlane.build(sc, window=32)
    recs = plane.run(192)
    served = np.asarray(recs["pair"], np.int64)
    P = paper_fleet().n_pairs
    assert served.max() >= P
    summ = ServingPlane.summarize(recs)
    assert summ["latency_ms"] > 0


def test_default_payload_scales_with_group():
    pl = default_payload_kb(5)
    assert pl.shape == (5,) and np.all(np.diff(pl) > 0)
    # xfer time: KB -> kbit over Mbps, zero at infinite bandwidth
    t = CloudTier(bw_mbps=16.0)
    np.testing.assert_allclose(t.xfer_ms(5), pl * 8.0 / 16.0, rtol=1e-6)
    assert np.all(CloudTier(bw_mbps=float("inf")).xfer_ms(5) == 0.0)
