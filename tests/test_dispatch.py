"""The dispatch-state contract (ISSUE 4): the default ``StaticDispatch``
path through the ``DispatchEngine`` interface reproduces the PR 3 engine
bit for bit, ``OnlineDispatch`` grids keep every batching axis (vmap /
mesh sharding / fleet stacking), under a ``DriftSchedule`` online-MO
strictly dominates static-MO on latency and energy while matching it with
no drift, and the sliding-window forgetting variant
(``OnlineDispatch(window=W)``, ISSUE 5) re-converges faster than plain
annealing after large drifts.

The golden fixture (``golden_static_pr3.json``) was captured from the
engine at PR 3 (commit a548684), before ``DispatchEngine`` existed — do
not regenerate it from current code, that would defeat the regression.
The two tests that drive the deprecated kwarg entry points on purpose
(the legacy golden contracts) opt out of the repo-wide
LegacyAPIWarning-as-error filter.
"""

import json
import os
import subprocess
import sys
from dataclasses import replace
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dispatch import (DriftSchedule, OnlineDispatch,
                                 StaticDispatch, default_dispatch)
from repro.core.policies import POLICY_CODES
from repro.core.profiles import paper_fleet, stack_profiles, synthetic_fleet
from repro.core.scenario import Scenario, Sweep, records, run
from repro.core.simulator import (SimConfig, _make_grid, _simulate,
                                  _simulate_batch)

REPO = Path(__file__).resolve().parent.parent
GOLDEN = REPO / "tests" / "golden_static_pr3.json"

LEGACY_OK = pytest.mark.filterwarnings(
    "ignore::repro.core.scenario.LegacyAPIWarning")


def _golden():
    with open(GOLDEN) as f:
        return json.load(f)


def _assert_metrics_equal(out, ref):
    """Bit-equality for every sweep metric except ``latency_p90_ms``,
    which gets a 1-ULP tolerance: ``jnp.percentile``'s linear
    interpolation (``lo + frac * (hi - lo)``) is an FMA-contraction
    candidate and XLA's choice varies with the compiled batch shape, so
    sharded vs single runs of bit-identical records can differ by one
    float32 ULP in that metric alone (drifted latency values expose it;
    see the FMA note in tests/test_workload_sources.py for the PR 3
    precedent)."""
    for k in ref:
        if k == "latency_p90_ms":
            np.testing.assert_allclose(out[k], ref[k], rtol=3e-7,
                                       err_msg=k)
        else:
            np.testing.assert_array_equal(out[k], ref[k], err_msg=k)


# ------------------------------------------------ static bit-identity --

@LEGACY_OK
def test_static_records_bit_identical_to_pr3_golden():
    """simulate() (the legacy shim) through the DispatchEngine interface
    == the records the pre-interface engine produced, every field, every
    bit — both via the default engine and an explicit StaticDispatch()."""
    from repro.core.simulator import simulate

    fix = _golden()
    prof = paper_fleet()
    for entry in fix["records"]:
        for dispatch in (None, StaticDispatch()):
            recs = simulate(prof, SimConfig(**entry["config"]),
                            dispatch=dispatch)
            assert set(recs) == set(entry["records"])
            for k, v in entry["records"].items():
                np.testing.assert_array_equal(
                    np.asarray(recs[k], np.float64), np.asarray(v),
                    err_msg=f"{entry['config']}:{k}")


@LEGACY_OK
def test_static_sweep_bit_identical_to_pr3_golden():
    from repro.core.simulator import sweep_grid

    fix = _golden()["sweep"]
    kw = dict(policies=tuple(fix["policies"]),
              user_levels=tuple(fix["user_levels"]),
              seeds=tuple(fix["seeds"]), n_requests=fix["n_requests"])
    for dispatch in (None, StaticDispatch()):
        m = sweep_grid(paper_fleet(), dispatch=dispatch, **kw)
        for k, v in fix["metrics"].items():
            np.testing.assert_array_equal(m[k], np.asarray(v), err_msg=k)
    assert isinstance(default_dispatch(), StaticDispatch)


# -------------------------------------------- online batching axes --

def test_online_single_equals_batched_row():
    """The vmap invariant holds for OnlineDispatch exactly as for the
    static engine: each row of a mixed-n_users batch equals its own
    unpadded single run, EWMA state and all."""
    prof = paper_fleet()
    od = OnlineDispatch()
    cfgs = [SimConfig(n_users=u, n_requests=200, policy="MO", seed=u)
            for u in (2, 6, 11)]
    grid = _make_grid(prof, cfgs, dispatch=od)
    recs = _simulate_batch(prof, grid, n_requests=200, dispatch=od)
    for i, cfg in enumerate(cfgs):
        ref = records(Scenario(n_users=cfg.n_users, n_requests=200,
                               policy="MO", seed=cfg.seed, dispatch=od))
        for k in ref:
            np.testing.assert_array_equal(np.asarray(recs[k][i]),
                                          np.asarray(ref[k]), err_msg=k)


def test_online_sharded_equals_single_on_local_mesh():
    """shard_map path == plain vmap path for an online grid, bit for bit
    (the DispatchState rides inside each shard's scan; no collectives)."""
    sc = Scenario(n_requests=250, dispatch=OnlineDispatch())
    sw = Sweep(policy=("MO", "LT"), n_users=(3, 7), seed=(0, 1))
    ref = run(sc, sw)
    out = run(replace(sc, mesh="local"), sw)
    for k in ref.metric_names:
        np.testing.assert_array_equal(out[k], ref[k], err_msg=k)


def test_online_fleet_stacked_matches_per_fleet():
    """An online grid fuses over a stacked fleet ensemble unchanged: the
    (F, ...) sweep equals each fleet's own single sweep."""
    fleets = [synthetic_fleet(jax.random.PRNGKey(i), 5) for i in range(2)]
    ens = stack_profiles(fleets)
    sw = Sweep(policy=("MO",), n_users=(4, 8), seed=(0,))
    m = run(Scenario(profile=ens, n_requests=250,
                     dispatch=OnlineDispatch()), sw)
    assert m.axes[0] == "fleet"
    assert m["latency_ms"].shape == (2, 1, 2, 1)
    for f, fleet in enumerate(fleets):
        ref = run(Scenario(profile=fleet, n_requests=250,
                           dispatch=OnlineDispatch()), sw)
        for k in ref.metric_names:
            np.testing.assert_array_equal(m[k][f], ref[k], err_msg=k)


def test_drifted_grid_vmaps_and_shards():
    """A DriftSchedule is grid data like the profile table: drifted sweeps
    shard bit-identically and batched rows equal single runs."""
    prof = paper_fleet()
    drift = DriftSchedule.throttle(prof, 4, at_step=80, t_mult=3.0,
                                   e_mult=8.0)
    sc = Scenario(profile=prof, n_requests=250, drift=drift)
    sw = Sweep(policy=("MO", "LC"), n_users=(3, 7), seed=(0,))
    ref = run(sc, sw)
    out = run(replace(sc, mesh="local"), sw)
    _assert_metrics_equal({k: out[k] for k in out.metric_names},
                          {k: ref[k] for k in ref.metric_names})
    drec = records(Scenario(profile=prof, n_users=3, n_requests=150,
                            seed=3, drift=drift),
                   Sweep(n_users=(3, 9), seed=(3, 9)))
    for i, u in enumerate((3, 9)):
        one = records(Scenario(profile=prof, n_users=u, n_requests=150,
                               seed=u, drift=drift))
        for k in one:
            np.testing.assert_array_equal(np.asarray(drec[k][i, i]),
                                          np.asarray(one[k]), err_msg=k)


# --------------------------------------------- drift / adaptation --

def test_online_dominates_static_under_drift_and_matches_without():
    """The acceptance check: when the fleet's energy-favourite pair loses
    its low-power state mid-run (3x slower, 8x the energy), online-MO
    strictly beats static-MO on BOTH mean latency and energy for every
    seed — the EWMA re-converges while the static table keeps routing on
    stale numbers. With no drift the two are indistinguishable (with an
    oracle estimator every observation equals the prior, so the belief
    tables never move)."""
    prof = paper_fleet()
    drift = DriftSchedule.throttle(prof, 4, at_step=400, t_mult=3.0,
                                   e_mult=8.0)
    sc = Scenario(profile=prof, policy="MO", n_users=10, n_requests=2000,
                  oracle_estimator=True)
    sw = Sweep(seed=(0, 1))
    stat = run(replace(sc, drift=drift), sw)
    onl = run(replace(sc, drift=drift, dispatch=OnlineDispatch()), sw)
    assert (onl["latency_ms"] < stat["latency_ms"]).all()
    assert (onl["energy_mwh"] < stat["energy_mwh"]).all()

    stat0 = run(sc, sw)
    onl0 = run(replace(sc, dispatch=OnlineDispatch()), sw)
    for k in stat0.metric_names:
        np.testing.assert_allclose(onl0[k], stat0[k], rtol=1e-5, err_msg=k)


def test_windowed_online_reconverges_faster_after_drift():
    """The forgetting satellite (ROADMAP drift-detection item): under the
    canonical DriftSchedule.throttle harness, the sliding-window variant
    routes measurably better than plain annealing while the fleet is
    drifted.

    Both engines start from identical hot beliefs (every cell has seen
    the offline truth often enough that the annealed step is at full
    ``alpha`` and the window prior has washed out), then the throttle
    hits and each engine routes + observes against the DRIFTED truth.
    "Post-drift latency" is the true service time of each engine's own
    choices: the windowed belief is fully post-drift after ``window``
    observations of a cell, while the annealed belief still carries
    ~0.9^n of the stale evidence, so the windowed engine must reroute
    sooner and pay strictly less."""
    prof = paper_fleet()
    drift = DriftSchedule.throttle(prof, 4, at_step=400, t_mult=3.0,
                                   e_mult=8.0)
    drifted = drift.at_step(prof, 400)
    code = POLICY_CODES["MO"]
    q = jnp.zeros(prof.n_pairs)
    key = jax.random.PRNGKey(0)

    def replay(engine, n_steps=64):
        st = engine.init(prof)
        for _ in range(12):                    # hot pre-drift beliefs
            for p in range(prof.n_pairs):
                for g in range(prof.n_groups):
                    st = engine.observe(st, p, g, prof.T[p, g],
                                        prof.E[p, g])
        lat = []
        for t in range(n_steps):
            g = t % prof.n_groups
            p, st = engine.select(st, prof, code, jnp.asarray(g), q, key,
                                  jnp.asarray(0.5), jnp.asarray(20.0))
            lat.append(float(drifted.T[int(p), g]))
            st = engine.observe(st, int(p), g, drifted.T[int(p), g],
                                drifted.E[int(p), g])
        return float(np.mean(lat)), st

    annealed, _ = replay(OnlineDispatch())
    for w in (8, 16):
        windowed, _ = replay(OnlineDispatch(window=w))
        assert windowed < annealed, (w, windowed, annealed)

    # estimator-level: after exactly W post-drift observations of one
    # hot cell, the windowed belief IS the drifted truth while the
    # annealed belief still carries ~0.9^W of the stale gap
    w = 8
    an, wd = OnlineDispatch(), OnlineDispatch(window=w)
    st_a, st_w = an.init(prof), wd.init(prof)
    for _ in range(50):                        # hot pre-drift cell
        st_a = an.observe(st_a, 4, 2, prof.T[4, 2], prof.E[4, 2])
        st_w = wd.observe(st_w, 4, 2, prof.T[4, 2], prof.E[4, 2])
    for _ in range(w):                         # w post-drift observations
        st_a = an.observe(st_a, 4, 2, drifted.T[4, 2], drifted.E[4, 2])
        st_w = wd.observe(st_w, 4, 2, drifted.T[4, 2], drifted.E[4, 2])
    truth = float(drifted.T[4, 2])
    gap0 = truth - float(prof.T[4, 2])
    win_err = abs(float(wd.tables(st_w, prof).T[4, 2]) - truth)
    ann_err = abs(float(an.tables(st_a, prof).T[4, 2]) - truth)
    assert win_err < 1e-3 * gap0               # fully re-converged
    assert ann_err > 0.25 * gap0               # annealing still lags
    assert win_err < ann_err


def test_drift_records_reflect_true_tables():
    """Before start_step the drifted run is bit-identical to the undrifted
    one; after it, the records' energies come from the drifted table."""
    prof = paper_fleet()
    drift = DriftSchedule.throttle(prof, 4, at_step=100, t_mult=2.0,
                                   e_mult=8.0)
    sc = Scenario(profile=prof, n_users=6, n_requests=300, policy="LC",
                  seed=2, oracle_estimator=True)
    base = records(sc)
    dr = records(replace(sc, drift=drift))
    for k in base:
        np.testing.assert_array_equal(np.asarray(base[k][:100]),
                                      np.asarray(dr[k][:100]), err_msg=k)
    srv = np.asarray(dr["server"][100:])
    en = np.asarray(dr["energy"][100:])
    g = np.asarray(dr["g_true"][100:])
    E = np.asarray(prof.E)
    hit = srv == 4
    assert hit.any()
    np.testing.assert_allclose(en[hit], 8.0 * E[4, g[hit]], rtol=1e-6)
    np.testing.assert_allclose(en[~hit], E[srv[~hit], g[~hit]], rtol=1e-6)


def test_drift_schedule_validates_and_segments():
    prof = paper_fleet()
    with pytest.raises(ValueError, match="beginning at 0"):
        DriftSchedule(np.array([5, 10]), np.ones((2, 5, 5)),
                      np.ones((2, 5, 5)))
    with pytest.raises(ValueError, match="ascending"):
        DriftSchedule(np.array([0, 50, 50]), np.ones((3, 5, 5)),
                      np.ones((3, 5, 5)))
    sched = DriftSchedule.throttle(prof, 1, at_step=50, t_mult=2.0,
                                   e_mult=3.0, recover_step=90)
    assert sched.n_segments == 3
    for step, mult in ((0, 1.0), (49, 1.0), (50, 2.0), (89, 2.0),
                       (90, 1.0)):
        tbl = sched.at_step(prof, step)
        np.testing.assert_allclose(np.asarray(tbl.T[1]),
                                   mult * np.asarray(prof.T[1]))
        np.testing.assert_array_equal(np.asarray(tbl.T[0]),
                                      np.asarray(prof.T[0]))
        np.testing.assert_array_equal(np.asarray(tbl.mAP),
                                      np.asarray(prof.mAP))


# ------------------------------------------------- grid plumbing --

def test_grid_rejects_mixed_dispatch_engines():
    prof = paper_fleet()
    a, b = OnlineDispatch(), OnlineDispatch(alpha=0.3)
    cfgs = [SimConfig(n_users=3, n_requests=50, dispatch=a),
            SimConfig(n_users=3, n_requests=50, dispatch=b)]
    with pytest.raises(ValueError, match="share a single dispatch"):
        _make_grid(prof, cfgs)
    with pytest.raises(ValueError, match="conflicts"):
        _make_grid(prof, cfgs[:1], dispatch=b)
    _make_grid(prof, cfgs[:1])                 # cfg-carried engine works
    # engines are value-compared: separately constructed equal engines
    # (same hyper-parameters) are ONE engine, not a mix
    _make_grid(prof, [SimConfig(n_users=3, n_requests=50,
                                dispatch=OnlineDispatch())
                      for _ in range(2)])
    _make_grid(prof, cfgs[:1], dispatch=OnlineDispatch())
    # the config's own engine drives the engine exactly like dispatch=
    cfg = SimConfig(n_users=4, n_requests=150, seed=3, dispatch=a)
    ref = _simulate(prof, SimConfig(n_users=4, n_requests=150, seed=3),
                    dispatch=a)
    out = _simulate(prof, cfg)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(ref[k]), err_msg=k)


def test_engine_observe_window_default_matches_batched_override():
    """The base-class observe_window (a loop over observe) and
    OnlineDispatch's fused override agree, so custom engines that only
    implement observe get correct windowed behaviour from the gateway."""
    from repro.core.dispatch import DispatchEngine

    prof = paper_fleet()
    od = OnlineDispatch(alpha=0.2, prior_weight=5.0)
    rng = np.random.default_rng(3)
    W = 24
    ps = rng.integers(0, prof.n_pairs, W)
    gs = rng.integers(0, prof.n_groups, W)
    ts = rng.uniform(80.0, 400.0, W).astype(np.float32)
    es = rng.uniform(0.02, 0.4, W).astype(np.float32)
    looped = DispatchEngine.observe_window(od, od.init(prof), ps, gs, ts,
                                           es)
    fused = od.observe_window(od.init(prof), ps, gs, ts, es)
    for k in ("T", "E", "count", "rr"):
        np.testing.assert_allclose(np.asarray(looped[k]),
                                   np.asarray(fused[k]), rtol=1e-6,
                                   err_msg=k)
    # the static engine discards windows and is flagged non-adaptive
    sd = StaticDispatch()
    assert not sd.adaptive and OnlineDispatch.adaptive
    assert sd.observe_window({"rr": 0}, ps, gs, ts, es) == {"rr": 0}
    # the windowed variant's sequential fold preserves ring-buffer order
    wd = OnlineDispatch(window=6)
    seq = wd.init(prof)
    for i in range(W):
        seq = wd.observe(seq, ps[i], gs[i], ts[i], es[i])
    win = wd.observe_window(wd.init(prof), ps, gs, ts, es)
    for k in ("tsum", "esum", "count", "ecount"):
        np.testing.assert_allclose(np.asarray(seq[k]), np.asarray(win[k]),
                                   rtol=1e-6, err_msg=k)


def test_sim_config_with_dispatch_stays_hashable():
    a = SimConfig(n_users=3, dispatch=OnlineDispatch())
    b = SimConfig(n_users=3)
    assert hash(a) == hash(b) and a == b
    assert len({a, b}) == 1


# --------------------------------------- forced 4-device subprocess --

_SUBPROC_CHECK = """
import json, warnings
import jax, numpy as np
from repro.core.dispatch import DriftSchedule, OnlineDispatch
from repro.core.profiles import paper_fleet
from repro.core.scenario import LegacyAPIWarning, Scenario, Sweep, run
from repro.core.simulator import sweep_grid
from repro.launch.mesh import make_sweep_mesh

warnings.simplefilter("ignore", LegacyAPIWarning)   # legacy on purpose
assert len(jax.devices()) == 4, jax.devices()
prof = paper_fleet()
mesh = make_sweep_mesh()

# StaticDispatch regression vs the PR 3 golden fixture on a real 4-device
# mesh, via BOTH the legacy kwarg shim and the Scenario path: neither may
# move a single bit even sharded.
fix = json.load(open({golden!r}))["sweep"]
kw = dict(policies=tuple(fix["policies"]),
          user_levels=tuple(fix["user_levels"]),
          seeds=tuple(fix["seeds"]), n_requests=fix["n_requests"])
gold = sweep_grid(prof, mesh=mesh, **kw)
res = run(Scenario(profile=prof, n_requests=fix["n_requests"],
                   mesh="local"),
          Sweep(policy=tuple(fix["policies"]),
                n_users=tuple(fix["user_levels"]),
                seed=tuple(fix["seeds"])))
for k, v in fix["metrics"].items():
    want = np.asarray(v)
    np.testing.assert_array_equal(gold[k], want, err_msg="legacy:" + k)
    np.testing.assert_array_equal(res[k], want.reshape(res[k].shape),
                                  err_msg="scenario:" + k)

# Online: sharded == single on 4 real devices, bit for bit (scenario path).
osc = Scenario(profile=prof, n_requests=150, dispatch=OnlineDispatch())
osw = Sweep(policy=("MO", "LT"), n_users=(3, 7), seed=(0,))
ref = run(osc, osw)
out = run(osc, osw, mesh=mesh)
for k in ref.metric_names:
    np.testing.assert_array_equal(out[k], ref[k], err_msg=k)

# Online + drift: bitwise except the percentile metric, which tolerates
# one float32 ULP — XLA's FMA contraction of the percentile interpolation
# varies with the compiled batch shape (see _assert_metrics_equal).
drift = DriftSchedule.throttle(prof, 4, at_step=40, t_mult=3.0, e_mult=8.0)
dsc = Scenario(profile=prof, n_requests=150, dispatch=OnlineDispatch(),
               drift=drift)
ref = run(dsc, osw)
out = run(dsc, osw, mesh=mesh)
for k in ref.metric_names:
    if k == "latency_p90_ms":
        np.testing.assert_allclose(out[k], ref[k], rtol=3e-7, err_msg=k)
    else:
        np.testing.assert_array_equal(out[k], ref[k], err_msg=k)
print("OK")
"""


def test_dispatch_bitwise_in_forced_4_device_subprocess():
    """Real multi-device bit-exactness for the dispatch interface, via
    xla_force_host_platform_device_count=4 in a fresh process: the static
    path still reproduces the PR 3 golden metrics sharded — through the
    legacy shim AND the Scenario path — and an online + drifted sweep is
    sharded == single."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=str(REPO / "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    src = _SUBPROC_CHECK.format(golden=str(GOLDEN))
    res = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout
