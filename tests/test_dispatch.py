"""The dispatch-state contract (ISSUE 4): the default ``StaticDispatch``
path through the ``DispatchEngine`` interface reproduces the PR 3 engine
bit for bit, ``OnlineDispatch`` grids keep every batching axis (vmap /
mesh sharding / fleet stacking), and under a ``DriftSchedule`` online-MO
strictly dominates static-MO on latency and energy while matching it with
no drift.

The golden fixture (``golden_static_pr3.json``) was captured from the
engine at PR 3 (commit a548684), before ``DispatchEngine`` existed — do
not regenerate it from current code, that would defeat the regression.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core.dispatch import (DriftSchedule, OnlineDispatch,
                                 StaticDispatch, default_dispatch)
from repro.core.profiles import paper_fleet, stack_profiles, synthetic_fleet
from repro.core.simulator import (SimConfig, make_grid, simulate,
                                  simulate_batch, sweep_grid)
from repro.launch.mesh import make_sweep_mesh

REPO = Path(__file__).resolve().parent.parent
GOLDEN = REPO / "tests" / "golden_static_pr3.json"


def _golden():
    with open(GOLDEN) as f:
        return json.load(f)


def _assert_metrics_equal(out, ref):
    """Bit-equality for every sweep metric except ``latency_p90_ms``,
    which gets a 1-ULP tolerance: ``jnp.percentile``'s linear
    interpolation (``lo + frac * (hi - lo)``) is an FMA-contraction
    candidate and XLA's choice varies with the compiled batch shape, so
    sharded vs single runs of bit-identical records can differ by one
    float32 ULP in that metric alone (drifted latency values expose it;
    see the FMA note in tests/test_workload_sources.py for the PR 3
    precedent)."""
    for k in ref:
        if k == "latency_p90_ms":
            np.testing.assert_allclose(out[k], ref[k], rtol=3e-7,
                                       err_msg=k)
        else:
            np.testing.assert_array_equal(out[k], ref[k], err_msg=k)


# ------------------------------------------------ static bit-identity --

def test_static_records_bit_identical_to_pr3_golden():
    """simulate() through the DispatchEngine interface == the records the
    pre-interface engine produced, every field, every bit — both via the
    default engine and an explicit StaticDispatch()."""
    fix = _golden()
    prof = paper_fleet()
    for entry in fix["records"]:
        for dispatch in (None, StaticDispatch()):
            recs = simulate(prof, SimConfig(**entry["config"]),
                            dispatch=dispatch)
            assert set(recs) == set(entry["records"])
            for k, v in entry["records"].items():
                np.testing.assert_array_equal(
                    np.asarray(recs[k], np.float64), np.asarray(v),
                    err_msg=f"{entry['config']}:{k}")


def test_static_sweep_bit_identical_to_pr3_golden():
    fix = _golden()["sweep"]
    kw = dict(policies=tuple(fix["policies"]),
              user_levels=tuple(fix["user_levels"]),
              seeds=tuple(fix["seeds"]), n_requests=fix["n_requests"])
    for dispatch in (None, StaticDispatch()):
        m = sweep_grid(paper_fleet(), dispatch=dispatch, **kw)
        for k, v in fix["metrics"].items():
            np.testing.assert_array_equal(m[k], np.asarray(v), err_msg=k)
    assert isinstance(default_dispatch(), StaticDispatch)


# -------------------------------------------- online batching axes --

def test_online_single_equals_batched_row():
    """The vmap invariant holds for OnlineDispatch exactly as for the
    static engine: each row of a mixed-n_users batch equals its own
    unpadded single run, EWMA state and all."""
    prof = paper_fleet()
    od = OnlineDispatch()
    cfgs = [SimConfig(n_users=u, n_requests=200, policy="MO", seed=u)
            for u in (2, 6, 11)]
    grid = make_grid(prof, cfgs, dispatch=od)
    recs = simulate_batch(prof, grid, n_requests=200, dispatch=od)
    for i, cfg in enumerate(cfgs):
        ref = simulate(prof, cfg, dispatch=od)
        for k in ref:
            np.testing.assert_array_equal(np.asarray(recs[k][i]),
                                          np.asarray(ref[k]), err_msg=k)


def test_online_sharded_equals_single_on_local_mesh():
    """shard_map path == plain vmap path for an online grid, bit for bit
    (the DispatchState rides inside each shard's scan; no collectives)."""
    kw = dict(policies=("MO", "LT"), user_levels=(3, 7), seeds=(0, 1),
              n_requests=250, dispatch=OnlineDispatch())
    ref = sweep_grid(paper_fleet(), **kw)
    out = sweep_grid(paper_fleet(), mesh=make_sweep_mesh(), **kw)
    for k in ref:
        np.testing.assert_array_equal(out[k], ref[k], err_msg=k)


def test_online_fleet_stacked_matches_per_fleet():
    """An online grid fuses over a stacked fleet ensemble unchanged: the
    (F, ...) sweep equals each fleet's own single sweep."""
    fleets = [synthetic_fleet(jax.random.PRNGKey(i), 5) for i in range(2)]
    ens = stack_profiles(fleets)
    kw = dict(policies=("MO",), user_levels=(4, 8), seeds=(0,),
              n_requests=250, dispatch=OnlineDispatch())
    m = sweep_grid(ens, **kw)
    assert m["latency_ms"].shape == (2, 1, 2, 1, 1, 1, 1)
    for f, fleet in enumerate(fleets):
        ref = sweep_grid(fleet, **kw)
        for k in ref:
            np.testing.assert_array_equal(m[k][f], ref[k], err_msg=k)


def test_drifted_grid_vmaps_and_shards():
    """A DriftSchedule is grid data like the profile table: drifted sweeps
    shard bit-identically and batched rows equal single runs."""
    prof = paper_fleet()
    drift = DriftSchedule.throttle(prof, 4, at_step=80, t_mult=3.0,
                                   e_mult=8.0)
    kw = dict(policies=("MO", "LC"), user_levels=(3, 7), seeds=(0,),
              n_requests=250, drift=drift)
    ref = sweep_grid(prof, **kw)
    out = sweep_grid(prof, mesh=make_sweep_mesh(), **kw)
    _assert_metrics_equal(out, ref)
    cfgs = [SimConfig(n_users=u, n_requests=150, seed=u) for u in (3, 9)]
    grid = make_grid(prof, cfgs)
    recs = simulate_batch(prof, grid, n_requests=150, drift=drift)
    for i, cfg in enumerate(cfgs):
        one = simulate(prof, cfg, drift=drift)
        for k in one:
            np.testing.assert_array_equal(np.asarray(recs[k][i]),
                                          np.asarray(one[k]), err_msg=k)


# --------------------------------------------- drift / adaptation --

def test_online_dominates_static_under_drift_and_matches_without():
    """The acceptance check: when the fleet's energy-favourite pair loses
    its low-power state mid-run (3x slower, 8x the energy), online-MO
    strictly beats static-MO on BOTH mean latency and energy for every
    seed — the EWMA re-converges while the static table keeps routing on
    stale numbers. With no drift the two are indistinguishable (with an
    oracle estimator every observation equals the prior, so the belief
    tables never move)."""
    prof = paper_fleet()
    drift = DriftSchedule.throttle(prof, 4, at_step=400, t_mult=3.0,
                                   e_mult=8.0)
    kw = dict(policies=("MO",), user_levels=(10,), seeds=(0, 1),
              n_requests=2000, oracle=(True,))
    stat = sweep_grid(prof, drift=drift, **kw)
    onl = sweep_grid(prof, drift=drift, dispatch=OnlineDispatch(), **kw)
    sl = stat["latency_ms"][0, 0, 0, 0, 0, :]
    ol = onl["latency_ms"][0, 0, 0, 0, 0, :]
    se = stat["energy_mwh"][0, 0, 0, 0, 0, :]
    oe = onl["energy_mwh"][0, 0, 0, 0, 0, :]
    assert (ol < sl).all(), (ol, sl)
    assert (oe < se).all(), (oe, se)

    stat0 = sweep_grid(prof, **kw)
    onl0 = sweep_grid(prof, dispatch=OnlineDispatch(), **kw)
    for k in stat0:
        np.testing.assert_allclose(onl0[k], stat0[k], rtol=1e-5, err_msg=k)


def test_drift_records_reflect_true_tables():
    """Before start_step the drifted run is bit-identical to the undrifted
    one; after it, the records' energies come from the drifted table."""
    prof = paper_fleet()
    drift = DriftSchedule.throttle(prof, 4, at_step=100, t_mult=2.0,
                                   e_mult=8.0)
    cfg = SimConfig(n_users=6, n_requests=300, policy="LC", seed=2,
                    oracle_estimator=True)
    base = simulate(prof, cfg)
    dr = simulate(prof, cfg, drift=drift)
    for k in base:
        np.testing.assert_array_equal(np.asarray(base[k][:100]),
                                      np.asarray(dr[k][:100]), err_msg=k)
    srv = np.asarray(dr["server"][100:])
    en = np.asarray(dr["energy"][100:])
    g = np.asarray(dr["g_true"][100:])
    E = np.asarray(prof.E)
    hit = srv == 4
    assert hit.any()
    np.testing.assert_allclose(en[hit], 8.0 * E[4, g[hit]], rtol=1e-6)
    np.testing.assert_allclose(en[~hit], E[srv[~hit], g[~hit]], rtol=1e-6)


def test_drift_schedule_validates_and_segments():
    prof = paper_fleet()
    with pytest.raises(ValueError, match="beginning at 0"):
        DriftSchedule(np.array([5, 10]), np.ones((2, 5, 5)),
                      np.ones((2, 5, 5)))
    with pytest.raises(ValueError, match="ascending"):
        DriftSchedule(np.array([0, 50, 50]), np.ones((3, 5, 5)),
                      np.ones((3, 5, 5)))
    sched = DriftSchedule.throttle(prof, 1, at_step=50, t_mult=2.0,
                                   e_mult=3.0, recover_step=90)
    assert sched.n_segments == 3
    for step, mult in ((0, 1.0), (49, 1.0), (50, 2.0), (89, 2.0),
                       (90, 1.0)):
        tbl = sched.at_step(prof, step)
        np.testing.assert_allclose(np.asarray(tbl.T[1]),
                                   mult * np.asarray(prof.T[1]))
        np.testing.assert_array_equal(np.asarray(tbl.T[0]),
                                      np.asarray(prof.T[0]))
        np.testing.assert_array_equal(np.asarray(tbl.mAP),
                                      np.asarray(prof.mAP))


# ------------------------------------------------- grid plumbing --

def test_grid_rejects_mixed_dispatch_engines():
    prof = paper_fleet()
    a, b = OnlineDispatch(), OnlineDispatch(alpha=0.3)
    cfgs = [SimConfig(n_users=3, n_requests=50, dispatch=a),
            SimConfig(n_users=3, n_requests=50, dispatch=b)]
    with pytest.raises(ValueError, match="share a single dispatch"):
        make_grid(prof, cfgs)
    with pytest.raises(ValueError, match="conflicts"):
        make_grid(prof, cfgs[:1], dispatch=b)
    make_grid(prof, cfgs[:1])                  # cfg-carried engine works
    # engines are value-compared: separately constructed equal engines
    # (same hyper-parameters) are ONE engine, not a mix
    make_grid(prof, [SimConfig(n_users=3, n_requests=50,
                               dispatch=OnlineDispatch())
                     for _ in range(2)])
    make_grid(prof, cfgs[:1], dispatch=OnlineDispatch())
    # the config's own engine drives simulate() exactly like dispatch=
    cfg = SimConfig(n_users=4, n_requests=150, seed=3, dispatch=a)
    ref = simulate(prof, SimConfig(n_users=4, n_requests=150, seed=3),
                   dispatch=a)
    out = simulate(prof, cfg)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(ref[k]), err_msg=k)


def test_engine_observe_window_default_matches_batched_override():
    """The base-class observe_window (a loop over observe) and
    OnlineDispatch's fused override agree, so custom engines that only
    implement observe get correct windowed behaviour from the gateway."""
    from repro.core.dispatch import DispatchEngine

    prof = paper_fleet()
    od = OnlineDispatch(alpha=0.2, prior_weight=5.0)
    rng = np.random.default_rng(3)
    W = 24
    ps = rng.integers(0, prof.n_pairs, W)
    gs = rng.integers(0, prof.n_groups, W)
    ts = rng.uniform(80.0, 400.0, W).astype(np.float32)
    es = rng.uniform(0.02, 0.4, W).astype(np.float32)
    looped = DispatchEngine.observe_window(od, od.init(prof), ps, gs, ts,
                                           es)
    fused = od.observe_window(od.init(prof), ps, gs, ts, es)
    for k in ("T", "E", "count", "rr"):
        np.testing.assert_allclose(np.asarray(looped[k]),
                                   np.asarray(fused[k]), rtol=1e-6,
                                   err_msg=k)
    # the static engine discards windows and is flagged non-adaptive
    sd = StaticDispatch()
    assert not sd.adaptive and OnlineDispatch.adaptive
    assert sd.observe_window({"rr": 0}, ps, gs, ts, es) == {"rr": 0}


def test_sim_config_with_dispatch_stays_hashable():
    a = SimConfig(n_users=3, dispatch=OnlineDispatch())
    b = SimConfig(n_users=3)
    assert hash(a) == hash(b) and a == b
    assert len({a, b}) == 1


# --------------------------------------- forced 4-device subprocess --

_SUBPROC_CHECK = """
import json, jax, numpy as np
from repro.core.dispatch import DriftSchedule, OnlineDispatch
from repro.core.profiles import paper_fleet
from repro.core.simulator import sweep_grid
from repro.launch.mesh import make_sweep_mesh

assert len(jax.devices()) == 4, jax.devices()
prof = paper_fleet()
mesh = make_sweep_mesh()

# StaticDispatch regression vs the PR 3 golden fixture on a real 4-device
# mesh: the dispatch refactor must not move a single bit even sharded.
fix = json.load(open({golden!r}))["sweep"]
kw = dict(policies=tuple(fix["policies"]),
          user_levels=tuple(fix["user_levels"]),
          seeds=tuple(fix["seeds"]), n_requests=fix["n_requests"])
gold = sweep_grid(prof, mesh=mesh, **kw)
for k, v in fix["metrics"].items():
    np.testing.assert_array_equal(gold[k], np.asarray(v), err_msg=k)

# Online: sharded == single on 4 real devices, bit for bit.
okw = dict(policies=("MO", "LT"), user_levels=(3, 7), seeds=(0,),
           n_requests=150, dispatch=OnlineDispatch())
ref = sweep_grid(prof, **okw)
out = sweep_grid(prof, mesh=mesh, **okw)
for k in ref:
    np.testing.assert_array_equal(out[k], ref[k], err_msg=k)

# Online + drift: bitwise except the percentile metric, which tolerates
# one float32 ULP — XLA's FMA contraction of the percentile interpolation
# varies with the compiled batch shape (see _assert_metrics_equal).
drift = DriftSchedule.throttle(prof, 4, at_step=40, t_mult=3.0, e_mult=8.0)
dkw = dict(okw, drift=drift)
ref = sweep_grid(prof, **dkw)
out = sweep_grid(prof, mesh=mesh, **dkw)
for k in ref:
    if k == "latency_p90_ms":
        np.testing.assert_allclose(out[k], ref[k], rtol=3e-7, err_msg=k)
    else:
        np.testing.assert_array_equal(out[k], ref[k], err_msg=k)
print("OK")
"""


def test_dispatch_bitwise_in_forced_4_device_subprocess():
    """Real multi-device bit-exactness for the dispatch interface, via
    xla_force_host_platform_device_count=4 in a fresh process: the static
    path still reproduces the PR 3 golden metrics sharded, and an online
    + drifted sweep is sharded == single."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=str(REPO / "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    src = _SUBPROC_CHECK.format(golden=str(GOLDEN))
    res = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout
