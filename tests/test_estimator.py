"""Estimator properties (hypothesis where it pays)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.estimator import (group_of_count, markov_transition,
                                  noisy_detected_count, stationary)


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 8), st.floats(0.5, 0.99), st.floats(0.5, 0.9))
def test_transition_is_stochastic(n, stick, drift):
    P = np.asarray(markov_transition(n, stick, drift))
    np.testing.assert_allclose(P.sum(1), 1.0, rtol=1e-5)
    assert (P >= -1e-9).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 8),
       st.floats(1.0, 99.0))
def test_detected_count_bounds(seed, true_count, map_pg):
    rng = jax.random.PRNGKey(seed)
    det = noisy_detected_count(rng, jnp.asarray(true_count),
                               jnp.asarray(map_pg))
    assert 0 <= int(det) <= min(true_count, 8) + 1   # +1 false positive


def test_detection_monotone_in_accuracy():
    """Expected detected count increases with mAP (1000-sample means)."""
    rngs = jax.random.split(jax.random.PRNGKey(0), 1000)
    def mean_det(m):
        f = jax.vmap(lambda r: noisy_detected_count(
            r, jnp.asarray(4), jnp.asarray(m)))
        return float(jnp.mean(f(rngs)))
    assert mean_det(90.0) > mean_det(10.0)
    assert mean_det(90.0) > 3.5      # strong detectors count ~right


def test_group_of_count_clips():
    assert int(group_of_count(jnp.asarray(0))) == 0
    assert int(group_of_count(jnp.asarray(4))) == 4
    assert int(group_of_count(jnp.asarray(99))) == 4


def test_stationary_skewed_up():
    pi = np.asarray(stationary(markov_transition(5, 0.85, 0.62)))
    assert pi.argmax() >= 2          # busy-crossing: mass on complex scenes
    np.testing.assert_allclose(pi.sum(), 1.0, rtol=1e-4)
