"""The batched request plane: windowed routing is bit-identical to
sequential routing under ANY window partition, the per-request Gateway is
a faithful shim, async executor accounting never goes negative, and the
windowed hot path actually delivers batched throughput."""

import time
import warnings

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import online as ONL
from repro.core.dispatch import OnlineDispatch, StaticDispatch
from repro.core.profiles import paper_fleet
from repro.core.scenario import LegacyAPIWarning, Scenario
from repro.kernels.moscore import moscore_route, resolve_backend
from repro.serving import (AsyncExecutorPool, Gateway, ServingPlane,
                           WindowedGateway)

PROF = paper_fleet()
P = PROF.n_pairs


def _drive(gw: WindowedGateway, streams, window: int, observe: bool):
    """Route ``streams`` through ``gw`` in windows of ``window``, threading
    the returned queue depths, observing each window on completion with
    deterministic measurements. Returns (pairs, final stream counts)."""
    q = np.zeros(P, np.float32)
    T, E = np.asarray(PROF.T), np.asarray(PROF.E)
    out = []
    for i in range(0, len(streams), window):
        chunk = streams[i:i + window]
        pairs, gs, q = gw.route_window(chunk, q)
        pairs, gs = np.asarray(pairs), np.asarray(gs)
        out.append(pairs)
        if observe:
            gw.observe_window(pairs, gs, 1.5 * T[pairs, gs],
                              2.0 * E[pairs, gs])
            gw.observe_detections_window(chunk, (np.asarray(chunk) + i) % 5)
    return np.concatenate(out), np.asarray(gw._counts)


@pytest.mark.parametrize("dispatch", [StaticDispatch(), OnlineDispatch(),
                                      OnlineDispatch(window=16)])
@pytest.mark.parametrize("window", [4, 64])
def test_windowed_matches_sequential_bit_exact(dispatch, window):
    """Tentpole acceptance: window=N and window=1 drives of the SAME
    request stream make identical decisions and leave identical
    device-resident stream counts — for static and online dispatch.
    Observations land at the coarser window's boundaries in both drives,
    so the belief-state trajectory is shared too."""
    rng = np.random.default_rng(7)
    streams = rng.integers(0, 24, size=192)
    gw_n = WindowedGateway(PROF, dispatch=dispatch, seed=11)
    gw_1 = WindowedGateway(PROF, dispatch=dispatch, seed=11)
    pairs_n, counts_n = _drive(gw_n, streams, window, observe=True)

    # reference: windows of ONE, threading q manually, observing at the
    # same 'window'-sized boundaries as the batched drive
    q = np.zeros(P, np.float32)
    T, E = np.asarray(PROF.T), np.asarray(PROF.E)
    pairs_1 = []
    for i in range(0, len(streams), window):
        chunk, block = streams[i:i + window], []
        for s in chunk:
            ps, gs, q = gw_1.route_window([s], q)
            block.append((int(ps[0]), int(gs[0])))
        bp = np.asarray([p for p, _ in block])
        bg = np.asarray([g for _, g in block])
        pairs_1.extend(bp)
        gw_1.observe_window(bp, bg, 1.5 * T[bp, bg], 2.0 * E[bp, bg])
        gw_1.observe_detections_window(chunk, (np.asarray(chunk) + i) % 5)
    np.testing.assert_array_equal(pairs_n, np.asarray(pairs_1))
    np.testing.assert_array_equal(counts_n, np.asarray(gw_1._counts))


@pytest.mark.parametrize("policy", ["MO", "RND", "RR", "LT"])
def test_rng_window_size_invariance(policy):
    """Bugfix regression: two gateways with the same seed and DIFFERENT
    window sizes route identical request streams identically. The key
    stream is fold_in(key, absolute_request_index), so no partition of
    the stream into windows can change a decision (the old per-request
    chain-split made RND depend on call count)."""
    streams = list(np.random.default_rng(0).integers(0, 40, size=60))
    ref = None
    for window in (1, 3, 5, 60):
        gw = WindowedGateway(PROF, policy=policy, seed=42)
        pairs, _ = _drive(gw, streams, window, observe=False)
        if ref is None:
            ref = pairs
        else:
            np.testing.assert_array_equal(ref, pairs, err_msg=f"W={window}")


@pytest.mark.filterwarnings(
    "ignore::repro.core.scenario.LegacyAPIWarning")
def test_per_request_shim_warns_and_is_bit_identical():
    """The deprecated Gateway warns once at construction, then behaves as
    windows-of-one over the same machinery — identical decisions and
    estimator state to a WindowedGateway on the same stream."""
    with pytest.warns(LegacyAPIWarning, match="windowed request plane"):
        shim = Gateway(PROF, policy="MO", online=True, seed=5)
    win = WindowedGateway(PROF, policy="MO", online=True, seed=5)
    streams = list(np.random.default_rng(1).integers(0, 16, size=48))
    for round_ in range(2):       # detections land between windows
        pairs_w, _counts = _drive(win, streams, 48, observe=False)
        q = np.zeros(P, np.float32)
        pairs_s = []
        for s in streams:
            p, _g = shim.route(int(s), q)
            q[p] += 1.0
            pairs_s.append(p)
        np.testing.assert_array_equal(pairs_w, np.asarray(pairs_s),
                                      err_msg=f"round {round_}")
        dets = [(int(s) + round_) % 5 for s in streams]
        for s, d in zip(streams, dets):
            shim.observe_detections(int(s), d)
        win.observe_detections_window(streams, dets)
        np.testing.assert_array_equal(np.asarray(shim._counts),
                                      np.asarray(win._counts))


def test_duplicate_streams_in_window_last_wins():
    """A stream completing twice in one observation window keeps the
    LATEST count — same as a sequential replay (scatter-max trick, not
    the unspecified duplicate semantics of .at[].set)."""
    gw = WindowedGateway(PROF)
    gw.observe_detections_window([3, 7, 3, 3, 7], [1, 2, 4, 2, 9])
    counts = np.asarray(gw._counts)
    assert counts[3] == 2 and counts[7] == 9
    with pytest.raises(ValueError, match="stream id out of range"):
        gw.observe_detections_window([gw.n_streams], [1])


def test_observe_windowed_batch_matches_sequential_ring():
    """The fused ring-buffer fold == W per-request folds, bit for bit
    (order within a cell is what the sliding-window estimator is about)."""
    rng = np.random.default_rng(3)
    st0 = ONL.init_window_state(PROF, 6)
    W = 40
    pairs = rng.integers(0, P, W)
    groups = rng.integers(0, PROF.n_groups, W)
    t = rng.uniform(10, 400, W).astype(np.float32)
    e = rng.uniform(0.1, 2.0, W).astype(np.float32)
    seq = st0
    for w in range(W):
        seq = ONL.observe_windowed(seq, pairs[w], groups[w], t[w], e[w],
                                   window=6)
    bat = ONL.observe_windowed_batch(st0, pairs, groups, t, e, window=6)
    for k in seq:
        np.testing.assert_array_equal(np.asarray(seq[k]),
                                      np.asarray(bat[k]), err_msg=k)


def test_moscore_backends_bit_identical():
    """Every fp32 backend — the serving hot path's candidates — agrees
    with the XLA reference choice for choice, queue for queue."""
    rng = np.random.default_rng(5)
    gs = rng.integers(0, PROF.n_groups, 96)
    q0 = np.zeros(P, np.float32)
    outs = {b: moscore_route(PROF.T, PROF.E, PROF.mAP, gs, q0,
                             delta=15.0, gamma=0.4, backend=b)
            for b in ("pallas", "xla", "hoisted", "pallas_hoisted")}
    for b in ("pallas", "hoisted", "pallas_hoisted"):
        np.testing.assert_array_equal(np.asarray(outs[b][0]),
                                      np.asarray(outs["xla"][0]),
                                      err_msg=b)
        np.testing.assert_array_equal(np.asarray(outs[b][1]),
                                      np.asarray(outs["xla"][1]),
                                      err_msg=b)
    # auto resolves to a bit-exact fp32 backend unless the env override
    # (tested in test_quant_route.py) says otherwise
    assert resolve_backend("auto") in ("pallas", "xla", "hoisted",
                                       "pallas_hoisted")
    with pytest.raises(ValueError, match="unknown moscore backend"):
        resolve_backend("cuda")


@settings(deadline=None, max_examples=25)
@given(st.integers(1, 40), st.integers(0, 2**31 - 1))
def test_executor_pool_depths_never_negative(n_ops, seed):
    """Property (satellite): under any interleaving of window submissions
    and polls — completions surfacing out of submission order across
    pairs — queue depths stay non-negative and the pool conserves
    requests (submitted == polled + in_flight)."""
    rng = np.random.default_rng(seed)
    pool = AsyncExecutorPool(PROF)
    now = 0.0
    for _ in range(n_ops):
        if rng.random() < 0.6:
            w = int(rng.integers(1, 9))
            pool.submit_window(rng.integers(0, P, w),
                               rng.integers(0, PROF.n_groups, w), now)
        else:
            now += float(rng.uniform(0.0, 2.0))
            done = pool.poll(now)
            assert (np.diff(done.finish_s) >= 0).all()  # completion order
            assert (done.finish_s <= now).all()
        assert (pool._depth >= 0).all()
        assert pool.submitted == pool.polled + pool.in_flight
    pool.poll(np.inf)
    assert pool.in_flight == 0 and (pool._depth == 0).all()


def test_serving_plane_end_to_end():
    """ServingPlane.build(scenario): one spec through gateway, pool and
    workload; the run conserves requests and produces sane metrics."""
    sc = Scenario(policy="MO", n_users=12, seed=3)
    plane = ServingPlane.build(sc, window=32)
    assert plane.gateway.policy == "MO" and plane.n_streams == 12
    recs = plane.run(256)
    assert len(recs["latency"]) == 256
    assert plane.pool.submitted == 256 and plane.pool.in_flight == 0
    s = ServingPlane.summarize(recs)
    assert s["latency_ms"] > 0 and 0.0 <= s["estimator_acc"] <= 1.0
    # adaptive plane: observations moved the belief tables
    online = ServingPlane.build(Scenario(policy="MO", n_users=12,
                                         dispatch=OnlineDispatch()),
                                window=32)
    online.run(256)
    assert float(np.asarray(online.gateway._dstate["count"]).sum()) > 0


def test_windowed_throughput_smoke():
    """The point of the redesign: the warm windowed router clears 1e5
    routed requests/sec on the default fleet (the bench suite reports the
    real number; this is a generous floor so CI noise cannot flake it)."""
    gw = WindowedGateway(PROF, policy="MO", n_streams=1024)
    rng = np.random.default_rng(0)
    ids = rng.integers(0, 1024, size=2048 * 11)
    q0 = np.zeros(P, np.float32)
    gw.route_window(ids[:2048], q0)[0].block_until_ready()   # warm
    t0 = time.perf_counter()
    for i in range(1, 11):
        gw.route_window(ids[i * 2048:(i + 1) * 2048],
                        q0)[0].block_until_ready()
    rps = (10 * 2048) / (time.perf_counter() - t0)
    assert rps > 1e5, f"windowed router too slow: {rps:.0f} req/s"


def test_windowed_gateway_from_scenario_precedence():
    """Scenario knobs apply to defaulted kwargs; explicit kwargs win —
    same contract as the (deprecated) per-request Gateway."""
    sc = Scenario(policy="LT", gamma=0.75, delta=5.0, seed=99)
    gw = WindowedGateway(sc)
    assert (gw.policy, gw.gamma, gw.delta, gw.seed) == ("LT", 0.75, 5.0, 99)
    tweaked = WindowedGateway(sc, policy="HA", gamma=0.9)
    assert tweaked.policy == "HA" and tweaked.gamma == 0.9
    assert tweaked.delta == 5.0 and tweaked.seed == 99
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        WindowedGateway(PROF)        # primary API: no deprecation warning
