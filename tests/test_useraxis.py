"""The user axis at scale (ISSUE 7): block decomposition, left-fold
segment aggregation, streamed workload draws, and the 10^5-user
acceptance run.

Pinned contracts:
  * segment-reduced per-user aggregation is BIT-equal to the dense
    masked reduction (property-tested, incl. all-padded and single-user
    rows) — both are the same left fold in index order, the thing a
    plain ``where(mask).sum(-1)`` is not;
  * every n_users <= user_block scenario is bit-identical to the
    un-blocked engine and to the PR 2/PR 3 golden fixtures (single
    device AND a forced 4-device mesh — fixtures are pinned, never
    regenerated);
  * streamed (chunked) workload draws reassemble bitwise for any chunk
    size, Markov and trace both;
  * a multi-block config's metrics equal the left-fold combination of
    its blocks run one-by-one — with ``latency_p90_ms`` the exact
    percentile of the merged per-block latency histograms
    (partition-invariant: any K-way block split of the same pooled
    sample gives the identical merged histogram, hence the identical
    percentile);
  * one ``run()`` at n_users=10^5 completes on CPU with users/sec >=
    10x the looped per-value (dense-user) path; 10^6 runs behind
    ``REPRO_MILLION_USERS=1``.
"""

import json
import os
import subprocess
import sys
import time
from pathlib import Path

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import useraxis as UA
from repro.core.dispatch import StaticDispatch
from repro.core.profiles import paper_fleet
from repro.core.scenario import (STATIC_AXES, Scenario, Sweep, records,
                                 run)
from repro.core.simulator import (ConfigGrid, SimConfig,
                                  _expand_user_blocks, _make_user_grid,
                                  _sweep_summaries)
from repro.core.workload import MarkovWorkload
from repro.data.traces import bundled_trace

REPO = Path(__file__).resolve().parent.parent
GOLDEN_STATIC = REPO / "tests" / "golden_static_pr3.json"
GOLDEN_MARKOV = REPO / "tests" / "golden_markov_pr2.json"


def _assert_metric_equal(k, out, ref, err_msg=""):
    """Bit-equality, except ``latency_p90_ms`` across DIFFERENT compiled
    batch shapes gets the repo's 1-ULP tolerance (percentile
    interpolation is an FMA-contraction candidate; see
    tests/test_dispatch.py:_assert_metrics_equal)."""
    if k == "latency_p90_ms":
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-7, err_msg=err_msg or k)
    else:
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref),
                                      err_msg=err_msg or k)


# --------------------------------------------- block decomposition ------

def test_block_decomposition_helpers():
    assert UA.n_user_blocks(15, 1024) == 1
    assert UA.n_user_blocks(1024, 1024) == 1
    assert UA.n_user_blocks(1025, 1024) == 2
    assert UA.block_sizes(2500, 1024) == [1024, 1024, 452]
    assert UA.block_sizes(7, 16) == [7]
    np.testing.assert_array_equal(UA.block_segments([1, 3, 1]),
                                  [0, 1, 1, 1, 2])
    with pytest.raises(ValueError):
        UA.n_user_blocks(10, 0)

    rows, seg = _expand_user_blocks(
        [SimConfig(n_users=5), SimConfig(n_users=20)], 8)
    assert rows == [(0, 0, 5), (1, 0, 8), (1, 1, 8), (1, 2, 4)]
    np.testing.assert_array_equal(seg, [0, 1, 1, 1])


# ------------------------------- segment == dense masked, bitwise -------

@given(st.integers(1, 8), st.integers(1, 32),
       st.integers(0, 2**31 - 1))
@settings(deadline=None, max_examples=25)
def test_segment_reduction_bit_equal_to_dense_masked(b, u, seed):
    """The padded-dense masked reduction, the ragged-flat segment
    reduction and a sequential NumPy left fold agree BITWISE on random
    (n_users, n_users_max) shapes — including all-padded rows (forced on
    row 0) and single-user rows (forced on row 1)."""
    rng = np.random.default_rng(seed)
    n_users = rng.integers(0, u + 1, size=b).astype(np.int32)
    n_users[0] = 0                        # all-padded edge case
    if b > 1:
        n_users[1] = 1                    # single-user edge case
    scale = rng.choice([1.0, 1e-6, 1e6], size=(b, u))
    values = (rng.uniform(-1e3, 1e3, size=(b, u)) * scale) \
        .astype(np.float32)

    dense = np.asarray(UA.masked_user_sum(values, n_users))
    flat_v = np.concatenate(
        [values[i, :n_users[i]] for i in range(b)]) \
        if n_users.any() else np.zeros((0,), np.float32)
    flat_s = np.concatenate(
        [np.full(n_users[i], i, np.int32) for i in range(b)]) \
        if n_users.any() else np.zeros((0,), np.int32)
    ragged = np.asarray(UA.segment_user_sum(flat_v, flat_s, b))
    np.testing.assert_array_equal(dense, ragged)

    ref = np.zeros((b,), np.float32)      # sequential left fold
    for i in range(b):
        acc = np.float32(0.0)
        for j in range(int(n_users[i])):
            acc = np.float32(acc + values[i, j])
        ref[i] = acc
    np.testing.assert_array_equal(dense, ref)

    # means agree the same way (all-padded rows give 0, not NaN)
    dmean = np.asarray(UA.masked_user_mean(values, n_users))
    rmean = np.asarray(UA.segment_user_mean(flat_v, flat_s, b))
    np.testing.assert_array_equal(dmean, rmean)
    assert dmean[0] == 0.0
    if b > 1:                             # single element: exact identity
        assert dmean[1] == values[1, 0]


def test_segment_reduction_eager_equals_jit():
    rng = np.random.default_rng(7)
    v = rng.uniform(-1e3, 1e3, size=(5, 9)).astype(np.float32)
    n = np.asarray([0, 1, 9, 4, 7], np.int32)
    eager = np.asarray(UA.masked_user_sum(v, n))
    jitted = np.asarray(jax.jit(UA.masked_user_sum)(v, n))
    np.testing.assert_array_equal(eager, jitted)


# ------------------------------------ streamed draws: chunk invariance --

@pytest.mark.parametrize("chunk", [1, 7, 64])
def test_streamed_draws_chunk_invariant(chunk):
    """Chunked Markov draws and chunked trace gathers reassemble bitwise
    to the one-shot full-width streamed path for every chunk size —
    per-user fold_in keys make the draw independent of how the user axis
    is partitioned."""
    for wl in (MarkovWorkload(), bundled_trace()):
        ref = wl.stream_draws(3, 0.85, n_groups=5, n_users=100,
                              chunk=100)
        got = wl.stream_draws(3, 0.85, n_groups=5, n_users=100,
                              chunk=chunk)
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=type(wl).__name__)


def test_stream_key_matches_legacy_scan_key():
    """The streamed path's scan key is the same per-seed threefry key the
    one-shot init_draws returns, so K=1 and K>1 configs share one
    in-scan RNG convention."""
    for wl in (MarkovWorkload(), bundled_trace()):
        _, rng, _ = wl.init_draws(11, 0.85, n_groups=5, n_users=4)
        np.testing.assert_array_equal(np.asarray(rng), wl.stream_key(11))


# -------------------------------------------- grid build + memory -------

def test_1e5_user_grid_build_under_memory_ceiling():
    """A mixed grid with a 10^5-user config builds with O(total_users)
    leaf bytes (array-size accounting — RSS is too noisy to gate): the
    blocked layout never pads small configs to the big config's width."""
    prof = paper_fleet()
    cfgs = [SimConfig(n_users=15, n_requests=64, seed=s)
            for s in range(24)]
    cfgs.append(SimConfig(n_users=100_000, n_requests=64, seed=99))
    grid, seg = _make_user_grid(prof, cfgs, 1024, chunk=4096)

    rows = 24 + UA.n_user_blocks(100_000, 1024)
    assert grid.n_configs == rows
    assert grid.n_users_max == 1024
    assert int(seg[-1]) == len(cfgs) - 1

    nbytes = UA.grid_nbytes(grid)
    # the dense layout pads every config to n_users_max=10^5: two
    # (25, 100000) int32 leaves alone are 20 MB
    dense_true0_phase = len(cfgs) * 100_000 * 4 * 2
    assert nbytes < dense_true0_phase / 10, nbytes
    # absolute ceiling: ~bytes per padded user slot across block rows
    assert nbytes < 12 * rows * 1024, nbytes


def test_trace_user_block_must_divide_streams():
    """Block-local stream assignment must match the global u % S — only
    user_block multiples of the trace's stream count are coherent."""
    tr = bundled_trace()                          # 8 streams
    sc = Scenario(workload=tr, n_users=40, n_requests=50, user_block=7)
    with pytest.raises(ValueError, match="multiple"):
        run(sc)
    res = run(Scenario(workload=tr, n_users=40, n_requests=50,
                       user_block=8))
    assert np.isfinite(res.scalar("latency_ms"))


# --------------------------------------- K = 1 bit-identity (golden) ----

def test_user_block_records_bit_identical_to_pr3_golden():
    """records() with user_block set (but n_users <= user_block) is the
    IDENTICAL program: every pinned PR 3 record, every field, every
    bit."""
    with open(GOLDEN_STATIC) as f:
        fix = json.load(f)
    for entry in fix["records"]:
        recs = records(Scenario(**entry["config"], user_block=16))
        assert set(recs) >= set(entry["records"])
        for k, v in entry["records"].items():
            np.testing.assert_array_equal(
                np.asarray(recs[k], np.float64), np.asarray(v),
                err_msg=f"{entry['config']}:{k}")


@pytest.mark.parametrize("golden", [GOLDEN_STATIC, GOLDEN_MARKOV],
                         ids=["static_pr3", "markov_pr2"])
def test_user_block_sweep_bit_identical_to_golden(golden):
    """The scenario sweep with user_block=16 (every config K=1)
    reproduces both golden fixtures' metrics bit for bit — block
    expansion and segment aggregation are exact passthroughs at K=1."""
    with open(golden) as f:
        fix = json.load(f)["sweep"]
    res = run(Scenario(n_requests=fix["n_requests"], user_block=16),
              Sweep(policy=tuple(fix["policies"]),
                    n_users=tuple(fix["user_levels"]),
                    seed=tuple(fix["seeds"])))
    for k, v in fix["metrics"].items():
        want = np.asarray(v).reshape(res[k].shape)
        _assert_metric_equal(k, res[k], want)


_SUBPROC_CHECK = """
import json
import jax, numpy as np
from repro.core.scenario import Scenario, Sweep, run

assert len(jax.devices()) == 4, jax.devices()

# K=1 golden bit-identity on a real 4-device mesh, user_block set
fix = json.load(open({golden!r}))["sweep"]
gold = run(Scenario(n_requests=fix["n_requests"], user_block=16,
                    mesh="local"),
           Sweep(policy=tuple(fix["policies"]),
                 n_users=tuple(fix["user_levels"]),
                 seed=tuple(fix["seeds"])))
for k, v in fix["metrics"].items():
    want = np.asarray(v).reshape(gold[k].shape)
    if k == "latency_p90_ms":      # FMA drift across batch shapes
        np.testing.assert_allclose(gold[k], want, rtol=3e-7, err_msg=k)
    else:
        np.testing.assert_array_equal(gold[k], want, err_msg=k)

# multi-block sharded == multi-block single-device, bitwise: block rows
# ride the sharded config axis (per-user state sharded across devices)
sc = Scenario(n_users=50, n_requests=100, user_block=8)
ref = run(sc)
out = run(sc, mesh="local")
for k in ref.metric_names:
    if k == "latency_p90_ms":
        np.testing.assert_allclose(out[k], ref[k], rtol=3e-7, err_msg=k)
    else:
        np.testing.assert_array_equal(out[k], ref[k], err_msg=k)
print("OK")
"""


def test_user_block_bitwise_in_forced_4_device_subprocess():
    """Real multi-device bit-exactness for the user axis, via
    xla_force_host_platform_device_count=4 in a fresh process: K=1 golden
    metrics survive a 4-device mesh with user_block set, and a K>1
    sharded run equals its single-device self bit for bit."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=str(REPO / "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    src = _SUBPROC_CHECK.format(golden=str(GOLDEN_MARKOV))
    res = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout


def test_k1_sweep_bit_identical_to_unblocked_engine():
    """user_block >= max n_users is a no-op for EVERY metric across a
    mixed sweep, workloads and dispatch engines included."""
    sw = Sweep(policy=("MO", "RR"), n_users=(5, 10), seed=(0, 1))
    for wl in (None, bundled_trace()):
        ref = run(Scenario(n_requests=150, workload=wl), sw)
        out = run(Scenario(n_requests=150, workload=wl, user_block=16),
                  sw)
        for k in ref.metric_names:
            np.testing.assert_array_equal(out[k], ref[k], err_msg=k)


# ----------------------------------- K > 1 semantics and aggregation ----

def test_multi_block_equals_manual_per_block_runs():
    """A K-block config's metrics are exactly the left-fold combination
    of its blocks run one at a time: means fold as float32 sum/count,
    throughput sums (parallel replicas), makespan maxes."""
    prof = paper_fleet()
    cfg = SimConfig(n_users=20, n_requests=120, seed=5)
    grid, seg = _make_user_grid(prof, [cfg], 8)
    assert grid.n_configs == 3            # 8 + 8 + 4 users
    wl, de = MarkovWorkload(), StaticDispatch()
    warmup = 12

    per_block = _sweep_summaries(prof, wl, de, None, None, None, grid,
                                 n_requests=120, warmup=warmup,
                                 mesh=None, with_hist=True)
    hists = per_block.pop("latency_hist")
    # each block row == its own single-row run (the engine's vmap
    # invariant, extended to block rows)
    for b in range(3):
        row = ConfigGrid(*[leaf[b:b + 1] for leaf in grid])
        solo = _sweep_summaries(prof, wl, de, None, None, None, row,
                                n_requests=120, warmup=warmup, mesh=None)
        for k in per_block:
            _assert_metric_equal(k, per_block[k][b], solo[k][0],
                                 err_msg=f"block {b}: {k}")

    res = run(Scenario(n_users=20, n_requests=120, seed=5, user_block=8))
    for k, v in per_block.items():
        blocks = np.asarray(v, np.float32)
        if k == "throughput_rps":
            want = np.float32(0.0)
            for x in blocks:
                want = np.float32(want + x)
        elif k == "makespan_s":
            want = blocks.max()
        elif k == "latency_p90_ms":
            # exact fleet-wide percentile: the merged per-block histogram
            merged = np.asarray(hists, np.float32).sum(0)
            want = np.float32(1000.0 * UA.histogram_p90(merged))
        else:
            acc = np.float32(0.0)
            for x in blocks:
                acc = np.float32(acc + x)
            want = np.float32(acc / np.float32(3.0))
        np.testing.assert_array_equal(
            np.float32(res.scalar(k)), want, err_msg=k)


def test_hist_p90_partition_invariant_and_matches_dense():
    """The merged-histogram p90 is a pure function of the pooled sample:
    any K-way split of the same latencies gives a bit-identical merged
    histogram, hence a bit-identical percentile (0 ULP — stronger than
    the 1-ULP pin the contract asks for); the estimator itself tracks
    ``np.percentile`` within the log-bin quantization (~0.55%
    relative)."""
    rng = np.random.default_rng(0)
    lat = rng.lognormal(-2.0, 1.0, size=4096).astype(np.float32)
    dense = np.asarray(UA.latency_histogram(lat))
    assert dense.shape == (UA.HIST_BINS,)
    assert dense.sum() == lat.size
    for k in (2, 3, 7, 16):
        merged = np.zeros_like(dense)
        for part in np.array_split(lat, k):
            merged = merged + np.asarray(UA.latency_histogram(part))
        np.testing.assert_array_equal(merged, dense, err_msg=f"K={k}")
        np.testing.assert_array_equal(
            np.asarray(UA.histogram_p90(merged)),
            np.asarray(UA.histogram_p90(dense)), err_msg=f"K={k}")
    est = float(UA.histogram_p90(dense))
    ref = float(np.percentile(np.asarray(lat, np.float64), 90))
    assert abs(est - ref) / ref < 5e-3, (est, ref)


def test_user_block_is_a_static_sweep_axis():
    """user_block sweeps like any STATIC_AXES field — one fused program
    per value — and the K=1 column equals the un-blocked run."""
    assert "user_block" in STATIC_AXES
    sw = Sweep(user_block=(4, 16), seed=(0, 1))
    res = run(Scenario(n_users=12, n_requests=100), sw)
    assert res["latency_ms"].shape == (2, 2)
    ref = run(Scenario(n_users=12, n_requests=100), Sweep(seed=(0, 1)))
    np.testing.assert_array_equal(
        res.sel("latency_ms", user_block=16), ref["latency_ms"])
    # the 3-block column is a different physical system, not a reshuffle
    assert not np.array_equal(res.sel("latency_ms", user_block=4),
                              ref["latency_ms"])


def test_records_rejects_multi_block_configs():
    with pytest.raises(ValueError, match="user_block"):
        records(Scenario(n_users=50, user_block=8))
    with pytest.raises(ValueError, match="user_block"):
        records(Scenario(n_users=4, user_block=8),
                Sweep(n_users=(4, 50)))
    recs = records(Scenario(n_users=4, n_requests=50, user_block=8))
    assert recs["latency"].shape == (50,)


# ------------------------------------------- scenario spec plumbing -----

def test_user_block_spec_roundtrip_and_hash_stability():
    """user_block enters the spec/hash only when set: every pre-user-axis
    scenario keeps its exact hash (the committed bench baseline depends
    on it), and blocked scenarios round-trip through JSON."""
    base = Scenario()
    assert "user_block" not in base.to_json()
    assert base.hash == Scenario(user_block=None).hash

    sc = Scenario(user_block=512)
    assert sc.to_json()["user_block"] == 512
    assert sc.hash != base.hash
    rt = Scenario.from_json(sc.to_json())
    assert rt == sc and rt.user_block == 512

    with pytest.raises(ValueError, match="user_block"):
        Scenario(user_block=0)
    with pytest.raises(ValueError, match="user_block"):
        Scenario(user_block=-3)


def test_gateway_adopts_scenario_stream_count():
    """A scenario-built gateway sizes its estimator state to the
    scenario's fleet: n_users streams by default, never shrinking below
    the constructor default, explicit n_streams= still winning."""
    from repro.serving.gateway import WindowedGateway

    prof = paper_fleet()
    assert WindowedGateway(prof).n_streams == 1024
    assert WindowedGateway(Scenario(n_users=15)).n_streams == 1024
    gw = WindowedGateway(Scenario(n_users=5000))
    assert gw.n_streams == 5000
    assert gw._counts.shape == (5000,)
    assert WindowedGateway(Scenario(n_users=5000),
                           n_streams=8192).n_streams == 8192


# ----------------------------------------- acceptance: 10^5 / 10^6 ------

def test_run_completes_at_1e5_users_and_beats_looped_path_10x():
    """Acceptance (ISSUE 7): one run() at n_users=10^5 completes on CPU
    CI, and its users/sec is >= 10x the looped per-value path (the dense
    user axis: one program per n_users value). The dense side is timed
    at a smaller width and extrapolated LINEARLY to 10^5 users at equal
    total requests — dense per-step cost grows at least linearly in U
    (argmin + per-user scatters), so the extrapolation flatters the
    dense baseline and the bar is conservative. Both sides are measured
    back-to-back per attempt (same pairing as the grid-build test) so
    host load hits numerator and denominator together."""
    N, C, R = 100_000, 1024, 32
    K = UA.n_user_blocks(N, C)
    sc = Scenario(n_users=N, n_requests=R, user_block=C,
                  warmup_frac=0.25)
    res = run(sc)                              # compile + complete
    for k in res.metric_names:
        assert np.isfinite(res.scalar(k)), k
    assert res.scalar("throughput_rps") > 0

    DENSE_U = 8192
    dsc = Scenario(n_users=DENSE_U, n_requests=R, warmup_frac=0.25)
    run(dsc)                                   # compile the dense side

    attempts = []
    for _ in range(3):
        t0 = time.perf_counter()
        run(dsc)
        t_dense_small = time.perf_counter() - t0
        t0 = time.perf_counter()
        run(sc)
        t_blocked = time.perf_counter() - t0
        # dense at 10^5 users, equal total requests (K*R steps, one
        # program): steps scale by K, per-step cost by >= N/DENSE_U
        t_dense = t_dense_small * K * (N / DENSE_U)
        users_blocked = N / t_blocked
        users_dense = N / t_dense
        attempts.append((users_blocked, users_dense))
        if users_blocked >= 10 * users_dense:
            break
    assert any(b >= 10 * d for b, d in attempts), attempts


@pytest.mark.skipif("REPRO_MILLION_USERS" not in os.environ,
                    reason="10^6-user acceptance run is opt-in "
                           "(REPRO_MILLION_USERS=1): ~10^3 block rows, "
                           "minutes of CPU")
def test_run_completes_at_1e6_users():
    sc = Scenario(n_users=1_000_000, n_requests=8, user_block=1024,
                  warmup_frac=0.25)
    res = run(sc)
    assert np.isfinite(res.scalar("latency_ms"))
    assert res.scalar("throughput_rps") > 0
