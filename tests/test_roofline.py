"""Roofline analysis machinery: HLO parsing, ring cost model, analytic
FLOPs/memory models."""

import pytest

from repro.common.configs import LMConfig, ShapeSpec, TrainingConfig
from repro.roofline.analysis import (Roofline, _ring_factor,
                                     collective_bytes, shape_bytes)
from repro.roofline.hw import V5E
from repro.roofline.memtraffic import cell_memory, lm_traffic
from repro.roofline.model_flops import cell_model_flops, lm_flops


def test_shape_bytes():
    assert shape_bytes("bf16[16,128]{1,0}") == 16 * 128 * 2
    assert shape_bytes("f32[]") == 4
    assert shape_bytes("(bf16[4,4]{1,0}, f32[2]{0})") == 32 + 8
    assert shape_bytes("s8[10]{0}") == 10
    assert shape_bytes("pred[8]{0}") == 8


def test_collective_parse_iota_groups():
    hlo = (
        "\n  %ar.1 = f32[8,16]{1,0} all-reduce(%x), channel_id=1, "
        "replica_groups=[16,16]<=[256], to_apply=%add\n"
        "  %ag.2 = bf16[4,32]{1,0} all-gather(%y), "
        "replica_groups={{0,1,2,3}}, dimensions={1}\n")
    out = collective_bytes(hlo)
    ar = 8 * 16 * 4 * _ring_factor("all-reduce", 16)
    ag = 4 * 32 * 2 * _ring_factor("all-gather", 4)
    assert out["all-reduce"] == pytest.approx(ar)
    assert out["all-gather"] == pytest.approx(ag)
    assert out["total"] == pytest.approx(ar + ag)


def test_ring_factors():
    assert _ring_factor("all-reduce", 2) == 1.0
    assert _ring_factor("all-reduce", 16) == pytest.approx(2 * 15 / 16)
    assert _ring_factor("all-gather", 1) == 0.0
    assert _ring_factor("collective-permute", 2) == 1.0


def test_roofline_terms_and_dominance():
    rl = Roofline(flops_per_device=197e12, bytes_per_device=819e9 / 2,
                  coll_bytes_per_device=50e9 * 2, chips=256)
    assert rl.t_compute == pytest.approx(1.0)
    assert rl.t_memory == pytest.approx(0.5)
    assert rl.t_collective == pytest.approx(2.0)
    assert rl.dominant == "collective"
    assert rl.step_time == pytest.approx(2.0)


@pytest.fixture()
def lm_cfg():
    return LMConfig(name="t", n_layers=4, d_model=512, n_heads=8,
                    n_kv_heads=8, d_ff=2048, vocab_size=32000)


def test_lm_flops_scaling(lm_cfg):
    tr = ShapeSpec("t", "train", global_batch=8, seq_len=1024)
    pf = ShapeSpec("p", "prefill", global_batch=8, seq_len=1024)
    f_tr = lm_flops(lm_cfg, tr)
    f_pf = lm_flops(lm_cfg, pf)
    # train = fwd + bwd = 3x inference matmuls
    assert f_tr["flops_6nd"] == pytest.approx(3 * f_pf["flops_6nd"])
    # 6ND exactly
    assert f_tr["flops_6nd"] == pytest.approx(
        6 * lm_cfg.n_params() * 8 * 1024)


def test_decode_traffic_dominated_by_cache(lm_cfg):
    dec = ShapeSpec("d", "decode", global_batch=32, seq_len=8192)
    t = lm_traffic(lm_cfg, dec, TrainingConfig())
    cache = 2 * 4 * 32 * 8192 * 8 * 64 * 2
    assert t["cache_io"] == pytest.approx(cache)
    assert t["cache_io"] > t["params_io"]


def test_capacity_fits_flags(lm_cfg):
    dec = ShapeSpec("d", "decode", global_batch=32, seq_len=8192)
    m = cell_memory(lm_cfg, dec, TrainingConfig(), chips=256,
                    param_shards=16)
    assert m["capacity"]["total"] < V5E.hbm_bytes
    assert set(m["traffic"]) >= {"params_io", "cache_io", "total"}


def test_model_flops_all_families():
    from repro import configs as C
    for aid in C.ARCH_IDS:
        arch = C.get(aid)
        for sh in arch.shapes:
            f = cell_model_flops(arch.config, sh)
            assert f["model_flops"] > 0, (aid, sh.name)
