"""Online-EWMA estimator properties (ISSUE 4 satellites): the deduplicated
cell fold, the batched ``observe_window`` equivalence, and the annealing
contract — cold cells track the prior, hot cells converge to observations
— property-tested over the (alpha, prior_weight) plane."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import online as ONL
from repro.core.profiles import paper_fleet


def _seq_observe(state, ps, gs, ts, es=None, **kw):
    for w in range(len(ps)):
        state = ONL.observe(state, ps[w], gs[w], ts[w],
                            None if es is None else es[w], **kw)
    return state


def test_observe_window_equals_sequential_observes():
    """The vmapped per-cell window fold == W sequential observe() calls,
    interleaved cells, repeats and all — with and without energy."""
    prof = paper_fleet()
    rng = np.random.default_rng(7)
    W = 64
    ps = rng.integers(0, prof.n_pairs, W)
    gs = rng.integers(0, prof.n_groups, W)
    ts = rng.uniform(50.0, 500.0, W).astype(np.float32)
    es = rng.uniform(0.01, 0.5, W).astype(np.float32)
    for energy in (es, None):
        seq = _seq_observe(ONL.init_state(prof), ps, gs, ts, energy)
        win = ONL.observe_window(ONL.init_state(prof), ps, gs, ts, energy)
        for k in ("T", "E", "count"):
            np.testing.assert_allclose(np.asarray(win[k]),
                                       np.asarray(seq[k]), rtol=1e-6,
                                       err_msg=f"energy={energy is not None}"
                                               f":{k}")
    # energy untouched when not observed
    win = ONL.observe_window(ONL.init_state(prof), ps, gs, ts, None)
    np.testing.assert_array_equal(np.asarray(win["E"]),
                                  np.asarray(prof.E, np.float32))


def test_observe_passes_extra_state_keys_through():
    """Dispatch states carry extra keys (the rr counter) — both observe
    paths must preserve them untouched."""
    import jax.numpy as jnp

    prof = paper_fleet()
    state = ONL.init_state(prof)
    state["rr"] = jnp.asarray(17, jnp.int32)
    out = ONL.observe(state, 1, 2, 100.0, 0.1)
    assert int(out["rr"]) == 17
    out = ONL.observe_window(state, np.array([1]), np.array([2]),
                             np.array([100.0], np.float32))
    assert int(out["rr"]) == 17


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 12), st.floats(1.0, 20.0), st.integers(1, 40))
def test_window_estimator_matches_numpy_reference(window, prior_weight,
                                                  n_obs):
    """The ring-buffer sliding-window estimator == a NumPy reference that
    literally keeps the last `window` observations: belief = (pw * prior
    + sum(last W)) / (pw + n), pw = max(prior_weight - count, 0). Covers
    wraparound, partial fills, and the prior wash-out."""
    prof = paper_fleet()
    rng = np.random.default_rng(window * 1000 + n_obs)
    obs = rng.uniform(50.0, 900.0, n_obs).astype(np.float32)
    state = ONL.init_window_state(prof, window)
    for o in obs:
        state = ONL.observe_windowed(state, 1, 3, o, window=window)
    tbl = ONL.window_tables(state, prof, window=window,
                            prior_weight=prior_weight)
    last = obs[-window:]
    pw = max(prior_weight - n_obs, 0.0)
    want = (pw * float(prof.T[1, 3]) + last.sum()) / (pw + len(last))
    np.testing.assert_allclose(float(tbl.T[1, 3]), want, rtol=1e-4)
    # untouched cells: bit-equal to the prior (T and E)
    T = np.asarray(tbl.T)
    mask = np.ones_like(T, bool)
    mask[1, 3] = False
    np.testing.assert_array_equal(T[mask],
                                  np.asarray(prof.T, np.float32)[mask])
    np.testing.assert_array_equal(np.asarray(tbl.E),
                                  np.asarray(prof.E, np.float32))
    # full turnover forgets the past entirely: after `window` constant
    # observations the belief IS that constant (prior fully washed out
    # once count >= prior_weight)
    for _ in range(window + int(prior_weight)):
        state = ONL.observe_windowed(state, 1, 3, np.float32(333.0),
                                     window=window)
    tbl = ONL.window_tables(state, prof, window=window,
                            prior_weight=prior_weight)
    np.testing.assert_allclose(float(tbl.T[1, 3]), 333.0, rtol=1e-5)


def test_window_counts_are_int32_so_the_ring_never_freezes():
    """Ring counts must be integer: a float32 counter saturates at 2^24
    (c + 1.0 == c), freezing the ring index of a long-lived gateway and
    pinning stale slots forever. With int32, incrementing and slot
    rotation still work past that boundary."""
    import jax.numpy as jnp

    prof = paper_fleet()
    W = 4
    state = ONL.init_window_state(prof, W)
    assert state["count"].dtype == jnp.int32
    assert state["ecount"].dtype == jnp.int32
    state["count"] = state["count"].at[0, 0].set(2**24)
    before = int(state["count"][0, 0])
    state = ONL.observe_windowed(state, 0, 0, 100.0, window=W)
    state = ONL.observe_windowed(state, 0, 0, 200.0, window=W)
    assert int(state["count"][0, 0]) == before + 2
    # the two observations landed in DIFFERENT slots (a frozen float32
    # index would overwrite one slot and sum only the last value)
    np.testing.assert_allclose(float(state["tsum"][0, 0]), 300.0)


def test_window_estimator_energy_has_independent_count():
    """Energy observations are optional: T-only observes advance the T
    ring but leave the E belief exactly at the prior (no silent decay)."""
    prof = paper_fleet()
    W = 4
    state = ONL.init_window_state(prof, W)
    for _ in range(10):
        state = ONL.observe_windowed(state, 0, 0, 200.0, None, window=W)
    tbl = ONL.window_tables(state, prof, window=W, prior_weight=2.0)
    np.testing.assert_allclose(float(tbl.T[0, 0]), 200.0, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(tbl.E),
                                  np.asarray(prof.E, np.float32))
    assert float(state["ecount"][0, 0]) == 0.0
    state = ONL.observe_windowed(state, 0, 0, 200.0, 0.5, window=W)
    assert float(state["ecount"][0, 0]) == 1.0


@settings(max_examples=12, deadline=None)
@given(st.floats(0.02, 0.5), st.floats(1.0, 30.0), st.floats(200.0, 900.0))
def test_ewma_annealing_cold_tracks_prior_hot_converges(alpha, prior_weight,
                                                        obs):
    """Over the (alpha, prior_weight) plane: a cell that saw nothing stays
    bit-equal to the prior; a cell's first observation never moves it
    (count 0 -> eff 0); after a couple of observations it has barely moved
    (cold: trust the prior); after 300 it has closed most of the gap to
    the observations (hot: trust the measurements), never overshooting and
    never moving away."""
    prof = paper_fleet()
    kw = dict(alpha=alpha, prior_weight=prior_weight)
    prior = float(prof.T[2, 3])
    n_obs = 300
    state = ONL.observe_window(
        ONL.init_state(prof), np.full(n_obs, 2), np.full(n_obs, 3),
        np.full(n_obs, obs, np.float32), **kw)

    # untouched cells: bit-equal to the prior, zero counts
    T = np.asarray(state["T"])
    mask = np.ones_like(T, bool)
    mask[2, 3] = False
    np.testing.assert_array_equal(T[mask], np.asarray(prof.T,
                                                      np.float32)[mask])
    assert float(state["count"][2, 3]) == n_obs

    # trajectory: replay the same stream cell-locally
    vals = [prior]
    v, c = prior, 0.0
    for _ in range(n_obs):
        eff = alpha * c / (c + prior_weight)
        v = v * (1.0 - eff) + eff * obs
        c += 1.0
        vals.append(v)
    np.testing.assert_allclose(float(state["T"][2, 3]), v, rtol=1e-5)

    gap0 = abs(obs - prior)
    gaps = np.abs(obs - np.asarray(vals))
    assert gaps[1] == gap0                      # first obs: eff == 0
    # cold: after 3 observations the move is bounded by the annealing ramp
    # (each eff_k <= alpha * k / (k + prior_weight)), so a heavy prior
    # keeps the cell near the prior
    assert gaps[3] >= gap0 * (1.0 - 3.0 * alpha / (1.0 + prior_weight)) \
        - 1e-3 * gap0
    assert (np.diff(gaps) <= 1e-6 * gap0).all()  # monotone toward obs
    assert gaps[-1] < 0.15 * gap0               # hot: mostly converged
    lo, hi = min(prior, obs), max(prior, obs)
    assert (np.asarray(vals) >= lo - 1e-3).all()
    assert (np.asarray(vals) <= hi + 1e-3).all()  # never overshoots
