"""Serving runtime: gateway, executors, end-to-end engine, online
adaptation, hierarchical balancing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import online as ONL
from repro.core.dispatch import OnlineDispatch, StaticDispatch
from repro.core.hierarchy import hierarchical_select, pod_aggregate
from repro.core.profiles import paper_fleet, synthetic_fleet
from repro.serving.engine import ServingEngine
from repro.serving.gateway import Gateway


def test_engine_modelled_mo_beats_ha_on_latency():
    prof = paper_fleet()
    res = {}
    for pol in ("MO", "HA"):
        eng = ServingEngine.build(prof, policy=pol, n_streams=8,
                                  mode="modelled", seed=1)
        res[pol] = eng.summarize(eng.run(n_requests=250, concurrency=8))
    assert res["MO"]["latency_ms"] < 0.6 * res["HA"]["latency_ms"]
    assert res["MO"]["map"] > res["HA"]["map"] - 12


def test_engine_real_detectors_close_the_loop():
    """Real mode: detection counts come from actual model output and feed
    the estimator; latency is wall-clock."""
    prof = paper_fleet()
    tiers = ["ssd_v1", "ssd_lite", "yolo_m", "yolo_s", "ssd_v1"]
    eng = ServingEngine.build(prof, policy="MO", n_streams=4, mode="real",
                              tiers=tiers, img_res=64, seed=0)
    recs = eng.run(n_requests=40, concurrency=4)
    s = eng.summarize(recs)
    assert s["latency_ms"] > 0
    assert 0.0 <= s["estimator_acc"] <= 1.0
    assert len(np.unique(recs["pair"])) >= 2


@pytest.mark.filterwarnings("ignore::repro.core.scenario.LegacyAPIWarning")
def test_gateway_respects_feasibility():
    prof = paper_fleet()
    gw = Gateway(prof, policy="MO", delta=10.0)
    gw.observe_detections(0, 4)       # complex scene
    pair, g = gw.route(0, np.zeros(5))
    thr = float(jnp.max(prof.mAP[:, g])) - 10.0
    assert float(prof.mAP[pair, g]) >= thr


@pytest.mark.filterwarnings("ignore::repro.core.scenario.LegacyAPIWarning")
def test_gateway_seedable_rng():
    """Same seed -> identical RND decision streams; different seeds
    diverge (the constructor's seed= replaced a hardcoded PRNGKey)."""
    prof = paper_fleet()
    q = np.zeros(5)
    runs = {}
    for seed in (0, 0, 7):
        gw = Gateway(prof, policy="RND", seed=seed)
        runs.setdefault(seed, []).append(
            [gw.route(0, q)[0] for _ in range(32)])
    assert runs[0][0] == runs[0][1]
    assert runs[0][0] != runs[7][0]
    assert Gateway(prof).seed == 1234          # historical default kept


@pytest.mark.filterwarnings("ignore::repro.core.scenario.LegacyAPIWarning")
def test_gateway_runs_dispatch_engine_state():
    """The gateway drives the SAME DispatchEngine hooks as the simulator:
    static discards observations; online folds them into the EWMA belief
    tables that the next decision scores against."""
    prof = paper_fleet()
    st_gw = Gateway(prof, dispatch=StaticDispatch())
    on_gw = Gateway(prof, online=True)
    assert not st_gw.online and on_gw.online
    for gw in (st_gw, on_gw):
        for _ in range(60):
            gw.observe_latency(0, 2, 900.0, 0.9)   # n1 suddenly slow+hungry
    np.testing.assert_array_equal(np.asarray(st_gw._tables().T),
                                  np.asarray(prof.T))
    assert float(on_gw._tables().T[0, 2]) > 2.0 * float(prof.T[0, 2])
    assert float(on_gw._tables().E[0, 2]) > 2.0 * float(prof.E[0, 2])
    # rr state lives in the dispatch state, advanced by route()
    st_gw.route(0, np.zeros(5))
    assert int(st_gw._dstate["rr"]) == 1


@pytest.mark.filterwarnings("ignore::repro.core.scenario.LegacyAPIWarning")
def test_gateway_window_matches_per_request_online():
    """Regression (ISSUE 4): with online=True, the windowed moscore path
    must make the same decisions as per-request route() calls with manual
    queue feedback, and observe_window must fold the window's measurements
    into the same belief state as per-request observe_latency calls."""
    prof = paper_fleet()
    gw_req = Gateway(prof, policy="MO", online=True, seed=3)
    gw_win = Gateway(prof, policy="MO", online=True, seed=3)
    counts = {0: 0, 1: 2, 2: 4, 3: 1, 4: 3, 5: 2}
    for s, c in counts.items():
        gw_req.observe_detections(s, c)
        gw_win.observe_detections(s, c)
    streams = [0, 1, 2, 3, 4, 5, 0, 2, 4, 1]
    q0 = np.zeros(prof.n_pairs, np.float32)

    for round_ in range(3):                    # windows interleaved with
        pairs_w, gs_w, _q = gw_win.route_window(streams, q0)   # adaptation
        q = q0.copy()
        pairs_r = []
        for s in streams:
            p, g = gw_req.route(s, q)
            q[p] += 1.0
            pairs_r.append(p)
        assert pairs_r == list(pairs_w), round_
        lat = 1.5 * np.asarray(prof.T)[pairs_w, gs_w]
        en = 2.0 * np.asarray(prof.E)[pairs_w, gs_w]
        for p, g, t, e in zip(pairs_w, gs_w, lat, en):
            gw_req.observe_latency(int(p), int(g), float(t), float(e))
        gw_win.observe_window(pairs_w, gs_w, lat, en)
        for k in ("T", "E", "count"):
            np.testing.assert_allclose(
                np.asarray(gw_req._dstate[k]),
                np.asarray(gw_win._dstate[k]), rtol=1e-6,
                err_msg=f"round {round_}: {k}")


def test_online_adaptation_tracks_drift():
    """A pair that slows 3x is learned by the EWMA and traffic shifts."""
    prof = paper_fleet()
    st = ONL.init_state(prof)
    for _ in range(200):
        st = ONL.observe(st, 0, 2, 300.0)     # n1 now 3x slower at g2
    adapted = ONL.as_profile(st, prof)
    assert float(adapted.T[0, 2]) > 2.0 * float(prof.T[0, 2])
    # static table keeps stale estimate
    gap = ONL.drift_robustness_gap(
        prof, adapted, st)
    assert gap["adapted_T_rms"] < gap["static_T_rms"]


def test_hierarchical_matches_flat_when_synced():
    """With fresh pod queues and delta=inf-ish tolerance inside the chosen
    pod, two-level selection stays accuracy-feasible and picks inside the
    chosen pod."""
    prof = synthetic_fleet(jax.random.PRNGKey(0), 16)
    pod_of = jnp.asarray([i // 8 for i in range(16)])
    pods = pod_aggregate(prof, pod_of)
    q = jnp.zeros(16)
    qp = jnp.zeros(2)
    pair, pod = hierarchical_select(prof, pods, pod_of, 3, q, qp,
                                    delta=25.0, gamma=0.5)
    assert int(pod_of[int(pair)]) == int(pod)
    # within-pod feasibility (relative to the pod's own best)
    in_pod = np.asarray(pod_of) == int(pod)
    pod_thr = float(np.max(np.asarray(prof.mAP)[in_pod, 3])) - 25.0
    assert float(prof.mAP[int(pair), 3]) >= pod_thr
