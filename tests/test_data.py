"""Data pipeline tests."""

import jax
import numpy as np

from repro.data.images import synthetic_diffusion_batch, synthetic_image_batch
from repro.data.tokens import TokenLoader, synthetic_lm_batch
from repro.data.workload import VideoStreamWorkload


def test_lm_batch_shapes_and_determinism():
    b1 = synthetic_lm_batch(jax.random.PRNGKey(5), 4, 32, 100)
    b2 = synthetic_lm_batch(jax.random.PRNGKey(5), 4, 32, 100)
    assert b1["tokens"].shape == (4, 32)
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 100).all()
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))


def test_token_loader_advances():
    it = TokenLoader(2, 16, 50)
    a = next(it)
    b = next(it)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_image_batch_is_learnable():
    b = synthetic_image_batch(jax.random.PRNGKey(0), 64, 32, 10)
    # class signal: the lit rows differ by label
    imgs, labels = np.asarray(b["images"]), np.asarray(b["labels"])
    means = imgs.mean(axis=(2, 3))
    rows = means.argmax(axis=1) // max(32 // 8, 1)
    assert (rows == labels % 8).mean() > 0.9


def test_workload_counts_match_groups():
    wl = VideoStreamWorkload(n_streams=2, img_res=64, seed=1)
    for _ in range(20):
        img, g = wl.next_frame(0)
        assert img.shape == (64, 64, 3)
        assert 0 <= g < 5
    img, obj, cls, g = wl.labelled_frame(1)
    n_obj = int(obj.sum())
    assert (g < 4 and n_obj == g) or (g == 4 and n_obj >= 4)


def test_diffusion_batch_fields():
    b = synthetic_diffusion_batch(jax.random.PRNGKey(0), 2, 8, 4)
    assert set(b) == {"latents", "noise", "labels", "t"}
    from repro.configs.flux_dev import REDUCED
    b2 = synthetic_diffusion_batch(jax.random.PRNGKey(0), 2, 8, 4,
                                   mmdit_cfg=REDUCED)
    assert set(b2) == {"latents", "noise", "txt", "pooled", "t", "guidance"}
