"""Data pipeline tests."""

import jax
import numpy as np
import pytest

from repro.data.images import synthetic_diffusion_batch, synthetic_image_batch
from repro.data.tokens import TokenLoader, synthetic_lm_batch
from repro.data.workload import VideoStreamWorkload, closed_loop_arrivals


def test_lm_batch_shapes_and_determinism():
    b1 = synthetic_lm_batch(jax.random.PRNGKey(5), 4, 32, 100)
    b2 = synthetic_lm_batch(jax.random.PRNGKey(5), 4, 32, 100)
    assert b1["tokens"].shape == (4, 32)
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 100).all()
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))


def test_token_loader_advances():
    it = TokenLoader(2, 16, 50)
    a = next(it)
    b = next(it)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_image_batch_is_learnable():
    b = synthetic_image_batch(jax.random.PRNGKey(0), 64, 32, 10)
    # class signal: the lit rows differ by label
    imgs, labels = np.asarray(b["images"]), np.asarray(b["labels"])
    means = imgs.mean(axis=(2, 3))
    rows = means.argmax(axis=1) // max(32 // 8, 1)
    assert (rows == labels % 8).mean() > 0.9


def test_workload_counts_match_groups():
    wl = VideoStreamWorkload(n_streams=2, img_res=64, seed=1)
    for _ in range(20):
        img, g = wl.next_frame(0)
        assert img.shape == (64, 64, 3)
        assert 0 <= g < 5
    img, obj, cls, g = wl.labelled_frame(1)
    n_obj = int(obj.sum())
    assert (g < 4 and n_obj == g) or (g == 4 and n_obj >= 4)


def test_reference_grid_matches_known_layout():
    """reference_grid recovers exactly the cells objects were drawn in:
    via the generator (count == g for g < 4) and via a hand-crafted frame
    with a known layout."""
    wl = VideoStreamWorkload(n_streams=2, img_res=64, seed=4)
    with pytest.raises(ValueError, match="no generated frame"):
        wl.reference_grid(0)
    for _ in range(15):
        img, g = wl.next_frame(0)
        ref = wl.reference_grid(0)
        assert ref.shape == (wl.grid, wl.grid) and set(np.unique(ref)) <= {0, 1}
        n = int(ref.sum())
        assert (g < 4 and n == g) or (g == 4 and 4 <= n <= 7)
    # hand-crafted frame: objects at exactly three known cells
    cell = wl.img_res // wl.grid
    img = np.random.default_rng(0).normal(
        0.0, 0.1, (wl.img_res, wl.img_res, 3)).astype(np.float32)
    want = np.zeros((wl.grid, wl.grid), np.int32)
    for cy, cx in ((0, 0), (3, 5), (7, 7)):
        want[cy, cx] = 1
        img[cy * cell:(cy + 1) * cell, cx * cell:(cx + 1) * cell] += 2.0
    wl._last_frame[1] = img
    np.testing.assert_array_equal(wl.reference_grid(1), want)


def test_labelled_frame_agrees_with_reference_grid():
    wl = VideoStreamWorkload(n_streams=1, img_res=64, seed=9)
    _img, obj, _cls, _g = wl.labelled_frame(0)
    np.testing.assert_array_equal(obj, wl.reference_grid(0))


def test_closed_loop_arrivals_spacing():
    """Locust-style closed loop: one offset per user, strictly increasing
    with 1e-4 s spacing from zero (matching the simulator's t_next init),
    independent of the request count."""
    arr = closed_loop_arrivals(5, 1000)
    assert arr == [i * 1e-4 for i in range(5)]
    assert closed_loop_arrivals(5, 10) == arr
    assert closed_loop_arrivals(0, 10) == []
    assert all(b > a for a, b in zip(arr, arr[1:]))


def test_noisy_count_seeded_statistics():
    """Modelled detection counts: bounded by true count + 1 false positive,
    seeded-deterministic, and the detection probability rises with mAP."""
    a = VideoStreamWorkload(n_streams=1, seed=12)
    b = VideoStreamWorkload(n_streams=1, seed=12)
    assert [a.noisy_count(0, 70.0) for _ in range(50)] \
        == [b.noisy_count(0, 70.0) for _ in range(50)]

    def mean_det(map_pg, n=400):
        wl = VideoStreamWorkload(n_streams=1, seed=3)
        wl._state[0] = 4                      # 4+ group -> true count 5
        vals = [wl.noisy_count(0, map_pg) for _ in range(n)]
        assert all(0 <= v <= 6 for v in vals)  # 5 objects + 1 false positive
        return float(np.mean(vals))

    lo, hi = mean_det(10.0), mean_det(90.0)
    assert hi > lo                            # p_det rises with mAP
    assert hi > 4.5                           # strong detectors count ~right
    assert lo > 0.8 * 5 * 0.5                 # p_det floor 0.80 keeps counts up


def test_diffusion_batch_fields():
    b = synthetic_diffusion_batch(jax.random.PRNGKey(0), 2, 8, 4)
    assert set(b) == {"latents", "noise", "labels", "t"}
    from repro.configs.flux_dev import REDUCED
    b2 = synthetic_diffusion_batch(jax.random.PRNGKey(0), 2, 8, 4,
                                   mmdit_cfg=REDUCED)
    assert set(b2) == {"latents", "noise", "txt", "pooled", "t", "guidance"}
