"""Checkpoint/restore, crash recovery, elastic resharding, compression."""

import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              reshard_restore, restore_checkpoint,
                              save_checkpoint)
from repro.checkpoint.checkpointer import all_steps
from repro.training.compression import (compress_roundtrip,
                                        compression_error, dequantize_int8,
                                        quantize_int8)


@pytest.fixture()
def tmpdir():
    d = tempfile.mkdtemp(prefix="ckpt_test_")
    yield d
    shutil.rmtree(d, ignore_errors=True)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (16, 32)),
                       "b": jnp.zeros((32,))},
            "opt": {"m": {"w": jnp.ones((16, 32)), "b": jnp.zeros((32,))}},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmpdir):
    st = _state()
    save_checkpoint(tmpdir, 7, st)
    out = restore_checkpoint(tmpdir, 7, st)
    for (n1, a), (n2, b) in zip(
            jax.tree_util.tree_leaves_with_path(st),
            jax.tree_util.tree_leaves_with_path(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_incomplete_checkpoint_skipped(tmpdir):
    st = _state()
    save_checkpoint(tmpdir, 10, st)
    # simulate a crash mid-write: directory without COMMIT
    broken = os.path.join(tmpdir, "step_00000020")
    os.makedirs(broken)
    assert latest_step(tmpdir) == 10
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(tmpdir, 20, st)


def test_gc_keeps_latest(tmpdir):
    st = _state()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmpdir, s, st, keep_n=3)
    assert all_steps(tmpdir) == [3, 4, 5]


def test_async_checkpointer(tmpdir):
    st = _state()
    ck = AsyncCheckpointer(tmpdir)
    ck.save(1, st)
    ck.save(2, jax.tree.map(lambda x: x + 1, st))
    ck.close()
    assert latest_step(tmpdir) == 2
    out = restore_checkpoint(tmpdir, 2, st)
    np.testing.assert_allclose(np.asarray(out["params"]["w"]),
                               np.asarray(st["params"]["w"]) + 1)


def test_crash_resume_training(tmpdir):
    """Inject a failure mid-training; restart resumes and completes with the
    same final step count."""
    from repro.launch.train import train

    with pytest.raises(RuntimeError, match="injected failure"):
        train("resnet-50", reduced=True, steps=9, ckpt_dir=tmpdir,
              ckpt_every=3, fail_at_step=7, log_every=100)
    assert latest_step(tmpdir) is not None
    state, _ = train("resnet-50", reduced=True, steps=9, ckpt_dir=tmpdir,
                     ckpt_every=3, log_every=100)
    assert int(state["step"]) == 9


def test_elastic_reshard(tmpdir):
    """Save under one sharding, restore under a different mesh layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_local_mesh

    st = _state()
    save_checkpoint(tmpdir, 5, st)
    mesh = make_local_mesh()       # whatever this host has (1 device here)
    sh = NamedSharding(mesh, P())
    shardings = jax.tree.map(lambda _: sh, st)
    out = reshard_restore(tmpdir, 5, st, shardings)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(st["params"]["w"]))


def test_int8_compression_error_small():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000, 257))
    err = float(compression_error(x))
    assert err < 0.01, err
    y = compress_roundtrip(x)
    assert y.shape == x.shape


def test_quantize_exact_for_small_ints():
    x = jnp.asarray([[1.0, -2.0, 3.0, 0.0] * 64])
    q, s, shp = quantize_int8(x)
    y = dequantize_int8(q, s, shp)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=0.02)
