"""Checkpoint/restore, crash recovery, elastic resharding, compression."""

import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              reshard_restore, restore_checkpoint,
                              save_checkpoint)
from repro.checkpoint.checkpointer import all_steps
from repro.training.compression import (compress_roundtrip,
                                        compression_error, dequantize_int8,
                                        quantization_error, quantize_int8)


@pytest.fixture()
def tmpdir():
    d = tempfile.mkdtemp(prefix="ckpt_test_")
    yield d
    shutil.rmtree(d, ignore_errors=True)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (16, 32)),
                       "b": jnp.zeros((32,))},
            "opt": {"m": {"w": jnp.ones((16, 32)), "b": jnp.zeros((32,))}},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmpdir):
    st = _state()
    save_checkpoint(tmpdir, 7, st)
    out = restore_checkpoint(tmpdir, 7, st)
    for (n1, a), (n2, b) in zip(
            jax.tree_util.tree_leaves_with_path(st),
            jax.tree_util.tree_leaves_with_path(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_incomplete_checkpoint_skipped(tmpdir):
    st = _state()
    save_checkpoint(tmpdir, 10, st)
    # simulate a crash mid-write: directory without COMMIT
    broken = os.path.join(tmpdir, "step_00000020")
    os.makedirs(broken)
    assert latest_step(tmpdir) == 10
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(tmpdir, 20, st)


def test_gc_keeps_latest(tmpdir):
    st = _state()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmpdir, s, st, keep_n=3)
    assert all_steps(tmpdir) == [3, 4, 5]


def test_async_checkpointer(tmpdir):
    st = _state()
    ck = AsyncCheckpointer(tmpdir)
    ck.save(1, st)
    ck.save(2, jax.tree.map(lambda x: x + 1, st))
    ck.close()
    assert latest_step(tmpdir) == 2
    out = restore_checkpoint(tmpdir, 2, st)
    np.testing.assert_allclose(np.asarray(out["params"]["w"]),
                               np.asarray(st["params"]["w"]) + 1)


def test_crash_resume_training(tmpdir):
    """Inject a failure mid-training; restart resumes and completes with the
    same final step count."""
    from repro.launch.train import train

    with pytest.raises(RuntimeError, match="injected failure"):
        train("resnet-50", reduced=True, steps=9, ckpt_dir=tmpdir,
              ckpt_every=3, fail_at_step=7, log_every=100)
    assert latest_step(tmpdir) is not None
    state, _ = train("resnet-50", reduced=True, steps=9, ckpt_dir=tmpdir,
                     ckpt_every=3, log_every=100)
    assert int(state["step"]) == 9


def test_elastic_reshard(tmpdir):
    """Save under one sharding, restore under a different mesh layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_local_mesh

    st = _state()
    save_checkpoint(tmpdir, 5, st)
    mesh = make_local_mesh()       # whatever this host has (1 device here)
    sh = NamedSharding(mesh, P())
    shardings = jax.tree.map(lambda _: sh, st)
    out = reshard_restore(tmpdir, 5, st, shardings)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(st["params"]["w"]))


def test_int8_compression_error_small():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000, 257))
    err = float(compression_error(x))
    assert err < 0.01, err
    y = compress_roundtrip(x)
    assert y.shape == x.shape


def test_quantize_exact_for_small_ints():
    x = jnp.asarray([[1.0, -2.0, 3.0, 0.0] * 64])
    q, s, shp = quantize_int8(x)
    y = dequantize_int8(q, s, shp)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=0.02)


def test_quantization_error_name_and_alias():
    """``quantization_error`` is the canonical name (shared with the
    quantized routing tables, ``repro.core.quant``); the pre-rename
    ``compression_error`` alias stays importable and identical."""
    assert compression_error is quantization_error
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 64))
    assert float(quantization_error(x)) == float(compression_error(x))
    # exactly-representable inputs round-trip with zero error
    exact = jnp.asarray([[127.0, -64.0, 1.0, 0.0] * 32])
    assert float(quantization_error(exact, chunk=128)) < 1e-6


@settings(deadline=None, max_examples=40)
@given(n=st.integers(1, 3000), chunk=st.sampled_from([16, 64, 256, 1024]),
       seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 1e3))
def test_quantization_error_documented_bound(n, chunk, seed, scale):
    """The documented worst-case bound holds for ANY input: per element
    the round-trip error is at most half a quantisation step of its
    chunk's absmax, so ``rel_l2 <= sqrt(chunk) / 254`` (see
    ``quantization_error``'s docstring — typical data sits far below)."""
    x = scale * jax.random.t(jax.random.PRNGKey(seed), 3.0, (n,))
    err = float(quantization_error(x, chunk=chunk))
    assert err <= float(np.sqrt(chunk)) / 254.0 + 1e-6, (n, chunk, err)
