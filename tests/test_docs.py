"""Docs stay true: the sweep_engine.md example runs as written (and stays
in sync with its runnable copy), and every relative markdown link
resolves."""

import importlib.util
import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "check_docs", REPO / "scripts" / "check_docs.py")
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)


def _fenced_python(md: Path) -> list[str]:
    blocks = re.findall(r"```python\n(.*?)```", md.read_text(), re.DOTALL)
    assert blocks, f"no fenced python block in {md}"
    return blocks

# guide -> the runnable example each of its fenced python blocks embeds,
# in document order
EMBEDDED_EXAMPLES = {
    "sweep_engine.md": ["scenario_api.py", "trace_workload.py",
                        "online_drift.py", "sweep_quickstart.py",
                        "user_scaling.py", "edge_cloud.py"],
    "serving.md": ["serving_gateway.py"],
    "kernels.md": ["moscore_backends.py"],
    "resilience.md": ["fault_injection.py"],
}


def test_guide_examples_match_runnable_copies():
    """Each guide embeds its docs/examples/*.py files verbatim, so the
    'runs as written' guarantee covers the markdown too."""
    for md, examples in EMBEDDED_EXAMPLES.items():
        blocks = _fenced_python(REPO / "docs" / md)
        assert len(blocks) == len(examples), \
            f"{md}: {len(blocks)} python blocks, {len(examples)} examples"
        for block, name in zip(blocks, examples):
            runnable = (REPO / "docs" / "examples" / name).read_text()
            assert block.strip() == runnable.strip(), f"{md} vs {name}"


def test_guide_examples_run():
    for examples in EMBEDDED_EXAMPLES.values():
        for name in examples:
            src = (REPO / "docs" / "examples" / name).read_text()
            exec(compile(src, f"docs/examples/{name}", "exec"), {})


def test_docs_links_resolve():
    assert check_docs.main() == 0
