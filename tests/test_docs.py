"""Docs stay true: the sweep_engine.md example runs as written (and stays
in sync with its runnable copy), and every relative markdown link
resolves."""

import importlib.util
import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "check_docs", REPO / "scripts" / "check_docs.py")
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)


def _fenced_python(md: Path) -> str:
    blocks = re.findall(r"```python\n(.*?)```", md.read_text(), re.DOTALL)
    assert blocks, f"no fenced python block in {md}"
    return blocks[0]


def test_sweep_engine_example_matches_runnable_copy():
    """The guide embeds docs/examples/sweep_quickstart.py verbatim, so the
    'runs as written' guarantee covers the markdown too."""
    block = _fenced_python(REPO / "docs" / "sweep_engine.md")
    runnable = (REPO / "docs" / "examples" /
                "sweep_quickstart.py").read_text()
    assert block.strip() == runnable.strip()


def test_sweep_engine_example_runs():
    src = (REPO / "docs" / "examples" / "sweep_quickstart.py").read_text()
    exec(compile(src, "docs/examples/sweep_quickstart.py", "exec"), {})


def test_docs_links_resolve():
    assert check_docs.main() == 0
