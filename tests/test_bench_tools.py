"""The CI benchmark gate (scripts/check_bench.py): artifact validation and
regression comparison logic."""

import importlib.util
import json
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
_spec = importlib.util.spec_from_file_location(
    "check_bench", REPO / "scripts" / "check_bench.py")
check_bench = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_bench)


def _artifact(seconds=2.0, error=None, rows=("suite.a,1",)):
    return {
        "schema": "repro-bench/v1",
        "fast": True,
        "suites": {"fig4": {"rows": list(rows), "seconds": seconds,
                            "error": error}},
    }


def test_validate_accepts_good_artifact():
    assert check_bench.validate(_artifact(), "new") == []


def test_validate_rejects_bad_schema_errors_and_empty_rows():
    assert check_bench.validate({"schema": "nope"}, "new")
    assert check_bench.validate(_artifact(error="Boom: x"), "new")
    assert check_bench.validate(_artifact(rows=()), "new")
    art = _artifact()
    art["suites"]["fig4"]["seconds"] = "slow"
    assert check_bench.validate(art, "new")


def test_compare_flags_only_real_regressions():
    base = _artifact(seconds=10.0)
    # +50% and > min_abs: fail
    assert check_bench.compare(_artifact(seconds=15.0), base, 0.20, 0.5)
    # +10%: within threshold
    assert not check_bench.compare(_artifact(seconds=11.0), base, 0.20, 0.5)
    # tiny suite: +100% but under the absolute floor
    tiny_base = _artifact(seconds=0.2)
    assert not check_bench.compare(_artifact(seconds=0.4), tiny_base,
                                   0.20, 0.5)
    # suite missing from the new run
    gone = _artifact()
    gone["suites"] = {}
    assert check_bench.compare(gone, base, 0.20, 0.5)


def test_compare_rejects_incomparable_artifacts():
    base = _artifact(seconds=10.0)
    slow_full = _artifact(seconds=60.0)
    slow_full["fast"] = False
    errs = check_bench.compare(slow_full, base, 0.20, 0.5)
    assert errs and "not comparable" in errs[0]
    gpu = _artifact(seconds=1.0)
    gpu["backend"], base["backend"] = "gpu", "cpu"
    errs = check_bench.compare(gpu, base, 0.20, 0.5)
    assert errs and "backend" in errs[0]


def test_compare_rejects_workload_mismatch():
    """A trace-mode artifact must not be gated against a Markov baseline."""
    base = _artifact(seconds=10.0)
    base["workload"] = "markov"
    tr = _artifact(seconds=1.0)
    tr["workload"] = "trace"
    errs = check_bench.compare(tr, base, 0.20, 0.5)
    assert errs and "workload" in errs[0]


def test_main_end_to_end(tmp_path):
    new = tmp_path / "new.json"
    base = tmp_path / "base.json"
    new.write_text(json.dumps(_artifact(seconds=2.0)))
    base.write_text(json.dumps(_artifact(seconds=1.9)))
    assert check_bench.main([str(new), str(base)]) == 0
    base.write_text(json.dumps(_artifact(seconds=0.9)))
    assert check_bench.main([str(new), str(base)]) == 1


def _with_extra_suite(art):
    art["suites"]["sweep_sharded"] = {"rows": ["s.a,1"], "seconds": 1.0,
                                      "error": None}
    return art


def test_stale_suites_detects_unmonitored():
    base = _artifact()
    new = _with_extra_suite(_artifact())
    assert check_bench.stale_suites(new, base) == ["sweep_sharded"]
    assert check_bench.stale_suites(_artifact(), base) == []


def test_parse_thresholds_and_per_suite_overrides():
    """--threshold accepts a global float and SUITE=FLOAT overrides; the
    override applies to its suite only (the ISSUE 5 satellite: loosen
    sweep_sharded without loosening the rest of the gate)."""
    th = check_bench.parse_thresholds(["0.25", "sweep_sharded=0.5"])
    assert th == {"*": 0.25, "sweep_sharded": 0.5}
    assert check_bench.parse_thresholds(None) == {"*": 0.20}
    import pytest
    with pytest.raises(SystemExit):
        check_bench.parse_thresholds(["sweep_sharded=fast"])
    with pytest.raises(SystemExit):
        check_bench.parse_thresholds(["=0.3"])

    base = _with_extra_suite(_artifact(seconds=10.0))
    base["suites"]["sweep_sharded"]["seconds"] = 10.0
    new = _with_extra_suite(_artifact(seconds=10.0))
    new["suites"]["sweep_sharded"]["seconds"] = 13.0    # +30%
    # global 20%: the sharded suite regresses
    assert check_bench.compare(new, base, {"*": 0.20}, 0.5)
    # per-suite 35%: within budget, and fig4 is still gated at 20%
    th = {"*": 0.20, "sweep_sharded": 0.35}
    assert not check_bench.compare(new, base, th, 0.5)
    new["suites"]["fig4"]["seconds"] = 13.0
    errs = check_bench.compare(new, base, th, 0.5)
    assert len(errs) == 1 and "fig4" in errs[0]


def test_compare_refuses_scenario_hash_mismatch():
    """Artifacts carrying scenario hashes are compared by hash: a
    mismatch is not comparable (different scenarios are different
    benchmarks), a match skips the legacy mode-string checks."""
    base = _artifact(seconds=10.0)
    new = _artifact(seconds=10.0)
    base["scenario_hash"] = "aaaa"
    new["scenario_hash"] = "bbbb"
    errs = check_bench.compare(new, base, 0.20, 0.5)
    assert errs and "scenario_hash" in errs[0]
    # equal hashes are comparable even if legacy mode strings disagree
    new["scenario_hash"] = "aaaa"
    new["workload"], base["workload"] = "trace", "markov"
    assert not check_bench.compare(new, base, 0.20, 0.5)
    # without hashes the legacy mode-string check still applies
    del new["scenario_hash"], base["scenario_hash"]
    errs = check_bench.compare(new, base, 0.20, 0.5)
    assert errs and "workload" in errs[0]


def test_compare_refuses_cloud_spec_mismatch():
    """A cloud tier in one scenario and not the other (or a different
    tier) is never comparable, hash or no hash — an offload-aware run is
    a different benchmark."""
    base = _artifact(seconds=10.0)
    new = _artifact(seconds=10.0)
    new["scenario"] = {"cloud": {"rtt_ms": 40.0, "bw_mbps": 20.0,
                                 "xfer_energy_mj_per_kb": 3.6}}
    base["scenario"] = {}
    errs = check_bench.compare(new, base, 0.20, 0.5)
    assert errs and "cloud" in errs[0]
    # same tier on both sides is fine
    base["scenario"] = dict(new["scenario"])
    assert not check_bench.compare(new, base, 0.20, 0.5)
    # differing tiers are refused
    base["scenario"] = {"cloud": {"rtt_ms": 80.0, "bw_mbps": 20.0,
                                  "xfer_energy_mj_per_kb": 3.6}}
    errs = check_bench.compare(new, base, 0.20, 0.5)
    assert errs and "cloud" in errs[0]


def test_compare_refuses_faults_spec_mismatch():
    """A fault schedule in one scenario and not the other (or a different
    schedule) is never comparable — fault injection shifts every suite's
    timing profile (mirrors the cloud-tier refusal)."""
    base = _artifact(seconds=10.0)
    new = _artifact(seconds=10.0)
    new["scenario"] = {"faults": {"down_rate": 0.05,
                                  "outages": [[2, 40, 90]]}}
    base["scenario"] = {}
    errs = check_bench.compare(new, base, 0.20, 0.5)
    assert errs and "faults" in errs[0]
    # same schedule on both sides is fine
    base["scenario"] = json.loads(json.dumps(new["scenario"]))
    assert not check_bench.compare(new, base, 0.20, 0.5)
    # differing schedules are refused
    base["scenario"] = {"faults": {"down_rate": 0.10}}
    errs = check_bench.compare(new, base, 0.20, 0.5)
    assert errs and "faults" in errs[0]


def test_main_accepts_threshold_overrides(tmp_path, capsys):
    new = tmp_path / "new.json"
    base = tmp_path / "base.json"
    new.write_text(json.dumps(_artifact(seconds=13.0)))
    base.write_text(json.dumps(_artifact(seconds=10.0)))
    assert check_bench.main([str(new), str(base)]) == 1
    assert check_bench.main([str(new), str(base),
                             "--threshold", "fig4=0.5"]) == 0
    assert check_bench.main([str(new), str(base),
                             "--threshold", "0.5"]) == 0
    # a typoed suite override is inoperative — WARN, error under --strict
    capsys.readouterr()
    assert check_bench.main([str(new), str(base), "--threshold", "0.5",
                             "--threshold", "fig-4=0.9"]) == 0
    assert "unknown suite 'fig-4'" in capsys.readouterr().out
    assert check_bench.main([str(new), str(base), "--threshold", "0.5",
                             "--threshold", "fig-4=0.9",
                             "--strict"]) == 1


def test_main_stale_baseline_warns_and_strict_fails(tmp_path, capsys):
    new = tmp_path / "new.json"
    base = tmp_path / "base.json"
    new.write_text(json.dumps(_with_extra_suite(_artifact(seconds=2.0))))
    base.write_text(json.dumps(_artifact(seconds=2.0)))
    # default: warn but pass
    assert check_bench.main([str(new), str(base)]) == 0
    assert "WARN" in capsys.readouterr().out
    # --strict: the stale baseline is a failure
    assert check_bench.main([str(new), str(base), "--strict"]) == 1
    assert "no baseline entry" in capsys.readouterr().out
