"""Kernel validation: shape/dtype sweeps against the pure-jnp oracles,
executed in Pallas interpret mode (kernel bodies run on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.profiles import paper_fleet, synthetic_fleet
from repro.kernels.decode_attention import (decode_attention,
                                            ref_decode_attention)
from repro.kernels.flash_attention import flash_attention, ref_attention
from repro.kernels.moscore import moscore_route, ref_moscore_route


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,sq,sk,h,kv,d", [
    (1, 128, 128, 2, 2, 64),
    (2, 256, 256, 4, 2, 64),
    (1, 512, 512, 2, 1, 128),
    (2, 128, 512, 2, 2, 64),     # cross-length (non-causal only)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, sq, sk, h, kv, d, dtype):
    rng = jax.random.PRNGKey(0)
    kq, kk, kv_ = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (b, sq, h, d), dtype)
    k = jax.random.normal(kk, (b, sk, kv, d), dtype)
    v = jax.random.normal(kv_, (b, sk, kv, d), dtype)
    causal = sq == sk
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=128)
    ref = ref_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("b,s,h,kv,d,partial_len", [
    (1, 512, 4, 4, 64, None),
    (2, 1024, 8, 2, 128, None),
    (2, 512, 4, 2, 64, 300),     # partially-filled cache
    (1, 2048, 2, 1, 128, 17),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(b, s, h, kv, d, partial_len, dtype):
    rng = jax.random.PRNGKey(1)
    kq, kk, kv_ = jax.random.split(rng, 3)
    q = jax.random.normal(kq, (b, h, d), dtype)
    k = jax.random.normal(kk, (b, s, kv, d), dtype)
    v = jax.random.normal(kv_, (b, s, kv, d), dtype)
    kv_len = None if partial_len is None \
        else jnp.full((b,), partial_len, jnp.int32)
    out = decode_attention(q, k, v, kv_len, n_splits=4)
    ref = ref_decode_attention(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("backend", ["pallas", "hoisted", "pallas_hoisted"])
@pytest.mark.parametrize("n_pairs,window,delta,gamma", [
    (5, 64, 20.0, 0.5),
    (5, 256, 20.0, 0.0),
    (37, 128, 10.0, 1.0),
    (200, 64, 30.0, 0.25),
])
def test_moscore(n_pairs, window, delta, gamma, backend):
    """Every fp32 backend — including the invariant-hoisted variants —
    is BITWISE identical to the reference scan: same choices, same final
    queue. (Hoisting only moves exactly-associative min/max reductions
    out of the scan; the surviving per-step expression is unchanged.)"""
    rng = jax.random.PRNGKey(2)
    prof = paper_fleet() if n_pairs == 5 else synthetic_fleet(rng, n_pairs)
    gs = jax.random.randint(rng, (window,), 0, prof.n_groups)
    q0 = jax.random.randint(jax.random.fold_in(rng, 1), (prof.n_pairs,),
                            0, 4).astype(jnp.float32)
    got_p, got_q = moscore_route(prof.T, prof.E, prof.mAP, gs, q0,
                                 delta=delta, gamma=gamma, backend=backend)
    ref_p, ref_q = ref_moscore_route(prof.T, prof.E, prof.mAP, gs, q0,
                                     delta=delta, gamma=gamma)
    np.testing.assert_array_equal(np.asarray(got_p), np.asarray(ref_p))
    np.testing.assert_array_equal(np.asarray(got_q), np.asarray(ref_q))


@pytest.mark.parametrize("backend",
                         ["pallas", "hoisted", "pallas_hoisted", "int8"])
def test_moscore_respects_accuracy_floor(backend):
    """Property: every choice is feasible for its (estimated) group —
    including the int8 backend, whose contract keeps the feasibility
    mask fp32-exact (mAP is never quantized)."""
    prof = paper_fleet()
    rng = jax.random.PRNGKey(3)
    gs = jax.random.randint(rng, (512,), 0, prof.n_groups)
    q0 = jnp.zeros((prof.n_pairs,))
    ps, _ = moscore_route(prof.T, prof.E, prof.mAP, gs, q0, delta=15.0,
                          backend=backend)
    thr = jnp.max(prof.mAP, axis=0) - 15.0
    ok = prof.mAP[ps, gs] >= thr[gs]
    assert bool(jnp.all(ok))
