"""Multi-device sweep sharding: bit-identical results across meshes, and
the padding helper's invariants. The in-process tests run on whatever
devices exist (a 1-device mesh still exercises the shard_map path); the
true multi-device guarantee is checked in a subprocess with 4 forced host
devices, so it holds even on single-device CI runners."""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.profiles import paper_fleet, stack_profiles, synthetic_fleet
from repro.core.scenario import Scenario, Sweep, run
from repro.core.simulator import ConfigGrid, SimConfig, _make_grid
from repro.distributed.sharding import config_axis_spec, pad_leading
from repro.launch.mesh import make_sweep_mesh

REPO = Path(__file__).resolve().parent.parent


def _small_sweep(mesh=None, prof=None):
    return run(Scenario(profile=prof if prof is not None else "paper",
                        n_requests=250, mesh=mesh),
               Sweep(policy=("MO", "LT", "HA"), n_users=(3, 7),
                     seed=(0, 1)))


def test_sharded_equals_single_on_local_mesh():
    """shard_map path == plain vmap path, bit for bit (any device count;
    12 configs over the mesh exercises padding whenever the device count
    doesn't divide 12)."""
    ref = _small_sweep()
    out = _small_sweep(mesh="local")
    for k in ref.metric_names:
        np.testing.assert_array_equal(out[k], ref[k], err_msg=k)


def test_sharded_equals_single_stacked_fleet():
    fleets = stack_profiles(
        [synthetic_fleet(jax.random.PRNGKey(i), 5) for i in range(2)])
    ref = _small_sweep(prof=fleets)
    out = _small_sweep(mesh="local", prof=fleets)
    assert ref.axes[0] == "fleet" and ref["latency_ms"].shape[0] == 2
    for k in ref.metric_names:
        np.testing.assert_array_equal(out[k], ref[k], err_msg=k)


def test_sharded_equals_single_trace_workload():
    """The trace workload shards like the Markov one: its device-resident
    trace is replicated and the config axis split, bit-identically."""
    from repro.data.traces import bundled_trace

    sc = Scenario(workload=bundled_trace(), n_requests=200)
    sw = Sweep(policy=("MO", "LT"), n_users=(3, 7), seed=(0, 1))
    ref = run(sc, sw)
    out = run(sc, sw, mesh=make_sweep_mesh())
    for k in ref.metric_names:
        np.testing.assert_array_equal(out[k], ref[k], err_msg=k)


_SUBPROC_CHECK = """
import json
import jax, numpy as np
from repro.core.scenario import Scenario, Sweep, run
from repro.data.traces import bundled_trace
from repro.launch.mesh import make_sweep_mesh

assert len(jax.devices()) == 4, jax.devices()
sw = Sweep(policy=("MO", "RR", "LC", "LT", "HA"), n_users=(3, 7),
           seed=(0,))                         # 10 configs -> padded to 12
sc = Scenario(n_requests=150)
ref = run(sc, sw)
mesh = make_sweep_mesh()
out = run(sc, sw, mesh=mesh)
for k in ref.metric_names:
    np.testing.assert_array_equal(out[k], ref[k], err_msg=k)

# Markov regression vs the PR 2 golden fixture, on a real 4-device mesh:
# neither the WorkloadSource refactor nor the Scenario layer may move a
# single bit even sharded.
fix = json.load(open({golden!r}))["sweep"]
gold = run(Scenario(n_requests=fix["n_requests"], mesh="local"),
           Sweep(policy=tuple(fix["policies"]),
                 n_users=tuple(fix["user_levels"]),
                 seed=tuple(fix["seeds"])))
for k, v in fix["metrics"].items():
    want = np.asarray(v).reshape(gold[k].shape)
    np.testing.assert_array_equal(gold[k], want, err_msg=k)

# Trace workload: sharded == single on 4 real devices too.
tsc = Scenario(workload=bundled_trace(), n_requests=150)
tsw = Sweep(policy=("MO", "LT"), n_users=(3, 7), seed=(0,))
t_ref = run(tsc, tsw)
t_out = run(tsc, tsw, mesh=mesh)
for k in t_ref.metric_names:
    np.testing.assert_array_equal(t_out[k], t_ref[k], err_msg=k)
print("OK")
"""


def test_sharded_bitwise_in_forced_4_device_subprocess():
    """Real multi-device bit-exactness, via xla_force_host_platform_device
    _count=4 in a fresh process (the flag only takes effect at jax init):
    sharded == single for both workload sources, and the Markov path still
    reproduces the PR 2 golden metrics bit for bit."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=str(REPO / "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    src = _SUBPROC_CHECK.format(
        golden=str(REPO / "tests" / "golden_markov_pr2.json"))
    res = subprocess.run([sys.executable, "-c", src], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout


def test_pad_leading_pads_and_preserves():
    prof = paper_fleet()
    cfgs = [SimConfig(n_users=u, n_requests=100, seed=u) for u in (2, 5, 9)]
    grid = _make_grid(prof, cfgs)
    padded, n = pad_leading(grid, 4)
    assert n == 3
    assert all(leaf.shape[0] == 4 for leaf in jax.tree.leaves(padded))
    for name in ConfigGrid._fields:
        a, b = np.asarray(getattr(padded, name)), \
            np.asarray(getattr(grid, name))
        np.testing.assert_array_equal(a[:3], b, err_msg=name)
        np.testing.assert_array_equal(a[3], b[0], err_msg=name)
    same, n = pad_leading(grid, 3)
    assert n == 3 and same is grid


def test_config_axis_spec_uses_every_mesh_axis():
    mesh = make_sweep_mesh()
    spec = config_axis_spec(mesh)
    assert tuple(spec) == (mesh.axis_names,)
    ragged = ConfigGrid(*(jnp.zeros((3,)),) * 6,
                        jnp.zeros((2, 2)), jnp.zeros((3, 4)),
                        jnp.zeros((3, 4)))
    with pytest.raises(ValueError, match="leading dim"):
        pad_leading(ragged, 4)
