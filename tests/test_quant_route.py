"""The quantized-routing-table contract (ISSUE 9).

Two backends, two contracts:

* fp32 ``hoisted`` / ``pallas_hoisted``: BIT-IDENTICAL to the unhoisted
  reference — pinned here against the PR 3 golden fixture's routing
  decisions (never regenerate it; see tests/test_dispatch.py).
* ``int8`` (:class:`~repro.core.quant.QuantProfileTable`): bounded
  decision mismatch — feasibility is fp32-exact by construction (mAP is
  never quantized), per-cell table error is bounded by half a
  quantisation step of the group column's absmax, and on the paper fleet
  the teacher-forced decision-mismatch rate and the end-metric deltas
  stay under the bounds asserted below (measured ~0.22 / ~1% worst-case;
  asserted with headroom).

The golden replay reconstructs each request's full queue vector from the
fixture alone: every user has at most one request in flight (a user's
next arrival IS its previous finish), so request ``j < i`` occupies
``server[j]`` at ``t_i`` iff ``t_arrival[j] + latency[j] > t_arrival[i]``.
The reconstruction is validated against the recorded ``q_at_dispatch``
scalars before any decision is checked, so a bad rebuild fails loudly
rather than vacuously passing.
"""

import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.policies import mo_precompute, mo_scores_hoisted
from repro.core.profiles import paper_fleet, stack_profiles, synthetic_fleet
from repro.core.quant import QuantProfileTable, quantize_roundtrip
from repro.kernels.moscore import (BACKEND_ENV, BACKENDS, moscore_route,
                                   resolve_backend)

GOLDEN = Path(__file__).resolve().parent / "golden_static_pr3.json"
PROF = paper_fleet()
P = PROF.n_pairs


# ------------------------------------------------- golden bit-identity --

def _reconstruct_queues(rec):
    """(N, P) queue-at-dispatch vectors from a golden record block."""
    t = np.asarray(rec["t_arrival"], np.float32)
    finish = t + np.asarray(rec["latency"], np.float32)
    srv = np.asarray(rec["server"], np.int32)
    qs = np.zeros((len(t), P), np.float32)
    for i in range(len(t)):
        inflight = finish[:i] > t[i]
        np.add.at(qs[i], srv[:i][inflight], 1.0)
    return qs


def test_hoisted_scores_reproduce_golden_mo_decisions():
    """Acceptance pin: the hoisted fp32 scorer, teacher-forced on every
    MO request of the PR 3 golden fixture (both configs: default γ/Δ and
    γ=0.25/Δ=10), picks EXACTLY the recorded server — the
    queue-independent precompute moved nothing."""
    fix = json.load(open(GOLDEN))
    checked = 0
    for entry in fix["records"]:
        if entry["config"]["policy"] != "MO":
            continue
        gamma = entry["config"].get("gamma", 0.5)
        delta = entry["config"].get("delta", 20.0)
        rec = entry["records"]
        qs = _reconstruct_queues(rec)
        srv = np.asarray(rec["server"], np.int32)
        ge = np.asarray(rec["g_est"], np.int32)
        # the rebuild must match the recorded per-choice queue depths,
        # or the decision check below would be meaningless
        np.testing.assert_array_equal(
            qs[np.arange(len(srv)), srv],
            np.asarray(rec["q_at_dispatch"], np.float32))
        feas, En = mo_precompute(PROF.T, PROF.E, PROF.mAP, delta=delta)
        score = jax.jit(jax.vmap(
            lambda g, q: jnp.argmin(mo_scores_hoisted(
                PROF.T[:, g], En[:, g], feas[:, g], q, gamma=gamma))))
        got = np.asarray(score(jnp.asarray(ge), jnp.asarray(qs)))
        np.testing.assert_array_equal(got, srv,
                                      err_msg=str(entry["config"]))
        checked += len(srv)
    assert checked == 240          # both MO configs, every request


# ------------------------------------------------ QuantProfileTable --

def test_quant_table_cell_error_bound_and_map_passthrough():
    """Per-cell contract: |deq - x| <= absmax of the cell's GROUP COLUMN
    / 254 (one half quantisation step), and mAP rides through untouched —
    the feasibility mask is fp32-exact by construction."""
    for prof in (PROF, synthetic_fleet(jax.random.PRNGKey(0), 37)):
        qt = QuantProfileTable.from_profile(prof)
        deq = qt.dequantize()
        for name, x, y in (("T", prof.T, deq.T), ("E", prof.E, deq.E)):
            step = np.max(np.abs(np.asarray(x)), axis=0) / 254.0
            err = np.abs(np.asarray(y) - np.asarray(x))
            assert (err <= step[None, :] + 1e-6).all(), name
        np.testing.assert_array_equal(np.asarray(deq.mAP),
                                      np.asarray(prof.mAP))
        assert qt.n_pairs == prof.n_pairs
        assert qt.n_groups == prof.n_groups
        assert qt.qT.dtype == jnp.int8 and qt.qE.dtype == jnp.int8
        # the point of the exercise: ~4x smaller hot payload
        fp32 = 2 * 4 * prof.n_pairs * prof.n_groups
        assert qt.nbytes_hot < fp32 / 2


def test_quant_table_rejects_stacked_and_crosses_jit():
    ens = stack_profiles([synthetic_fleet(jax.random.PRNGKey(i), 5)
                          for i in range(2)])
    with pytest.raises(ValueError, match="stacked"):
        QuantProfileTable.from_profile(ens)
    # registered pytree: quantize + dequantize trace under jit, and the
    # roundtrip inside jit equals the eager one bit for bit
    eager = quantize_roundtrip(PROF)
    jitted = jax.jit(lambda p: QuantProfileTable.from_profile(p)
                     .dequantize())(PROF)
    for k in ("T", "E", "mAP"):
        np.testing.assert_array_equal(np.asarray(getattr(jitted, k)),
                                      np.asarray(getattr(eager, k)),
                                      err_msg=k)
    leaves, treedef = jax.tree_util.tree_flatten(
        QuantProfileTable.from_profile(PROF))
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.names == PROF.names


# ------------------------------------------------- backend resolution --

def test_env_override_selects_backend(monkeypatch):
    """REPRO_MOSCORE_BACKEND redirects 'auto' only: explicit backends
    win, junk values fail loudly, and the override actually routes."""
    for b in ("xla", "hoisted", "int8"):
        monkeypatch.setenv(BACKEND_ENV, b)
        assert resolve_backend("auto") == b
        assert resolve_backend("pallas") == "pallas"      # explicit wins
    monkeypatch.setenv(BACKEND_ENV, "auto")               # not a target
    with pytest.raises(ValueError, match=BACKEND_ENV):
        resolve_backend("auto")
    monkeypatch.setenv(BACKEND_ENV, "cuda")
    with pytest.raises(ValueError, match=BACKEND_ENV):
        resolve_backend("auto")
    monkeypatch.delenv(BACKEND_ENV)
    assert resolve_backend("auto") in BACKENDS

    # the override reaches the hot path: an env-pinned 'auto' routes
    # identically to the explicitly named backend
    monkeypatch.setenv(BACKEND_ENV, "hoisted")
    gs = np.arange(32) % PROF.n_groups
    q0 = np.zeros(P, np.float32)
    auto_p, _ = moscore_route(PROF.T, PROF.E, PROF.mAP, gs, q0,
                              delta=15.0, gamma=0.5, backend="auto")
    named_p, _ = moscore_route(PROF.T, PROF.E, PROF.mAP, gs, q0,
                               delta=15.0, gamma=0.5, backend="hoisted")
    np.testing.assert_array_equal(np.asarray(auto_p), np.asarray(named_p))
    assert os.environ[BACKEND_ENV] == "hoisted"   # monkeypatch sanity


# ------------------------------------------------- int8 contract --

GRID = [(d, g) for d in (10.0, 20.0, 30.0) for g in (0.0, 0.25, 0.5, 1.0)]


@pytest.mark.parametrize("delta,gamma", GRID)
def test_int8_feasibility_exact_and_mismatch_bounded(delta, gamma):
    """The bounded-mismatch contract on the paper fleet, teacher-forced
    (both scorers see the SAME queue state per request, so single-step
    disagreement is measured, not compounded trajectories):

    * every int8 choice is accuracy-feasible under the FP32 mAP (the mask
      never touches quantized data);
    * the decision-mismatch rate stays under 0.35 across the full Δ x γ
      grid (measured worst case ~0.22 — mismatches happen only between
      near-tied candidates, which queue feedback makes common)."""
    rng = np.random.default_rng(int(delta) * 7 + int(gamma * 4))
    N = 400
    gs = rng.integers(0, PROF.n_groups, N)
    qs = rng.integers(0, 6, (N, P)).astype(np.float32)
    deq = quantize_roundtrip(PROF)
    feas, En = mo_precompute(PROF.T, PROF.E, PROF.mAP, delta=delta)
    feas8, En8 = mo_precompute(deq.T, deq.E, deq.mAP, delta=delta)
    np.testing.assert_array_equal(np.asarray(feas8), np.asarray(feas))

    def choose(T, Enr, F, g, q):
        return jnp.argmin(mo_scores_hoisted(T[:, g], Enr[:, g], F[:, g], q,
                                            gamma=gamma))

    pick = jax.jit(jax.vmap(choose, in_axes=(None, None, None, 0, 0)))
    fp = np.asarray(pick(PROF.T, En, feas, jnp.asarray(gs),
                         jnp.asarray(qs)))
    i8 = np.asarray(pick(deq.T, En8, feas8, jnp.asarray(gs),
                         jnp.asarray(qs)))
    thr = np.max(np.asarray(PROF.mAP), axis=0) - delta
    assert (np.asarray(PROF.mAP)[i8, gs] >= thr[gs]).all()
    mismatch = float(np.mean(fp != i8))
    assert mismatch <= 0.35, (delta, gamma, mismatch)


@pytest.mark.parametrize("delta,gamma", [(20.0, 0.5), (10.0, 0.25)])
def test_int8_end_metrics_within_bound(delta, gamma):
    """What the contract buys: routing full windows with queue feedback
    through the int8 backend moves the paper-fleet END metrics (mean
    profiled latency / energy / mAP of the chosen pairs) by under 3%
    relative to the bit-exact fp32 path (measured worst case ~1%).
    Near-tie flips redistribute load between near-equivalent pairs; they
    do not change what the fleet delivers."""
    rng = np.random.default_rng(11)
    gs = rng.integers(0, PROF.n_groups, 512)
    q0 = np.zeros(P, np.float32)
    T, E, M = (np.asarray(PROF.T), np.asarray(PROF.E),
               np.asarray(PROF.mAP))

    def metrics(backend):
        ps, _ = moscore_route(PROF.T, PROF.E, PROF.mAP, gs, q0,
                              delta=delta, gamma=gamma, backend=backend)
        ps = np.asarray(ps)
        return np.array([T[ps, gs].mean(), E[ps, gs].mean(),
                         M[ps, gs].mean()])

    ref, q8 = metrics("hoisted"), metrics("int8")
    rel = np.abs(q8 - ref) / np.abs(ref)
    assert (rel <= 0.03).all(), (delta, gamma, rel)


@settings(deadline=None, max_examples=20)
@given(st.integers(2, 64), st.integers(0, 2**31 - 1))
def test_int8_choices_always_feasible_any_fleet(n_pairs, seed):
    """Property: on ANY synthetic fleet the int8 backend never picks an
    accuracy-infeasible pair — quantisation cannot corrupt the mask."""
    prof = synthetic_fleet(jax.random.PRNGKey(seed % 997), n_pairs)
    rng = np.random.default_rng(seed)
    gs = rng.integers(0, prof.n_groups, 64)
    q0 = rng.integers(0, 4, prof.n_pairs).astype(np.float32)
    delta = float(rng.uniform(5.0, 30.0))
    ps, _ = moscore_route(prof.T, prof.E, prof.mAP, gs, q0, delta=delta,
                          gamma=0.5, backend="int8")
    ps = np.asarray(ps)
    thr = np.max(np.asarray(prof.mAP), axis=0) - delta
    assert (np.asarray(prof.mAP)[ps, gs] >= thr[gs] - 1e-6).all()


def test_int8_gateway_routes_and_matches_fp32_metrics():
    """End to end through the serving plane: a WindowedGateway pinned to
    the int8 backend routes the same stream as an fp32 gateway with end
    metrics inside the contract bound, and its int8 quantisation happens
    on the OnlineDispatch BLENDED tables (the per-window churn the
    quantized format exists for)."""
    from repro.core.dispatch import OnlineDispatch
    from repro.serving import WindowedGateway

    rng = np.random.default_rng(2)
    streams = rng.integers(0, 32, 384)
    T, E = np.asarray(PROF.T), np.asarray(PROF.E)
    out = {}
    for backend in ("xla", "int8"):
        gw = WindowedGateway(PROF, dispatch=OnlineDispatch(), seed=9,
                             backend=backend)
        q = np.zeros(P, np.float32)
        pairs_all, gs_all = [], []
        for i in range(0, len(streams), 128):
            pairs, gs, q = gw.route_window(streams[i:i + 128], q)
            pairs, gs = np.asarray(pairs), np.asarray(gs)
            gw.observe_window(pairs, gs, 1.2 * T[pairs, gs],
                              1.1 * E[pairs, gs])
            pairs_all.append(pairs)
            gs_all.append(gs)
        ps, gs = np.concatenate(pairs_all), np.concatenate(gs_all)
        out[backend] = np.array([T[ps, gs].mean(), E[ps, gs].mean()])
    rel = np.abs(out["int8"] - out["xla"]) / np.abs(out["xla"])
    assert (rel <= 0.05).all(), rel
