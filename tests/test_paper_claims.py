"""Validate the reproduction against the paper's §IV claims (orderings and
ratios — the absolute numbers are testbed-specific; DESIGN.md §11)."""

import numpy as np
import pytest

from repro.core.profiles import paper_fleet
from repro.core.scenario import Scenario, Sweep, run


@pytest.fixture(scope="module")
def results():
    pols = ("MO", "RR", "RND", "LC", "LE", "LT", "HA")
    res = run(Scenario(n_users=15, n_requests=2500), Sweep(policy=pols))
    return {pol: {m: float(res.sel(m, policy=pol))
                  for m in res.metric_names} for pol in pols}


def test_latency_ordering(results):
    """Fig 4a: LT fastest; MO well below LE and HA."""
    r = results
    assert r["LT"]["latency_ms"] < r["MO"]["latency_ms"]
    assert r["MO"]["latency_ms"] < r["LE"]["latency_ms"]
    assert r["LE"]["latency_ms"] < r["HA"]["latency_ms"]


def test_mo_latency_reduction_vs_ha(results):
    """§IV-C headline: >80% mean-latency reduction vs HA at high load."""
    ratio = results["MO"]["latency_ms"] / results["HA"]["latency_ms"]
    assert ratio < 0.30, ratio          # paper ~0.18; slack for sim noise


def test_mo_halves_energy_vs_ha(results):
    """§IV-C headline: ~half the energy per request vs HA."""
    ratio = results["MO"]["energy_mwh"] / results["HA"]["energy_mwh"]
    assert ratio < 0.65, ratio


def test_mo_accuracy_within_10pct_of_ha(results):
    gap = (results["HA"]["map"] - results["MO"]["map"]) / results["HA"]["map"]
    assert gap < 0.12, gap
    assert results["MO"]["map"] > results["RR"]["map"]
    assert results["MO"]["map"] > results["LT"]["map"] * 1.3


def test_energy_ordering(results):
    r = results
    assert r["LE"]["energy_mwh"] < r["MO"]["energy_mwh"]
    assert r["MO"]["energy_mwh"] < r["HA"]["energy_mwh"]


def test_throughput(results):
    r = results
    assert r["LT"]["throughput_rps"] > r["MO"]["throughput_rps"]
    assert r["MO"]["throughput_rps"] > 2.5 * r["HA"]["throughput_rps"]


def test_gamma_monotonicity():
    """Fig 5: latency non-increasing in gamma; gamma=0 cheapest energy."""
    res = run(Scenario(policy="MO", n_users=15, n_requests=2000),
              Sweep(gamma=(0.0, 0.5, 1.0)))
    lat = list(res["latency_ms"])
    en = list(res["energy_compute_mwh"])
    assert lat[0] >= lat[1] >= lat[2] * 0.95
    assert en[0] <= min(en[1], en[2]) + 1e-3


def test_low_load_mo_tracks_ha_accuracy():
    """Fig 4f: at 1 user MO accuracy is close to HA."""
    res = run(Scenario(n_users=1, n_requests=800),
              Sweep(policy=("MO", "HA")))
    mo = float(res.sel("map", policy="MO"))
    ha = float(res.sel("map", policy="HA"))
    assert mo > ha - 8.0


def test_table1_winners_match_paper():
    """Table I: best pair per metric/group."""
    import numpy as np
    prof = paper_fleet()
    E, T, M = np.asarray(prof.E), np.asarray(prof.T), np.asarray(prof.mAP)
    assert prof.names[int(np.argmin(E.mean(1)))] == "orin/ssd_v1"
    assert prof.names[int(np.argmin(T.mean(1)))] == "pi5tpu/ssd_v1"
    expect = ["pi5tpu/ssd_v1", "pi5tpu/ssd_lite", "orin/yolov8s",
              "pi5aihat/yolov8s", "pi5aihat/yolov8s"]
    for g, want in enumerate(expect):
        assert prof.names[int(np.argmax(M[:, g]))] == want, g
