"""Unit + property tests for the paper's Algorithm 1 and baselines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.policies import (POLICY_CODES, mo_scores, mo_select,
                                 mo_select_batch, policy_scores)
from repro.core.profiles import ProfileTable, paper_fleet, synthetic_fleet


@st.composite
def profile_and_request(draw):
    P = draw(st.integers(2, 24))
    G = draw(st.integers(2, 6))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    T = rng.uniform(10, 500, (P, G))
    E = rng.uniform(0.01, 0.5, (P, G))
    mAP = rng.uniform(1, 99, (P, G))
    g = draw(st.integers(0, G - 1))
    q = rng.integers(0, 10, P).astype(np.float32)
    delta = draw(st.floats(0.0, 60.0))
    gamma = draw(st.floats(0.0, 1.0))
    return (ProfileTable(jnp.asarray(T), jnp.asarray(E), jnp.asarray(mAP)),
            g, jnp.asarray(q), delta, gamma)


@settings(max_examples=60, deadline=None)
@given(profile_and_request())
def test_mo_select_always_feasible(case):
    """Invariant: the selected pair always satisfies the accuracy floor."""
    prof, g, q, delta, gamma = case
    p, J, feasible = mo_select(prof, g, q, delta=delta, gamma=gamma)
    thr = float(jnp.max(prof.mAP[:, g])) - delta
    assert float(prof.mAP[int(p), g]) >= thr - 1e-6
    assert bool(feasible[int(p)])


@settings(max_examples=40, deadline=None)
@given(profile_and_request())
def test_mo_scores_normalised(case):
    """Scores of feasible pairs lie in [0, 1] (weighted sum of min-max
    normalised terms)."""
    prof, g, q, delta, gamma = case
    J, feasible = mo_scores(prof.T[:, g], prof.E[:, g], prof.mAP[:, g], q,
                            delta=delta, gamma=gamma)
    Jf = np.asarray(J)[np.asarray(feasible)]
    assert (Jf >= -1e-6).all() and (Jf <= 1 + 1e-6).all()


@settings(max_examples=30, deadline=None)
@given(profile_and_request())
def test_delta_zero_selects_best_accuracy(case):
    """With delta=0 only max-mAP pairs are feasible."""
    prof, g, q, _, gamma = case
    p, _, feasible = mo_select(prof, g, q, delta=0.0, gamma=gamma)
    assert float(prof.mAP[int(p), g]) == pytest.approx(
        float(jnp.max(prof.mAP[:, g])), abs=1e-5)


def test_queue_feedback_spreads_load():
    """A window of identical requests must not all land on one pair when a
    fast-but-finite pair exists (expected-latency grows with queue)."""
    prof = paper_fleet()
    gs = jnp.full((40,), 4, jnp.int32)       # all complex scenes
    ps, q = mo_select_batch(prof, gs, jnp.zeros(5), delta=20.0, gamma=1.0)
    used = np.unique(np.asarray(ps))
    assert len(used) >= 2, "queue feedback should spread load"
    # only accuracy-feasible pairs used (n3, n4)
    assert set(used.tolist()) <= {2, 3}


def test_policy_scores_fixed_configs():
    prof = paper_fleet()
    q = jnp.zeros(5)
    rnd = jax.random.PRNGKey(0)
    le = policy_scores(POLICY_CODES["LE"], prof, 2, q, rnd, 0, 0.5, 20.0)
    ha = policy_scores(POLICY_CODES["HA"], prof, 2, q, rnd, 0, 0.5, 20.0)
    assert int(jnp.argmin(le)) == 4          # orin/ssd_v1 lowest energy
    assert int(jnp.argmin(ha)) == 2          # aihat/yolov8s best mean mAP


def test_rr_cycles():
    prof = paper_fleet()
    q = jnp.zeros(5)
    rnd = jax.random.PRNGKey(0)
    picks = [int(jnp.argmin(policy_scores(
        POLICY_CODES["RR"], prof, 0, q, rnd, c, 0.5, 20.0)))
        for c in range(10)]
    assert picks == [0, 1, 2, 3, 4, 0, 1, 2, 3, 4]


def test_gateway_matches_kernel():
    """Gateway scan path == fused kernel path, bit-for-bit assignments."""
    from repro.kernels.moscore import moscore_route

    prof = synthetic_fleet(jax.random.PRNGKey(3), 17)
    gs = jax.random.randint(jax.random.PRNGKey(4), (128,), 0, 5)
    q0 = jnp.zeros((17,))
    ps_ref, q_ref = mo_select_batch(prof, gs, q0, delta=15.0, gamma=0.3)
    ps_k, q_k = moscore_route(prof.T, prof.E, prof.mAP, gs, q0,
                              delta=15.0, gamma=0.3)
    np.testing.assert_array_equal(np.asarray(ps_ref), np.asarray(ps_k))
    np.testing.assert_allclose(np.asarray(q_ref), np.asarray(q_k))
