"""Training substrate: optimizers, schedules, microbatching, convergence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.configs import TrainingConfig
from repro.training.schedule import warmup_cosine
from repro.training.train_loop import (clip_by_global_norm, init_state, make_train_step)


def _quadratic_loss(params, batch):
    loss = jnp.sum(jnp.square(params["w"] - 3.0)) \
        + jnp.sum(jnp.square(params["b"] + 1.0))
    return loss, {"l": loss}


@pytest.mark.parametrize("opt", ["adamw", "adafactor", "sgdm"])
def test_optimizers_descend(opt):
    tcfg = TrainingConfig(optimizer=opt, lr=0.1, warmup_steps=0,
                          total_steps=1000, weight_decay=0.0, grad_clip=1e9)
    # start at 1.0, not 0: adafactor steps are relative to RMS(param), so a
    # zero init deliberately moves at the 1e-3 epsilon floor
    params = {"w": jnp.ones((128, 128)), "b": jnp.ones((4,))}
    step = make_train_step(_quadratic_loss, tcfg)
    state = init_state(params, tcfg)
    losses = []
    for _ in range(60):
        state, m = step(state, {})
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.2 * losses[0], (opt, losses[0], losses[-1])


def test_microbatch_matches_full_batch():
    """Gradient accumulation == full-batch gradients (linear loss)."""
    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        loss = jnp.mean(jnp.square(pred - batch["y"]))
        return loss, {}

    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (16, 8))
    y = jax.random.normal(jax.random.fold_in(rng, 1), (16, 4))
    params = {"w": jnp.zeros((8, 4))}

    outs = {}
    for mb in (0, 4):
        tcfg = TrainingConfig(optimizer="sgdm", lr=0.1, warmup_steps=0,
                              microbatch=mb, weight_decay=0.0, grad_clip=1e9)
        st = init_state(params, tcfg)
        st, _ = make_train_step(loss_fn, tcfg)(st, {"x": x, "y": y})
        outs[mb] = np.asarray(st["params"]["w"])
    # microbatched MSE means over 1/4 batch; scale-adjust then compare
    np.testing.assert_allclose(outs[4], outs[0], rtol=1e-4, atol=1e-5)


def test_grad_clip():
    tree = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) > 100
    n2 = float(jnp.linalg.norm(clipped["a"]))
    assert n2 == pytest.approx(1.0, rel=1e-4)


def test_warmup_cosine_shape():
    lr0 = float(warmup_cosine(jnp.asarray(0), 1.0, 10, 100))
    lr_w = float(warmup_cosine(jnp.asarray(10), 1.0, 10, 100))
    lr_end = float(warmup_cosine(jnp.asarray(100), 1.0, 10, 100))
    assert lr0 == pytest.approx(0.0)
    assert lr_w == pytest.approx(1.0)
    assert lr_end == pytest.approx(0.1, rel=1e-3)


def test_lm_training_loss_decreases():
    """A few dozen steps on the reduced LM must show real learning."""
    from repro.launch.train import train
    _, losses = train("stablelm-3b", reduced=True, steps=40, log_every=5)
    assert losses[-1] < losses[0] - 0.3, losses
