"""The workload-source contract (ISSUE 3): the Markov path through the
``WorkloadSource`` interface reproduces the PR 2 engine bit for bit, the
trace path reproduces an independent looped NumPy replay bit for bit, and
the contract's invariants hold property-based.

The golden fixture (``golden_markov_pr2.json``) was captured from the
engine at PR 2 (commit 519f2e2), before ``WorkloadSource`` existed — do
not regenerate it from current code, that would defeat the regression.
"""

import json
from pathlib import Path

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.estimator import group_of_count, markov_transition
from repro.core.policies import POLICY_CODES
from repro.core.profiles import paper_fleet
from repro.core.scenario import Scenario, Sweep, records, run
from repro.core.simulator import (SimConfig, _make_grid, _simulate_batch,
                                  summarize)
from repro.core.workload import MarkovWorkload, default_workload
from repro.data.traces import (TraceWorkload, bundled_trace, load_trace,
                               save_trace, synthetic_trace)

GOLDEN = Path(__file__).resolve().parent / "golden_markov_pr2.json"

f4 = np.float32
BIG = f4(1e30)


def _golden():
    with open(GOLDEN) as f:
        return json.load(f)


# ------------------------------------------------ Markov bit-identity --

def test_markov_records_bit_identical_to_pr2_golden():
    """The engine through the WorkloadSource interface (scenario path) ==
    the records the pre-interface engine produced, every field, every
    bit."""
    fix = _golden()
    prof = paper_fleet()
    for entry in fix["records"]:
        recs = records(Scenario(profile=prof, **entry["config"]))
        assert set(recs) == set(entry["records"])
        for k, v in entry["records"].items():
            np.testing.assert_array_equal(
                np.asarray(recs[k], np.float64), np.asarray(v), err_msg=k)


def test_markov_sweep_bit_identical_to_pr2_golden():
    fix = _golden()["sweep"]
    res = run(Scenario(n_requests=fix["n_requests"]),
              Sweep(policy=tuple(fix["policies"]),
                    n_users=tuple(fix["user_levels"]),
                    seed=tuple(fix["seeds"])))
    for k, v in fix["metrics"].items():
        want = np.asarray(v).reshape(res[k].shape)
        np.testing.assert_array_equal(res[k], want, err_msg=k)


def test_explicit_markov_workload_matches_default():
    """Passing MarkovWorkload() explicitly is the default path."""
    sc = Scenario(n_users=4, n_requests=150, policy="MO", seed=7)
    ref = records(sc)
    out = records(Scenario(n_users=4, n_requests=150, policy="MO", seed=7,
                           workload=MarkovWorkload()))
    for k in ref:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(ref[k]),
                                      err_msg=k)
    assert isinstance(default_workload(), MarkovWorkload)


# ------------------------------------- trace replay (NumPy reference) --

def _np_trace_replay(prof, cfg: SimConfig, tw: TraceWorkload,
                     n_users_max: int):
    """Looped NumPy reimplementation of the closed-loop simulator driven
    by a trace. Valid for oracle configs with RNG-free policies (MO, RR,
    LC, LT): the trace supplies every count, so no threefry draw feeds
    any record and plain float32 NumPy reproduces the scan bit for bit."""
    T = np.asarray(prof.T, f4)
    E = np.asarray(prof.E, f4)
    MAP = np.asarray(prof.mAP, f4)
    P, G = T.shape
    counts = np.asarray(tw.counts)
    S, TL = counts.shape
    true0, _rng, phase = tw.init_draws(cfg.seed, cfg.stickiness,
                                       n_groups=G, n_users=cfg.n_users)
    U = n_users_max
    gamma, delta = f4(cfg.gamma), f4(cfg.delta)
    assert cfg.oracle_estimator and cfg.policy in ("MO", "RR", "LC", "LT")

    t_next = np.where(np.arange(U) < cfg.n_users,
                      np.arange(U, dtype=f4) * f4(1e-4), f4(np.inf))
    t_next = t_next.astype(f4)
    true_cnt = np.zeros((U,), np.int32)
    true_cnt[:cfg.n_users] = true0
    ph = np.zeros((U,), np.int32)
    ph[:cfg.n_users] = phase
    pos = np.zeros((U,), np.int64)
    server = np.full((U,), -1, np.int64)
    finish_by_user = np.zeros((U,), f4)
    avail = np.zeros((P,), f4)
    rr = 0

    out = {k: [] for k in ("t_arrival", "latency", "energy", "map",
                           "server", "g_true", "g_est", "q_at_dispatch",
                           "correct_group")}
    for _ in range(cfg.n_requests):
        u = int(np.argmin(t_next))
        t = t_next[u]
        new_true = int(counts[u % S, (ph[u] + pos[u] + 1) % TL])
        g = int(np.clip(new_true, 0, G - 1))

        q = np.zeros((P,), f4)
        for v in range(U):
            if finish_by_user[v] > t and server[v] >= 0:
                q[server[v]] += f4(1.0)

        if cfg.policy == "MO":
            map_max = MAP[:, g].max()
            feas = MAP[:, g] >= map_max - delta
            L_exp = T[:, g] * (f4(1.0) + q)
            l_min = np.where(feas, L_exp, BIG).min()
            l_max = np.where(feas, L_exp, -BIG).max()
            e_min = np.where(feas, E[:, g], BIG).min()
            e_max = np.where(feas, E[:, g], -BIG).max()
            L_n = (L_exp - l_min) / np.maximum(l_max - l_min, f4(1e-9))
            E_n = (E[:, g] - e_min) / np.maximum(e_max - e_min, f4(1e-9))
            J = gamma * L_n + (f4(1.0) - gamma) * E_n
            scores = np.where(feas, J, BIG)
        elif cfg.policy == "RR":
            scores = ((np.arange(P) - rr % P) % P).astype(f4)
        elif cfg.policy == "LC":
            scores = q
        else:                                  # LT
            scores = T[:, g] * (f4(1.0) + q)
        p = int(np.argmin(scores))

        # XLA compiles the scan's ms->s conversion + add as a fused
        # multiply-add by the f32 reciprocal of the constant divisor:
        # finish = fma(T, 1/1000, start), ONE rounding. The f64 detour
        # reproduces that single rounding (the f32xf32 product is exact
        # in f64); a plain f32 mult-then-add drifts 1 ULP.
        recip = np.float64(f4(f4(1.0) / f4(1000.0)))
        start = np.maximum(t, avail[p])
        fin = f4(np.float64(start) + np.float64(T[p, g]) * recip)

        out["t_arrival"].append(t)
        out["latency"].append(f4(fin - t))
        out["energy"].append(E[p, g])
        out["map"].append(MAP[p, g])
        out["server"].append(p)
        out["g_true"].append(g)
        out["g_est"].append(g)                 # oracle: g_est == g_true
        out["q_at_dispatch"].append(q[p])
        out["correct_group"].append(f4(1.0))

        true_cnt[u] = new_true
        pos[u] += 1
        server[u] = p
        finish_by_user[u] = fin
        avail[p] = fin
        t_next[u] = fin
        rr += 1
    return {k: np.asarray(v) for k, v in out.items()}


def test_trace_records_bit_identical_to_numpy_replay():
    """The acceptance check: a trace-driven policy × users × seed grid run
    as ONE jitted vmapped scan reproduces, row by row and bit by bit, an
    independent looped NumPy replay of the same traces."""
    prof = paper_fleet()
    tw = bundled_trace()
    cfgs = [SimConfig(n_users=u, n_requests=160, policy=p, seed=s,
                      oracle_estimator=True)
            for p in ("MO", "RR", "LC", "LT")
            for u in (3, 7) for s in (0, 1)]
    grid = _make_grid(prof, cfgs, workload=tw)
    recs = _simulate_batch(prof, grid, n_requests=160, workload=tw)
    for i, cfg in enumerate(cfgs):
        ref = _np_trace_replay(prof, cfg, tw, grid.n_users_max)
        for k, v in ref.items():
            np.testing.assert_array_equal(
                np.asarray(recs[k][i], v.dtype), v,
                err_msg=f"{cfg.policy}/u{cfg.n_users}/s{cfg.seed}:{k}")


def test_trace_sweep_matches_replayed_metrics():
    """The fused summaries over a trace grid equal the engine summarizer
    applied to the NumPy-replayed records (float32-tight)."""
    prof = paper_fleet()
    tw = bundled_trace()
    pols, users, seeds = ("MO", "LT"), (3, 7), (0, 1)
    m = run(Scenario(workload=tw, n_requests=160, oracle_estimator=True),
            Sweep(policy=pols, n_users=users, seed=seeds))
    for pol in pols:
        for u in users:
            for s in seeds:
                cfg = SimConfig(n_users=u, n_requests=160, policy=pol,
                                seed=s, oracle_estimator=True)
                ref = _np_trace_replay(prof, cfg, tw, max(users))
                want = summarize({k: jax.numpy.asarray(v)
                                  for k, v in ref.items()}, prof, cfg)
                for k, v in want.items():
                    np.testing.assert_allclose(
                        m.sel(k, policy=pol, n_users=u, seed=s),
                        float(v), rtol=1e-5,
                        err_msg=f"{pol}/u{u}/s{s}:{k}")


def test_trace_single_equals_batched_row():
    """Padding/batching invariance holds for traces exactly as for the
    Markov source: each row of a mixed-n_users batch equals its own
    unpadded single run."""
    prof = paper_fleet()
    tw = synthetic_trace(seed=5, n_streams=4, n_steps=64)
    cfgs = [SimConfig(n_users=u, n_requests=200, policy="MO", seed=u,
                      workload=tw) for u in (2, 6, 11)]
    grid = _make_grid(prof, cfgs)
    recs = _simulate_batch(prof, grid, n_requests=200, workload=tw)
    for i, cfg in enumerate(cfgs):
        ref = records(Scenario(workload=tw, n_users=cfg.n_users,
                               n_requests=200, policy="MO",
                               seed=cfg.seed))
        for k in ref:
            np.testing.assert_array_equal(np.asarray(recs[k][i]),
                                          np.asarray(ref[k]), err_msg=k)


# ------------------------------------------------- contract properties --

@settings(max_examples=30, deadline=None)
@given(st.integers(2, 12), st.floats(0.0, 1.0), st.floats(0.0, 1.0))
def test_markov_transition_rows_exactly_stochastic(n, stick, drift_up):
    """Rows renormalise to exactly 1 (float32) and stay non-negative over
    the whole parameter cube, boundary values included."""
    P = np.asarray(markov_transition(n, stick, drift_up))
    np.testing.assert_allclose(P.sum(1), 1.0, atol=2e-6)
    assert (P >= 0).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(-5, 40), st.integers(1, 9))
def test_group_of_count_clips_into_range(count, n_groups):
    g = int(group_of_count(np.int32(count), n_groups))
    assert 0 <= g <= n_groups - 1
    if 0 <= count < n_groups:
        assert g == count


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 6), st.integers(2, 50),
       st.integers(1, 9))
def test_trace_workload_groups_always_in_range(seed, n_streams, n_steps,
                                               n_users):
    """For arbitrary trace shapes, seeds and per-user offsets, every count
    a TraceWorkload emits maps into group range [0, n_groups-1] — the
    initial draw and any number of steps."""
    n_groups = 5
    rng = np.random.default_rng(seed)
    tw = TraceWorkload(rng.integers(0, 12, size=(n_streams, n_steps)))
    true0, _, phase = tw.init_draws(seed, 0.85, n_groups=n_groups,
                                    n_users=n_users)
    assert true0.shape == (n_users,) and phase.shape == (n_users,)
    assert ((phase >= 0) & (phase < n_steps)).all()
    ctx = tw.prepare(n_groups, 0.85)
    for u in range(n_users):
        for k in range(n_steps + 3):           # wraps past the trace end
            c = int(tw.next_count(ctx, None, None, np.int32(u),
                                  np.int32(phase[u] + k)))
            g = int(group_of_count(np.int32(c), n_groups))
            assert 0 <= g <= n_groups - 1
            if k == 0:
                assert c == int(true0[u])


# ------------------------------------------------------ traces plumbing --

def test_trace_roundtrip_and_loader_errors(tmp_path):
    tw = synthetic_trace(seed=3, n_streams=3, n_steps=40)
    p = tmp_path / "t.npz"
    save_trace(p, tw)
    back = load_trace(p)
    assert back.name == tw.name
    np.testing.assert_array_equal(np.asarray(back.counts),
                                  np.asarray(tw.counts))
    np.savez(tmp_path / "bad.npz", other=np.arange(3))
    with pytest.raises(ValueError, match="no 'counts'"):
        load_trace(tmp_path / "bad.npz")
    with pytest.raises(ValueError, match="negative"):
        TraceWorkload(np.array([[1, -2, 3]]))
    with pytest.raises(ValueError, match="counts must be"):
        TraceWorkload(np.zeros((2, 2, 2), np.int32))
    one_d = TraceWorkload(np.arange(6))
    assert one_d.n_streams == 1 and one_d.length == 6


def test_synthetic_trace_busy_crossing_statistics():
    """The CI generator is seeded-deterministic and carries the paper's
    busy-crossing skew: complex scenes (group 3+) outnumber empty ones,
    and the 4+ group is realised as 4..max_count objects."""
    a = synthetic_trace(seed=11, n_streams=6, n_steps=400)
    b = synthetic_trace(seed=11, n_streams=6, n_steps=400)
    np.testing.assert_array_equal(np.asarray(a.counts),
                                  np.asarray(b.counts))
    c = np.asarray(a.counts)
    assert c.min() >= 0 and c.max() <= 7
    groups = np.clip(c, 0, 4)
    assert (groups == 3).mean() > (groups == 0).mean()
    assert (c >= 4).any()                      # the open-ended bucket


def test_simulate_batch_rejects_trace_grid_under_markov_default():
    """Forgetting to repeat workload= on a trace-built grid must raise,
    not silently Markov-step from trace-drawn initial states."""
    prof = paper_fleet()
    tw = bundled_trace()
    cfgs = [SimConfig(n_users=5, n_requests=50, seed=0)]
    grid = _make_grid(prof, cfgs, workload=tw)
    with pytest.raises(ValueError, match="nonzero workload phase"):
        _simulate_batch(prof, grid, n_requests=50)
    _simulate_batch(prof, grid, n_requests=50, workload=tw)  # correct call
    markov_grid = _make_grid(prof, cfgs)
    _simulate_batch(prof, markov_grid, n_requests=50)        # default fine


def test_grid_rejects_mixed_workload_sources():
    prof = paper_fleet()
    t1 = synthetic_trace(seed=1, n_streams=2, n_steps=16)
    t2 = synthetic_trace(seed=2, n_streams=2, n_steps=16)
    cfgs = [SimConfig(n_users=3, n_requests=50, workload=t1),
            SimConfig(n_users=3, n_requests=50, workload=t2)]
    with pytest.raises(ValueError, match="share a single workload"):
        _make_grid(prof, cfgs)
    with pytest.raises(ValueError, match="conflicts"):
        _make_grid(prof, cfgs[:1], workload=t2)
    grid = _make_grid(prof, cfgs[:1])          # cfg-carried source works
    assert grid.phase.shape == (1, 3)


def test_trace_init_draws_memoized_and_deterministic():
    tw = bundled_trace()
    a = tw.init_draws(4, 0.85, n_groups=5, n_users=6)
    b = tw.init_draws(4, 0.5, n_groups=5, n_users=6)   # stickiness ignored
    assert a[0] is b[0]                        # per-instance memo hit
    fresh = bundled_trace().init_draws(4, 0.85, n_groups=5, n_users=6)
    for x, y in zip(a, fresh):
        np.testing.assert_array_equal(x, y)
    t0, _, phase = a
    np.testing.assert_array_equal(
        t0, np.asarray(tw.counts)[np.arange(6) % tw.n_streams, phase])


def test_sim_config_with_trace_stays_hashable():
    """SimConfig must stay usable in sets/dicts with any workload source
    attached (the workload is compare-excluded grid data)."""
    tw = bundled_trace()
    a = SimConfig(n_users=3, workload=tw)
    b = SimConfig(n_users=3)
    assert hash(a) == hash(b) and a == b
    assert len({a, b}) == 1
    assert POLICY_CODES[a.policy] == 0
