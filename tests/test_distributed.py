"""Distribution-layer tests. Multi-device cases run in a subprocess with
forced host-platform devices (the main test process keeps 1 device so smoke
tests see the normal environment)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import DEFAULT_RULES


def _run_subprocess(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd="/root/repo", timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_rules_override_and_divisibility():
    r = DEFAULT_RULES.override(seq_kv=("model",), batch=None)
    d = r.as_dict()
    assert d["seq_kv"] == ("model",) and d["batch"] is None
    # unknown axes preserved
    assert d["heads"] == ("model",)


def test_logical_to_mesh_drops_indivisible():
    body = """
        from repro.distributed.sharding import DEFAULT_RULES, logical_to_mesh
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        # kv dim 6 not divisible by model=4 -> dropped to None
        spec = logical_to_mesh(mesh, DEFAULT_RULES, ("embed", "heads"),
                               (8, 6))
        print("spec", spec)
        assert spec[1] is None, spec
        spec2 = logical_to_mesh(mesh, DEFAULT_RULES, ("embed", "heads"),
                                (8, 8))
        assert spec2[1] == "model", spec2
        print("OK")
    """
    assert "OK" in _run_subprocess(body)


def test_seq_sharded_decode_matches_reference():
    """Distributed split-K decode (shard_map + LSE psum) == local oracle."""
    body = """
        from repro.distributed.collectives import seq_sharded_decode
        from repro.kernels.decode_attention.ref import ref_decode_attention
        from repro.launch.mesh import compat_mesh
        mesh = compat_mesh((2, 4), ("data", "model"))
        rng = jax.random.PRNGKey(0)
        B, S, H, KV, D = 2, 64, 8, 4, 16
        q = jax.random.normal(rng, (B, H, D))
        k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, KV, D))
        v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, KV, D))
        fn = seq_sharded_decode(mesh, ("data", "model"))
        out = jax.jit(fn)(q, k, v)
        ref = ref_decode_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        print("OK")
    """
    assert "OK" in _run_subprocess(body)


def test_sharded_train_step_matches_single_device():
    """One reduced LM train step on an 8-device mesh == 1-device result."""
    body = """
        import contextlib
        from repro import configs as C
        from repro.launch import steps as S
        from repro.launch.mesh import compat_mesh
        arch = C.get("stablelm-3b")
        shape = arch.shapes[0]
        mesh = compat_mesh((4, 2), ("data", "model"))
        cell1 = S.build_cell(arch, shape, mesh=None, reduced=True)
        args = S.init_concrete(cell1, jax.random.PRNGKey(0))
        _, m1 = jax.jit(cell1.step_fn)(*args)

        cell2 = S.build_cell(arch, shape, mesh=mesh, reduced=True)
        args2 = S.init_concrete(cell2, jax.random.PRNGKey(0))
        ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") \\
            else contextlib.nullcontext()
        with ctx:
            _, m2 = jax.jit(cell2.step_fn,
                            in_shardings=cell2.in_shardings(mesh))(*args2)
        a, b = float(m1["loss"]), float(m2["loss"])
        assert abs(a - b) / a < 5e-3, (a, b)
        print("OK", a, b)
    """
    assert "OK" in _run_subprocess(body)
