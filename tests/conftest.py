"""Tier-1 test configuration.

Optional-dependency gate: `hypothesis` is not part of the minimal runtime
image; when it is missing, install the deterministic fallback from
``_hypothesis_stub`` before the test modules import it, so the suite
collects and the property tests run a fixed seeded-example sweep."""

import sys
from pathlib import Path

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, str(Path(__file__).resolve().parent))
    import _hypothesis_stub

    _hypothesis_stub.install()
