"""The Scenario/Sweep/Results contract (ISSUE 5): declarative scenarios
round-trip through JSON exactly, the scenario path reproduces the legacy
kwarg engine bit for bit (pinned by the PR 3 golden fixture — do NOT
regenerate it), any Scenario field sweeps as a named axis (config-leaf
axes as ONE fused program), and the legacy entry points are
deprecation-warned shims over this path."""

import json
from dataclasses import replace
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core import scenario as SC
from repro.core.dispatch import (DriftSchedule, OnlineDispatch,
                                 StaticDispatch)
from repro.core.profiles import paper_fleet, stack_profiles, synthetic_fleet
from repro.core.scenario import (LegacyAPIWarning, Results, Scenario,
                                 Sweep, records, run)
from repro.core.simulator import SimConfig, summarize
from repro.data.traces import synthetic_trace

GOLDEN = Path(__file__).resolve().parent / "golden_static_pr3.json"

LEGACY_OK = pytest.mark.filterwarnings(
    "ignore::repro.core.scenario.LegacyAPIWarning")


def _golden():
    with open(GOLDEN) as f:
        return json.load(f)


# ------------------------------------------------- JSON round-tripping --

def _drift():
    return DriftSchedule.throttle(paper_fleet(), 4, at_step=60,
                                  t_mult=3.0, e_mult=8.0, recover_step=90)


@pytest.mark.parametrize("workload", ["none", "markov", "trace"])
@pytest.mark.parametrize("dispatch", ["none", "static", "online",
                                      "windowed"])
@pytest.mark.parametrize("drift", ["none", "throttle"])
@pytest.mark.parametrize("cloud", ["none", "tier"])
def test_scenario_roundtrip_all_component_combos(workload, dispatch,
                                                 drift, cloud):
    """Scenario.from_json(s.to_json()) == s over the full component cube
    (workload x dispatch x drift x cloud), via the dict AND the JSON
    string, with a stable hash."""
    from repro.core.cloud import CloudTier
    from repro.core.workload import MarkovWorkload

    wl = {"none": None, "markov": MarkovWorkload(),
          "trace": synthetic_trace(seed=3, n_streams=2, n_steps=24)}
    dp = {"none": None, "static": StaticDispatch(),
          "online": OnlineDispatch(alpha=0.2, prior_weight=5.0),
          "windowed": OnlineDispatch(window=12)}
    dr = {"none": None, "throttle": _drift()}
    cl = {"none": None,
          "tier": CloudTier(rtt_ms=80.0, bw_mbps=float("inf"),
                            payload_kb=np.linspace(30, 90, 5))}
    sc = Scenario(n_users=7, n_requests=90, policy="LT", gamma=0.25,
                  delta=15.0, stickiness=0.7, seed=11, mesh=None,
                  workload=wl[workload], dispatch=dp[dispatch],
                  drift=dr[drift], cloud=cl[cloud])
    back = Scenario.from_json(sc.to_json())
    assert back == sc and back.hash == sc.hash
    again = Scenario.from_json(json.dumps(sc.to_json()))
    assert again == sc
    # spec is canonical: serializing the round-trip changes nothing
    assert back.to_json() == sc.to_json()
    # components restored by VALUE, not reference
    if drift == "throttle":
        np.testing.assert_array_equal(np.asarray(back.drift.t_scale),
                                      np.asarray(sc.drift.t_scale))
    if workload == "trace":
        np.testing.assert_array_equal(np.asarray(back.workload.counts),
                                      np.asarray(sc.workload.counts))
        assert back.workload.name == sc.workload.name
    if cloud == "tier":
        np.testing.assert_array_equal(back.cloud.payload_kb,
                                      sc.cloud.payload_kb)
        assert back.cloud.bw_mbps == float("inf")


def test_roundtripped_scenario_runs_identically():
    """A spec is self-contained: the deserialized scenario (inline trace
    counts, drift arrays, engine hyper-parameters) produces bit-identical
    records to the original objects."""
    sc = Scenario(n_users=5, n_requests=120, seed=2,
                  workload=synthetic_trace(seed=9, n_streams=3,
                                           n_steps=32),
                  dispatch=OnlineDispatch(window=8), drift=_drift())
    back = Scenario.from_json(json.dumps(sc.to_json()))
    a, b = records(sc), records(back)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]),
                                      err_msg=k)


def test_scenario_profile_inline_roundtrip_and_hash_sensitivity():
    prof = synthetic_fleet(jax.random.PRNGKey(4), 5)
    sc = Scenario(profile=prof, n_requests=80)
    back = Scenario.from_json(sc.to_json())
    assert back == sc
    np.testing.assert_array_equal(np.asarray(back.resolve_profile().T),
                                  np.asarray(prof.T))
    # the hash actually discriminates scenarios...
    assert sc.hash != Scenario(n_requests=80).hash
    assert Scenario(seed=0).hash != Scenario(seed=1).hash
    # ...but NOT by mesh: sharded runs are bit-identical, so a --sharded
    # artifact stays gateable against the single-device baseline
    assert Scenario(mesh="local").hash == Scenario().hash
    assert Scenario(mesh="local").to_json()["mesh"] == "local"


def test_default_equivalent_components_share_one_spec():
    """An explicit MarkovWorkload()/StaticDispatch() IS the default: the
    spec canonicalizes them to null, so default-equivalent scenarios are
    == with one hash — a hand-written --scenario spec saying
    {"kind": "markov"} gates cleanly against the committed baseline."""
    from repro.core.workload import MarkovWorkload

    assert Scenario(workload=MarkovWorkload()) == Scenario()
    assert Scenario(workload=MarkovWorkload()).hash == Scenario().hash
    assert Scenario(dispatch=StaticDispatch()) == Scenario()
    assert Scenario(dispatch=StaticDispatch()).hash == Scenario().hash
    assert Scenario(workload=MarkovWorkload()).to_json()["workload"] is None
    # the explicit spec forms still parse
    spec = Scenario().to_json()
    spec["workload"] = {"kind": "markov"}
    spec["dispatch"] = {"kind": "static"}
    assert Scenario.from_json(spec) == Scenario()
    # non-default components still discriminate
    assert Scenario(dispatch=OnlineDispatch()).hash != Scenario().hash


def test_scenario_validation():
    with pytest.raises(ValueError, match="unknown profile"):
        Scenario(profile="nope")
    with pytest.raises(ValueError, match="unknown policy"):
        Scenario(policy="XX")
    with pytest.raises(ValueError, match="mesh must be"):
        Scenario(mesh="galaxy")
    with pytest.raises(TypeError, match="profile must be"):
        Scenario(profile=123)
    with pytest.raises(ValueError, match="not a repro-scenario/v1"):
        Scenario.from_json({"schema": "other"})


# --------------------------------------- golden bit-identity (PR 3) ----

def test_scenario_records_bit_identical_to_pr3_golden():
    """records(Scenario(...)) reproduces the pre-DispatchEngine engine's
    records bit for bit — the scenario path IS the engine, not a copy."""
    fix = _golden()
    prof = paper_fleet()
    for entry in fix["records"]:
        sc = Scenario(profile=prof, **entry["config"])
        recs = records(sc)
        assert set(recs) == set(entry["records"])
        for k, v in entry["records"].items():
            np.testing.assert_array_equal(
                np.asarray(recs[k], np.float64), np.asarray(v),
                err_msg=f"{entry['config']}:{k}")


def test_scenario_sweep_bit_identical_to_pr3_golden():
    """run(Scenario, Sweep) over the golden grid == the golden sweep
    metrics, every bit — the named-axis layout maps onto the legacy
    SWEEP_AXES product exactly."""
    fix = _golden()["sweep"]
    res = run(Scenario(profile=paper_fleet(),
                       n_requests=fix["n_requests"]),
              Sweep(policy=tuple(fix["policies"]),
                    n_users=tuple(fix["user_levels"]),
                    seed=tuple(fix["seeds"])))
    assert res.axes == ("policy", "n_users", "seed")
    for k, v in fix["metrics"].items():
        ref = np.asarray(v).reshape(len(fix["policies"]),
                                    len(fix["user_levels"]),
                                    len(fix["seeds"]))
        np.testing.assert_array_equal(res[k], ref, err_msg=k)


@LEGACY_OK
def test_legacy_entry_points_warn_and_match_scenario_path():
    """Every legacy entry point issues LegacyAPIWarning and returns
    bit-identical results to its scenario-path replacement."""
    from repro.core import simulator as SIM

    prof = paper_fleet()
    kw = dict(policies=("MO", "LT"), user_levels=(3, 7), seeds=(0, 1),
              n_requests=150)
    with pytest.warns(LegacyAPIWarning):
        legacy = SIM.sweep_grid(prof, **kw)
    res = run(Scenario(profile=prof, n_requests=150),
              Sweep(policy=("MO", "LT"), n_users=(3, 7), gamma=(0.5,),
                    delta=(20.0,), oracle_estimator=(False,),
                    seed=(0, 1)))
    for k in legacy:
        np.testing.assert_array_equal(legacy[k], res[k], err_msg=k)

    cfg = SimConfig(n_users=4, n_requests=120, seed=5)
    with pytest.warns(LegacyAPIWarning):
        ref = SIM.simulate(prof, cfg)
    out = records(Scenario(profile=prof, n_users=4, n_requests=120,
                           seed=5))
    for k in ref:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(ref[k]), err_msg=k)

    with pytest.warns(LegacyAPIWarning):
        rp = SIM.run_policy(prof, "MO", n_users=4, n_requests=120, seed=5)
    sc = Scenario(profile=prof, n_users=4, n_requests=120, seed=5)
    want = {k: float(v)
            for k, v in summarize(records(sc), prof, sc.to_config()).items()}
    assert rp == want

    with pytest.warns(LegacyAPIWarning):
        grid = SIM.make_grid(prof, [cfg])
    with pytest.warns(LegacyAPIWarning):
        recs = SIM.simulate_batch(prof, grid, n_requests=120)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(recs[k][0]),
                                      np.asarray(ref[k]), err_msg=k)

    with pytest.warns(LegacyAPIWarning):
        sw = SIM.sweep(prof, ["MO"], [3], n_requests=120, seeds=(0, 1))
    np.testing.assert_allclose(
        sw["MO"]["latency_ms"][0],
        run(Scenario(profile=prof, n_requests=120),
            Sweep(policy=("MO",), n_users=(3,),
                  seed=(0, 1))).mean("latency_ms", over="seed")[0, 0])


# ----------------------------------------- new axes, fused programs ----

def test_stickiness_axis_runs_as_one_fused_program(monkeypatch):
    """The acceptance check: an axis OUTSIDE the old SWEEP_AXES tuple
    (stickiness) runs end-to-end through run() as ONE fused device
    program and lands as a named axis of the Results."""
    from repro.core import simulator as SIM

    calls = []
    orig = SIM._sweep_summaries

    def spy(*args, **kwargs):
        calls.append(1)
        return orig(*args, **kwargs)

    monkeypatch.setattr(SIM, "_sweep_summaries", spy)
    sticks, seeds = (0.5, 0.85, 0.99), (0, 1)
    res = run(Scenario(n_users=6, n_requests=150),
              Sweep(stickiness=sticks, seed=seeds))
    assert len(calls) == 1                     # ONE fused program
    assert res.axes == ("stickiness", "seed")
    assert res.coords["stickiness"] == sticks
    assert res["latency_ms"].shape == (3, 2)
    # each stickiness slice equals its own per-value fused run, bit for
    # bit, and the scalar summarize path agrees to float32 tolerance
    # (vmap may reassociate reductions — same bound as summarize_batch)
    for st in sticks:
        one = run(Scenario(n_users=6, n_requests=150, stickiness=st),
                  Sweep(seed=seeds))
        np.testing.assert_array_equal(res.sel("latency_ms", stickiness=st),
                                      one["latency_ms"])
        for sd in seeds:
            sc = Scenario(n_users=6, n_requests=150, stickiness=st,
                          seed=sd)
            want = summarize(records(sc), paper_fleet(), sc.to_config())
            np.testing.assert_allclose(
                res.sel("latency_ms", stickiness=st, seed=sd),
                np.float64(want["latency_ms"]), rtol=1e-5)
    # varying stickiness genuinely changes the workload
    assert len({res["latency_ms"][i, 0] for i in range(3)}) == 3


def test_drift_axis_fuses_same_shape_schedules(monkeypatch):
    """A drift axis over same-shape schedules becomes one vmapped batch
    axis — no per-value Python loop — and each slice equals the
    per-drift scalar run."""
    from repro.core import simulator as SIM

    calls = []
    orig = SIM._sweep_summaries

    def spy(*args, **kwargs):
        calls.append(1)
        return orig(*args, **kwargs)

    monkeypatch.setattr(SIM, "_sweep_summaries", spy)
    prof = paper_fleet()
    drifts = tuple(DriftSchedule.throttle(prof, 4, at_step=50, t_mult=tm,
                                          e_mult=2.0)
                   for tm in (1.5, 3.0, 6.0))
    sc = Scenario(profile=prof, n_users=6, n_requests=150)
    res = run(sc, Sweep(drift=drifts, seed=(0, 1)))
    assert not calls                           # fused drift path, no loop
    assert res.axes == ("drift", "seed")
    assert res["latency_ms"].shape == (3, 2)
    for d in drifts:
        one = run(replace(sc, drift=d), Sweep(seed=(0, 1)))
        np.testing.assert_array_equal(res.sel("latency_ms", drift=d),
                                      one["latency_ms"], err_msg="drift")
    # severity ordering: harsher throttle of the energy favourite hurts
    lat = res.mean("latency_ms", over="seed")
    assert lat[2] > lat[0]
    # sel() matches by VALUE, not identity: a schedule rebuilt with the
    # same arguments (or round-tripped through JSON) selects its entry
    rebuilt = DriftSchedule.throttle(prof, 4, at_step=50, t_mult=3.0,
                                     e_mult=2.0)
    np.testing.assert_array_equal(res.sel("latency_ms", drift=rebuilt),
                                  res.sel("latency_ms", drift=drifts[1]))


def test_component_axes_loop_with_named_coords():
    """workload / dispatch axes (different pytree structures) run one
    fused program per value but still land as named axes."""
    tw = synthetic_trace(seed=5, n_streams=3, n_steps=48)
    res = run(Scenario(n_users=4, n_requests=120),
              Sweep(workload=(None, tw),
                    dispatch=(None, OnlineDispatch())))
    assert res.axes == ("workload", "dispatch")
    assert res["latency_ms"].shape == (2, 2)
    base = run(Scenario(n_users=4, n_requests=120))
    np.testing.assert_array_equal(
        res.sel("latency_ms", workload=None, dispatch=None),
        base["latency_ms"])
    tr = run(Scenario(n_users=4, n_requests=120, workload=tw))
    np.testing.assert_array_equal(
        res.sel("latency_ms", workload=tw, dispatch=None),
        tr["latency_ms"])


def test_n_requests_static_axis():
    res = run(Scenario(n_users=3), Sweep(n_requests=(80, 160)))
    assert res.axes == ("n_requests",)
    one = run(Scenario(n_users=3, n_requests=160))
    np.testing.assert_array_equal(res.sel("makespan_s", n_requests=160),
                                  one["makespan_s"])


def test_profile_axis_stacks_same_shape_fleets():
    fleets = [synthetic_fleet(jax.random.PRNGKey(i), 5) for i in range(2)]
    res = run(Scenario(n_users=4, n_requests=120),
              Sweep(seed=(0, 1), profile=tuple(fleets)))
    assert res.axes == ("seed", "profile")
    for f, fleet in enumerate(fleets):
        one = run(Scenario(profile=fleet, n_users=4, n_requests=120),
                  Sweep(seed=(0, 1)))
        np.testing.assert_array_equal(
            res.sel("latency_ms", profile=fleets[f]), one["latency_ms"])


def test_ragged_profile_axis_overrides_stacked_base():
    """A profile axis of differing shapes loops (no stacking) and fully
    replaces the scenario's own profile — even a stacked one: no phantom
    implicit fleet axis, and each slice equals that fleet's own run."""
    ragged = (synthetic_fleet(jax.random.PRNGKey(0), 4),
              synthetic_fleet(jax.random.PRNGKey(1), 6))
    base = stack_profiles([paper_fleet(), paper_fleet()])
    res = run(Scenario(profile=base, n_users=3, n_requests=80),
              Sweep(profile=ragged, seed=(0, 1)))
    assert res.axes == ("profile", "seed")
    assert res["latency_ms"].shape == (2, 2)
    for fleet in ragged:
        one = run(Scenario(profile=fleet, n_users=3, n_requests=80),
                  Sweep(seed=(0, 1)))
        np.testing.assert_array_equal(res.sel("latency_ms", profile=fleet),
                                      one["latency_ms"])


def test_stacked_profile_adds_named_fleet_axis():
    ens = stack_profiles([synthetic_fleet(jax.random.PRNGKey(i), 5)
                          for i in range(3)])
    res = run(Scenario(profile=ens, n_users=4, n_requests=100),
              Sweep(policy=("MO", "LT")))
    assert res.axes == ("fleet", "policy")
    assert res["latency_ms"].shape == (3, 2)
    assert res.coords["fleet"] == (0, 1, 2)


def test_mesh_spec_is_bit_identical_to_single_device():
    sw = Sweep(policy=("MO", "LT"), n_users=(3, 7), seed=(0,))
    ref = run(Scenario(n_requests=120), sw)
    out = run(Scenario(n_requests=120, mesh="local"), sw)
    for k in ref.metric_names:
        np.testing.assert_array_equal(out[k], ref[k], err_msg=k)


# --------------------------------------------------- records batched ----

def test_records_batched_rows_equal_single_runs():
    sc = Scenario(n_users=5, n_requests=120)
    sweep = Sweep(policy=("MO", "RR"), seed=(0, 1, 2))
    recs = records(sc, sweep)
    assert recs["latency"].shape == (2, 3, 120)
    for pi, pol in enumerate(("MO", "RR")):
        for si in range(3):
            one = records(replace(sc, policy=pol, seed=si))
            for k in one:
                np.testing.assert_array_equal(
                    np.asarray(recs[k][pi, si]), np.asarray(one[k]),
                    err_msg=f"{pol}/s{si}:{k}")


def test_records_rejects_component_axes():
    with pytest.raises(ValueError, match="config-leaf axes only"):
        records(Scenario(), Sweep(dispatch=(None, OnlineDispatch())))


# ----------------------------------------------- Sweep / Results API ----

def test_sweep_validation_and_scalars():
    with pytest.raises(ValueError, match="unknown sweep axis"):
        Sweep(users=(3,))
    with pytest.raises(ValueError, match="unknown sweep axis"):
        Sweep(mesh=("local",))
    with pytest.raises(ValueError, match="no values"):
        Sweep(seed=())
    sw = Sweep(policy="MO", seed=range(2))     # scalars + ranges coerce
    assert sw.names == ("policy", "seed")
    assert sw.values("policy") == ("MO",) and sw.shape == (1, 2)
    with pytest.raises(KeyError):
        sw.values("gamma")
    assert Sweep(seed=(0, 1)) == Sweep(seed=[0, 1])


def test_results_sel_mean_scalar_errors():
    res = run(Scenario(n_users=3, n_requests=100),
              Sweep(policy=("MO", "LT"), seed=(0, 1)))
    assert isinstance(res, Results)
    with pytest.raises(KeyError, match="no axis"):
        res.sel("latency_ms", gamma=0.5)
    with pytest.raises(KeyError, match="not on axis"):
        res.sel("latency_ms", policy="HA")
    with pytest.raises(ValueError, match="use sel"):
        res.scalar("latency_ms")
    assert res.mean("latency_ms", over="seed").shape == (2,)
    assert res.mean("latency_ms", over=("policy", "seed")).shape == ()
    scalar = run(Scenario(n_users=3, n_requests=100))
    assert scalar.shape == () and scalar.scalar("map") > 0
    assert "Results" in repr(res) and "policy" in repr(res)


def test_profile_registry_extensible():
    SC.register_profile("tiny-test",
                        lambda: synthetic_fleet(jax.random.PRNGKey(0), 4))
    try:
        sc = Scenario(profile="tiny-test", n_users=3, n_requests=80)
        assert sc.resolve_profile().n_pairs == 4
        assert Scenario.from_json(sc.to_json()) == sc    # by name
        assert run(sc).scalar("latency_ms") > 0
    finally:
        del SC.PROFILE_REGISTRY["tiny-test"]


# ------------------------------------------------- serving gateway ----

def test_gateway_accepts_scenario():
    """WindowedGateway(scenario) adopts the scenario's profile, policy,
    gamma, delta, seed and dispatch engine — sim and serving share ONE
    config object (the deprecated per-request Gateway shim inherits the
    identical resolution; tests/test_serving_plane.py pins the shim)."""
    from repro.serving.gateway import WindowedGateway

    sc = Scenario(policy="LT", gamma=0.75, delta=5.0, seed=7,
                  dispatch=OnlineDispatch(window=4))
    gw = WindowedGateway(sc)
    assert gw.policy == "LT" and gw.gamma == 0.75 and gw.delta == 5.0
    assert gw.seed == 7 and gw.dispatch == OnlineDispatch(window=4)
    assert gw.online is True       # any OnlineDispatch flavour counts
    np.testing.assert_array_equal(
        np.asarray(gw.prof.T), np.asarray(sc.resolve_profile().T))
    # identical decisions to the kwarg-built gateway
    ref = WindowedGateway(paper_fleet(), policy="LT", gamma=0.75,
                          delta=5.0, seed=7,
                          dispatch=OnlineDispatch(window=4))
    q = np.zeros(5, np.float32)
    np.testing.assert_array_equal(
        np.asarray(gw.route_window(range(4), q)[0]),
        np.asarray(ref.route_window(range(4), q)[0]))
    with pytest.raises(ValueError, match="stacked"):
        WindowedGateway(Scenario(profile=stack_profiles(
            [paper_fleet(), paper_fleet()])))
    # a redundant online=True must NOT swap the scenario's tuned engine
    # for a default OnlineDispatch(); it only fills in when the scenario
    # left dispatch unset
    tuned = WindowedGateway(sc, online=True)
    assert tuned.dispatch == OnlineDispatch(window=4)
    bare = WindowedGateway(Scenario(), online=True)
    assert bare.dispatch == OnlineDispatch()
    # explicitly passed non-default knobs win over the scenario (tweak
    # one knob on a shared spec); untouched knobs adopt the scenario's
    tweaked = WindowedGateway(sc, policy="HA", gamma=0.9)
    assert tweaked.policy == "HA" and tweaked.gamma == 0.9
    assert tweaked.delta == 5.0 and tweaked.seed == 7
