"""A tiny deterministic stand-in for the slice of the `hypothesis` API the
tier-1 tests use (``given``, ``settings``, ``strategies.integers/floats/
composite``).

Installed by ``conftest.py`` only when the real package is absent (the CI
image pins just jax + numpy + pytest). Each ``@given`` test then runs a
fixed number of seeded examples instead of hypothesis' adaptive search —
weaker shrinking/coverage, but the property assertions still execute on a
spread of inputs and stay deterministic across runs."""

from __future__ import annotations

import sys
import types

import numpy as np

N_EXAMPLES = 10
_SEED = 0xC0FFEE


class _Strategy:
    """A strategy is just a sampler: rng -> value."""

    def __init__(self, sample):
        self.sample = sample


def integers(min_value, max_value):
    return _Strategy(lambda r: int(r.integers(min_value, max_value + 1)))


def floats(min_value, max_value):
    return _Strategy(lambda r: float(r.uniform(min_value, max_value)))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda r: elements[int(r.integers(0, len(elements)))])


def composite(fn):
    def build(*args, **kwargs):
        def sample(rng):
            def draw(strategy):
                return strategy.sample(rng)

            return fn(draw, *args, **kwargs)

        return _Strategy(sample)

    return build


def given(*strategies, **kw_strategies):
    def deco(fn):
        def runner():
            for i in range(N_EXAMPLES):
                rng = np.random.default_rng(_SEED + i)
                fn(*[s.sample(rng) for s in strategies],
                   **{k: s.sample(rng) for k, s in kw_strategies.items()})

        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner

    return deco


def settings(**_kwargs):
    return lambda fn: fn


def install() -> None:
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.sampled_from = sampled_from
    st.composite = composite
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
