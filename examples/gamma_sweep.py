"""Reproduce Fig. 5: the gamma knob trading latency against energy.

  PYTHONPATH=src python examples/gamma_sweep.py
"""

from repro.core.scenario import Scenario, Sweep, run

GAMMAS = (0.0, 0.25, 0.5, 0.75, 1.0)
res = run(Scenario(policy="MO", n_users=15, n_requests=2000),
          Sweep(gamma=GAMMAS))

print(f"{'gamma':>6} {'lat_ms':>8} {'p90_ms':>8} {'thr_rps':>8} "
      f"{'mWh/req':>8} {'mAP':>6}")
for gamma in GAMMAS:
    at = lambda m: float(res.sel(m, gamma=gamma))  # noqa: E731
    print(f"{gamma:6.2f} {at('latency_ms'):8.0f} "
          f"{at('latency_p90_ms'):8.0f} {at('throughput_rps'):8.1f} "
          f"{at('energy_mwh'):8.3f} {at('map'):6.1f}")
print("\nsmaller gamma -> energy priority; larger -> latency priority; "
      "accuracy is protected by the hard mAP tolerance either way.")
