"""Reproduce Fig. 5: the gamma knob trading latency against energy.

  PYTHONPATH=src python examples/gamma_sweep.py
"""

from repro.core.profiles import paper_fleet
from repro.core.simulator import run_policy

prof = paper_fleet()
print(f"{'gamma':>6} {'lat_ms':>8} {'p90_ms':>8} {'thr_rps':>8} "
      f"{'mWh/req':>8} {'mAP':>6}")
for gamma in (0.0, 0.25, 0.5, 0.75, 1.0):
    r = run_policy(prof, "MO", n_users=15, n_requests=2000, gamma=gamma)
    print(f"{gamma:6.2f} {r['latency_ms']:8.0f} {r['latency_p90_ms']:8.0f} "
          f"{r['throughput_rps']:8.1f} {r['energy_mwh']:8.3f} {r['map']:6.1f}")
print("\nsmaller gamma -> energy priority; larger -> latency priority; "
      "accuracy is protected by the hard mAP tolerance either way.")
