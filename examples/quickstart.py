"""Quickstart: the paper's two-stage multi-objective balancer in 30 lines.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.core.policies import mo_select, mo_select_batch
from repro.core.profiles import paper_fleet
from repro.core.scenario import Scenario, Sweep, run

prof = paper_fleet()

# --- one decision: group g=3 (3 objects), queue depths q -------------------
q = jnp.array([2.0, 0.0, 5.0, 1.0, 0.0])
p_star, scores, feasible = mo_select(prof, g=3, q=q, delta=20.0, gamma=0.5)
print("feasible pairs:", [prof.names[i] for i in range(5) if feasible[i]])
print("selected:", prof.names[int(p_star)])

# --- a routing window with queue feedback ----------------------------------
groups = jnp.array([0, 1, 4, 4, 2, 3, 4, 0])
pairs, q_after = mo_select_batch(prof, groups, jnp.zeros(5), delta=20.0,
                                 gamma=0.5)
print("window assignment:", [prof.names[int(p)] for p in pairs])
print("queues after:", q_after)

# --- full closed-loop simulation vs the accuracy-centric baseline ----------
# One Scenario, swept over the policy axis — a single fused device program.
res = run(Scenario(n_users=15, n_requests=1500),
          Sweep(policy=("MO", "HA", "LT")))
for pol in ("MO", "HA", "LT"):
    print(f"{pol:3s}: latency={res.sel('latency_ms', policy=pol):7.0f} ms  "
          f"energy={res.sel('energy_mwh', policy=pol):.3f} mWh  "
          f"mAP={res.sel('map', policy=pol):.1f}")
