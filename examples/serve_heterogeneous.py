"""End-to-end serving driver (the paper's system, real execution):

  * 5 heterogeneous "edge" executors each running a REAL (tiny) single-shot
    detector on this host;
  * synthetic pedestrian-crossing video streams with Markov scene
    complexity;
  * the gateway estimates each frame's complexity from the PREVIOUS frame's
    actual detections (paper §III-B.1), filters by accuracy tolerance and
    scores latency x energy (Algorithm 1);
  * compares MO vs RR / LT / HA on latency, energy, and detection quality.

  PYTHONPATH=src python examples/serve_heterogeneous.py
"""

import json

from repro.core.profiles import paper_fleet
from repro.serving.engine import ServingEngine

TIERS = ["ssd_v1", "ssd_lite", "yolo_m", "yolo_s", "ssd_v1"]

prof = paper_fleet()
print(f"fleet: {list(prof.names)}")

results = {}
for policy in ("MO", "RR", "LT", "HA"):
    eng = ServingEngine.build(prof, policy=policy, n_streams=8, mode="real",
                              tiers=TIERS, img_res=64, seed=0)
    recs = eng.run(n_requests=240, concurrency=8)
    results[policy] = eng.summarize(recs)
    r = results[policy]
    print(f"{policy:3s}: latency={r['latency_ms']:7.1f} ms "
          f"p90={r['latency_p90_ms']:7.1f} energy={r['energy_mwh']:.3f} mWh "
          f"mAP*={r['map']:.1f} est_acc={r['estimator_acc']:.2f}")

mo, ha = results["MO"], results["HA"]
print(json.dumps({
    "mo_vs_ha_latency_ratio": round(mo["latency_ms"] / ha["latency_ms"], 3),
    "mo_vs_ha_energy_ratio": round(mo["energy_mwh"] / ha["energy_mwh"], 3),
    "map_gap_pct": round(100 * (ha["map"] - mo["map"]) / ha["map"], 2),
}, indent=2))
