"""Train a small LM for a few hundred steps on CPU with checkpoint/resume.

Exercises the full production train path (cell build -> jit train step ->
async checkpointing -> crash recovery) at a size this box can execute:

  PYTHONPATH=src python examples/train_lm.py
"""

import shutil
import tempfile

from repro.launch.train import train

ckpt = tempfile.mkdtemp(prefix="repro_lm_ckpt_")
try:
    # phase 1: train 120 steps, checkpoint every 40
    _, losses1 = train("stablelm-3b", reduced=True, steps=120,
                       ckpt_dir=ckpt, ckpt_every=40)
    print(f"phase1: loss {losses1[0]:.3f} -> {losses1[-1]:.3f}")
    assert losses1[-1] < losses1[0], "loss should decrease"

    # phase 2: simulate a preemption + restart; resumes from step 120
    _, losses2 = train("stablelm-3b", reduced=True, steps=200,
                       ckpt_dir=ckpt, resume="auto", ckpt_every=40)
    print(f"phase2 (resumed): loss -> {losses2[-1]:.3f}")
    assert losses2[-1] <= losses1[-1] + 0.5
    print("OK: trained 200 steps across a restart, loss decreased")
finally:
    shutil.rmtree(ckpt, ignore_errors=True)
