"""Gradient compression for cross-pod all-reduce.

Intra-pod ICI is fast (~50 GB/s/link); the pod-to-pod hop is the scarce
resource on multi-pod meshes. ``int8 chunked`` compression quantises
gradients with a per-chunk fp32 scale (<= 0.4% cosine error on transformer
grads, validated in tests) for the 'pod'-axis reduction, cutting cross-pod
bytes ~3.6x (2B bf16 -> 1B payload + scale overhead).

Usable two ways:
  * quantize/dequantize pair around any collective (shard_map manual path);
  * ``compressed_psum(x, 'pod')`` — psum of dequantised int8 (semantically a
    compressed all-reduce; on real fleets the wire format is the int8).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32


def quantize_int8(x, chunk: int = 256):
    """x (any shape) -> (q int8 flat-chunked, scales f32, orig_shape)."""
    flat = x.astype(f32).reshape(-1)
    pad = (-flat.size) % chunk
    flat = jnp.pad(flat, (0, pad))
    ck = flat.reshape(-1, chunk)
    scale = jnp.max(jnp.abs(ck), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(ck / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0], x.shape


def dequantize_int8(q, scale, shape):
    flat = (q.astype(f32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress_roundtrip(x, chunk: int = 256):
    q, s, shp = quantize_int8(x, chunk)
    return dequantize_int8(q, s, shp)


def compressed_psum(x, axis_name: str, chunk: int = 256):
    """Wire-compressed cross-pod gradient reduction (shard_map context):
    each pod quantises its partial sum; the psum runs on the dequantised
    values (the int8 payload is what would cross the DCN)."""
    q, s, shp = quantize_int8(x, chunk)
    deq = dequantize_int8(q, s, shp)
    return jax.lax.psum(deq, axis_name)


def quantization_error(x, chunk: int = 256):
    """Relative L2 error of the chunked int8 round trip.

    Worst-case bound: each element's error is at most half a quantisation
    step of its chunk's absmax, ``|x - deq(q(x))| <= absmax_c / 254``, so
    over a chunk ``||err||_2 <= sqrt(n_c) * absmax_c / 254`` while
    ``||x||_2 >= absmax_c`` — giving ``rel_l2 <= sqrt(chunk) / 254``
    for any input (hypothesis-tested across shapes and chunk sizes in
    ``tests/test_training.py``; typical random data sits two orders of
    magnitude below the bound). Shared by the cross-pod gradient
    compression and the ``repro.core.quant`` routing tables."""
    y = compress_roundtrip(x, chunk)
    return jnp.linalg.norm((y - x).reshape(-1)) / \
        (jnp.linalg.norm(x.reshape(-1)) + 1e-12)


#: Backwards-compatible alias (pre-quantized-routing name).
compression_error = quantization_error
