"""Generic train-step builder: loss -> grads (optionally microbatched) ->
clip -> schedule -> optimizer. Works for every family in the zoo; the loss
callable owns all model specifics.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.common import flags
from repro.common.configs import TrainingConfig
from repro.training.optimizer import make_optimizer
from repro.training.schedule import warmup_cosine

f32 = jnp.float32


def TrainState(params, opt_state, step=None, extra=None):
    st = {"params": params, "opt": opt_state,
          "step": step if step is not None else jnp.zeros((), jnp.int32)}
    if extra is not None:
        st["extra"] = extra
    return st


def init_state(loss_params, tcfg: TrainingConfig, extra=None):
    opt = make_optimizer(tcfg)
    return TrainState(loss_params, opt.init(loss_params), extra=extra)


def abstract_state(abstract_params, tcfg: TrainingConfig, extra=None):
    """Shape-only TrainState for dry-run lowering (no allocation)."""
    opt = make_optimizer(tcfg)
    opt_shapes = jax.eval_shape(opt.init, abstract_params)
    st = {"params": abstract_params, "opt": opt_shapes,
          "step": jax.ShapeDtypeStruct((), jnp.int32)}
    if extra is not None:
        st["extra"] = extra
    return st


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(f32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (n + 1e-9))
    return jax.tree.map(lambda g: (g.astype(f32) * scale).astype(g.dtype), tree), n


def make_train_step(loss_fn: Callable, tcfg: TrainingConfig,
                    has_extra_state: bool = False):
    """loss_fn(params, batch[, extra]) -> (loss, metrics[, new_extra]).

    Returns step(state, batch) -> (state, metrics). If ``tcfg.microbatch``
    > 0, the batch's leading dim is split into microbatches and gradients
    accumulate in fp32 via lax.scan (sequential — the standard memory/
    throughput trade; also the hook where pipeline-parallel schedules would
    attach).
    """
    opt = make_optimizer(tcfg)

    def compute_grads(params, batch, extra):
        if has_extra_state:
            def wrapped(p):
                loss, (metrics, new_extra) = loss_fn(p, batch, extra)
                return loss, (metrics, new_extra)
            (loss, (metrics, new_extra)), grads = jax.value_and_grad(
                wrapped, has_aux=True)(params)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch), has_aux=True)(params)
            new_extra = extra
        return loss, metrics, grads, new_extra

    def step(state, batch):
        params = state["params"]
        extra = state.get("extra")
        if tcfg.microbatch and tcfg.microbatch > 0:
            def split(x):
                b = x.shape[0]
                assert b % tcfg.microbatch == 0, (b, tcfg.microbatch)
                return x.reshape(tcfg.microbatch, b // tcfg.microbatch,
                                 *x.shape[1:])
            mbatch = jax.tree.map(split, batch)

            def acc(carry, mb):
                g_acc, l_acc, extra_c = carry
                loss, metrics, grads, new_extra = compute_grads(
                    params, mb, extra_c)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(f32) / tcfg.microbatch,
                    g_acc, grads)
                return (g_acc, l_acc + loss / tcfg.microbatch, new_extra), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, f32), params)
            (grads, loss, new_extra), _ = jax.lax.scan(
                acc, (g0, jnp.zeros((), f32), extra), mbatch,
                unroll=flags.layer_unroll("micro"))
            metrics = {}
        else:
            loss, metrics, grads, new_extra = compute_grads(
                params, batch, extra)

        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = warmup_cosine(state["step"], tcfg.lr, tcfg.warmup_steps,
                           tcfg.total_steps)
        new_params, new_opt = opt.update(grads, state["opt"], params,
                                         state["step"], lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if new_extra is not None:
            new_state["extra"] = new_extra
        out_metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        out_metrics.update({k: v for k, v in metrics.items()
                            if jnp.ndim(v) == 0})
        return new_state, out_metrics

    return step
