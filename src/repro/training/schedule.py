"""LR schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp

f32 = jnp.float32


def warmup_cosine(step, base_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1):
    s = step.astype(f32)
    warm = base_lr * s / jnp.maximum(1.0, warmup)
    prog = jnp.clip((s - warmup) / jnp.maximum(1.0, total - warmup), 0.0, 1.0)
    cos = base_lr * (final_frac + (1 - final_frac)
                     * 0.5 * (1.0 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup, warm, cos)


def constant(step, base_lr: float):
    return jnp.full_like(step, base_lr, dtype=f32)
