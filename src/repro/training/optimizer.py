"""Optimizers (from scratch — no optax in this environment).

* ``adamw``     — fp32 m/v, decoupled weight decay. Memory 8 B/param extra.
* ``adafactor`` — factored second moment (Shazeer & Stern), no first moment.
                  The only optimizer that fits ≥100 B-param configs on one
                  v5e pod (DESIGN.md §7); default for arctic-480b.
* ``sgdm``      — momentum; used by the ResNet-family vision configs.

State layout mirrors the param tree so FSDP shardings apply verbatim.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

f32 = jnp.float32


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array, float], tuple[Any, Any]]
    # update(grads, opt_state, params, step, lr) -> (new_params, new_state)


def _adamw(b1: float, b2: float, eps: float, wd: float) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, f32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step, lr):
        t = step.astype(f32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            gf = g.astype(f32)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * jnp.square(gf)
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if wd and p.ndim >= 2:
                u = u + wd * p.astype(f32)
            return (p.astype(f32) - lr * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)

        def pick(i):
            return jax.tree.map(lambda o: o[i], out,
                                is_leaf=lambda x: isinstance(x, tuple))

        return pick(0), {"m": pick(1), "v": pick(2)}

    return Optimizer(init, update)


def _adafactor(eps: float = 1e-30, clip: float = 1.0,
               min_dim_factored: int = 128) -> Optimizer:
    def factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] >= min_dim_factored \
            and p.shape[-2] >= min_dim_factored

    def init(params):
        def st(p):
            if factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], f32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], f32)}
            return {"v": jnp.zeros(p.shape, f32)}
        return jax.tree.map(st, params, is_leaf=lambda x: hasattr(x, "shape"))

    def update(grads, state, params, step, lr):
        t = step.astype(f32) + 1.0
        beta2 = 1.0 - t ** -0.8

        def upd(g, s, p):
            gf = g.astype(f32)
            g2 = jnp.square(gf) + eps
            if "vr" in s:
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                r = vr / jnp.mean(vr, axis=-1, keepdims=True)
                u = gf / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :])
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = gf / jnp.sqrt(v)
                ns = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip)
            scale = jnp.maximum(jnp.sqrt(jnp.mean(jnp.square(p.astype(f32)))),
                                1e-3)
            return (p.astype(f32) - lr * scale * u).astype(p.dtype), ns

        out = jax.tree.map(upd, grads, state, params,
                           is_leaf=lambda x: isinstance(x, dict)
                           and ("v" in x or "vr" in x))
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_s = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, new_s

    return Optimizer(init, update)


def _sgdm(momentum: float, wd: float) -> Optimizer:
    def init(params):
        return {"mom": jax.tree.map(lambda p: jnp.zeros(p.shape, f32), params)}

    def update(grads, state, params, step, lr):
        def upd(g, m, p):
            gf = g.astype(f32)
            if wd and p.ndim >= 2:
                gf = gf + wd * p.astype(f32)
            m = momentum * m + gf
            return (p.astype(f32) - lr * m).astype(p.dtype), m

        out = jax.tree.map(upd, grads, state["mom"], params)
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"mom": new_m}

    return Optimizer(init, update)


def make_optimizer(tcfg) -> Optimizer:
    if tcfg.optimizer == "adamw":
        return _adamw(tcfg.b1, tcfg.b2, 1e-8, tcfg.weight_decay)
    if tcfg.optimizer == "adafactor":
        return _adafactor()
    if tcfg.optimizer == "sgdm":
        return _sgdm(0.9, tcfg.weight_decay)
    raise ValueError(tcfg.optimizer)
