from repro.training.optimizer import make_optimizer
from repro.training.schedule import warmup_cosine
from repro.training.train_loop import TrainState, make_train_step

__all__ = ["make_optimizer", "warmup_cosine", "TrainState", "make_train_step"]
