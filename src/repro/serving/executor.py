"""Per-pair executors.

Two modes:
  * ``real``  — actually run a (tiny) detection model on this host, measuring
    wall-clock service time (used by the end-to-end example); profiled T/E
    still drive the *balancer's* expectations, mirroring the paper's split
    between offline profiles and live execution.
  * ``modelled`` — service time/energy drawn from the ProfileTable (used for
    large fleets; identical queue semantics).

Each executor is a FIFO: ``submit`` returns the response-ready time given
the queue; the gateway reads ``outstanding(now)`` as q_p.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np

from repro.core.profiles import ProfileTable
from repro.models import detection
from repro.serving.request import Request, Response


@dataclass
class Executor:
    pair: int
    name: str
    prof: ProfileTable
    mode: str = "modelled"            # modelled | real
    tier: str = "ssd_v1"              # detection tier for real mode
    params: Any = None
    avail_s: float = 0.0
    finish_times: list = field(default_factory=list)

    def __post_init__(self):
        if self.mode == "real" and self.params is None:
            self.params = detection.init_params(
                self.tier, jax.random.PRNGKey(self.pair))
            self._fwd = jax.jit(
                lambda p, x: detection.forward(self.tier, p, x))

    def outstanding(self, now: float) -> int:
        self.finish_times = [t for t in self.finish_times if t > now]
        return len(self.finish_times)

    def submit(self, req: Request, g_true: int, now: float) -> Response:
        start = max(now, self.avail_s)
        if self.mode == "real":
            t0 = time.perf_counter()
            preds = self._fwd(self.params, req.payload[None])
            preds = jax.block_until_ready(preds)
            service = time.perf_counter() - t0
            count = int(detection.count_objects(preds)[0])
            dets = np.asarray(preds[0])
        else:
            service = float(self.prof.T[self.pair, g_true]) / 1000.0
            count = -1
            dets = None
        finish = start + service
        self.avail_s = finish
        self.finish_times.append(finish)
        return Response(
            rid=req.rid, stream_id=req.stream_id, pair=self.pair,
            start_s=start, finish_s=finish, detections=dets,
            detected_count=count,
            energy_mwh=float(self.prof.E[self.pair, g_true]),
            map_proxy=float(self.prof.mAP[self.pair, g_true]))
