"""Per-pair executors.

Two modes:
  * ``real``  — actually run a (tiny) detection model on this host, measuring
    wall-clock service time (used by the end-to-end example); profiled T/E
    still drive the *balancer's* expectations, mirroring the paper's split
    between offline profiles and live execution.
  * ``modelled`` — service time/energy drawn from the ProfileTable (used for
    large fleets; identical queue semantics).

Each executor is a FIFO: ``submit`` returns the response-ready time given
the queue; the gateway reads ``outstanding(now)`` as q_p.

:class:`AsyncExecutorPool` is the windowed request plane's counterpart:
the whole fleet's queues in one object, fed a routed window at a time
(``submit_window`` never blocks — completions surface asynchronously via
``poll``, usually out of submission order).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np

from repro.core.profiles import ProfileTable
from repro.models import detection
from repro.serving.request import Request, Response, ResponseWindow


@dataclass
class Executor:
    pair: int
    name: str
    prof: ProfileTable
    mode: str = "modelled"            # modelled | real
    tier: str = "ssd_v1"              # detection tier for real mode
    params: Any = None
    avail_s: float = 0.0
    finish_times: list = field(default_factory=list)

    def __post_init__(self):
        if self.mode == "real" and self.params is None:
            self.params = detection.init_params(
                self.tier, jax.random.PRNGKey(self.pair))
            self._fwd = jax.jit(
                lambda p, x: detection.forward(self.tier, p, x))

    def outstanding(self, now: float) -> int:
        self.finish_times = [t for t in self.finish_times if t > now]
        return len(self.finish_times)

    def submit(self, req: Request, g_true: int, now: float) -> Response:
        start = max(now, self.avail_s)
        if self.mode == "real":
            t0 = time.perf_counter()
            preds = self._fwd(self.params, req.payload[None])
            preds = jax.block_until_ready(preds)
            service = time.perf_counter() - t0
            count = int(detection.count_objects(preds)[0])
            dets = np.asarray(preds[0])
        else:
            service = float(self.prof.T[self.pair, g_true]) / 1000.0
            count = -1
            dets = None
        finish = start + service
        self.avail_s = finish
        self.finish_times.append(finish)
        return Response(
            rid=req.rid, stream_id=req.stream_id, pair=self.pair,
            start_s=start, finish_s=finish, detections=dets,
            detected_count=count,
            energy_mwh=float(self.prof.E[self.pair, g_true]),
            map_proxy=float(self.prof.mAP[self.pair, g_true]))


@dataclass
class AsyncExecutorPool:
    """The whole fleet's executor queues as one non-blocking object.

    ``submit_window`` enqueues a routed window — each pair's requests
    serialize FIFO behind that pair's backlog, with modelled service
    times from the profile table — and returns immediately with the
    *scheduled* finish times; the gateway's dispatch loop never blocks on
    simulated service completion. Completions surface later through
    :meth:`poll`, in completion order and (because pairs drain at
    different speeds) generally OUT of submission order — exactly the
    feedback stream the windowed observation path has to digest.
    :meth:`depths` is the live per-pair in-flight count the next routing
    window scores against (q_p of Algorithm 1).

    Accounting invariant (property-tested): every completion polled was
    previously submitted, so queue depths never go negative and
    ``submitted == polled + failed + in_flight`` at every instant
    (``failed`` counts work the fault plane killed via
    :meth:`fail_pairs` — zero when no faults are injected).
    """

    prof: ProfileTable

    def __post_init__(self):
        if self.prof.is_stacked:
            raise ValueError("executor pool serves one fleet, not a "
                             "stacked ensemble")
        P = self.prof.n_pairs
        # the TRUE service times factor as base x drift x fault-throttle:
        # drift is cumulative (apply_drift multiplies in), the fault
        # throttle is SET each window (a pure function of the fault step),
        # so the two compose order-independently and in the documented
        # order truth = (prof x drift) x fault
        self._T_base = np.asarray(self.prof.T, np.float64) / 1000.0
        self._E_base = np.asarray(self.prof.E, np.float64)
        self._drift_t = np.float64(1.0)
        self._drift_e = np.float64(1.0)
        self._fault_t = np.float64(1.0)
        self._fault_e = np.float64(1.0)
        self._recompute()
        self._M = np.asarray(self.prof.mAP, np.float64)
        self._avail = np.zeros(P, np.float64)   # per-pair FIFO frontier
        self._depth = np.zeros(P, np.int64)
        self.submitted = 0
        self.polled = 0
        self.failed = 0
        # pending completions, appended per window, drained by poll()
        self._pending: list[ResponseWindow] = []

    @property
    def in_flight(self) -> int:
        return int(self._depth.sum())

    def _recompute(self) -> None:
        self._T_s = (self._T_base * self._drift_t) * self._fault_t
        self._E = (self._E_base * self._drift_e) * self._fault_e

    def apply_drift(self, t_scale, e_scale=None) -> None:
        """Scale the TRUE service times (and optionally energies) from
        now on — thermal throttling, a model swap. Balancers are never
        told; an adaptive gateway finds out through its windowed
        observations (cf. ``DriftSchedule`` in the simulator). Drift is
        cumulative: repeated calls multiply."""
        self._drift_t = self._drift_t * np.asarray(t_scale, np.float64)
        if e_scale is not None:
            self._drift_e = self._drift_e * np.asarray(e_scale, np.float64)
        self._recompute()

    def set_fault_throttle(self, t_mult, e_mult=None) -> None:
        """SET the fault plane's throttling multipliers (replacing the
        previous ones — fault throttles are a pure function of the fault
        step, not a cumulative drift). Applied ON TOP of any drift:
        ``truth = (prof x drift) x fault``."""
        self._fault_t = np.asarray(t_mult, np.float64)
        self._fault_e = np.float64(1.0) if e_mult is None \
            else np.asarray(e_mult, np.float64)
        self._recompute()

    def fail_pairs(self, down, now: float, *,
                   timeout_s: float | None = None) -> ResponseWindow:
        """Kill in-flight work the fault plane lost: every unpolled entry
        that has NOT finished by ``now`` and is either queued on a pair
        in ``down`` ((P,) bool) or — when ``timeout_s`` is given — would
        finish later than ``arrival + timeout_s``. Entries already past
        their finish time are completions awaiting :meth:`poll` and are
        never failed. Returns the failed entries as one
        :class:`ResponseWindow` (submission-order) so the serving plane
        can retry them; each affected pair's FIFO frontier is rebuilt
        from its surviving work, so a recovered pair does not stay
        blocked behind ghost requests."""
        down = np.asarray(down, bool)
        if not self._pending:
            return ResponseWindow()
        cat = {f: np.concatenate([getattr(w, f) for w in self._pending])
               for f in ("rids", "stream_ids", "pairs", "groups",
                         "est_groups", "arrival_s", "finish_s",
                         "energy_mwh", "map_proxy")}
        live = cat["finish_s"] > now
        kill = live & down[cat["pairs"]]
        if timeout_s is not None:
            kill |= live & (cat["finish_s"] > cat["arrival_s"] + timeout_s)
        if not kill.any():
            return ResponseWindow()
        out = ResponseWindow(**{f: v[kill] for f, v in cat.items()})
        keep = {f: v[~kill] for f, v in cat.items()}
        self._pending = [] if keep["pairs"].size == 0 \
            else [ResponseWindow(**keep)]
        np.subtract.at(self._depth, out.pairs, 1)
        self.failed += out.size
        # rebuild the FIFO frontier of every touched pair from what
        # survived (0.0 == free now; submit takes max(now, frontier))
        for p in np.unique(out.pairs):
            rem = keep["finish_s"][keep["pairs"] == p]
            self._avail[p] = rem.max(initial=0.0)
        return out

    def depths(self) -> np.ndarray:
        """(P,) live queue depths — q_p for the next admission window."""
        return self._depth.astype(np.float32).copy()

    def submit_window(self, pairs, groups, now: float, *, est_groups=None,
                      stream_ids=None, rids=None) -> ResponseWindow:
        """Enqueue one routed window at time ``now`` (non-blocking).

        ``pairs``: (W,) routing decisions; ``groups``: (W,) TRUE
        complexity groups (drive modelled service time/energy). Returns
        the scheduled :class:`ResponseWindow` immediately — the same
        records :meth:`poll` will surface once ``now`` passes their
        finish times."""
        pairs = np.asarray(pairs, np.int64)
        groups = np.asarray(groups, np.int64)
        W = pairs.shape[0]
        svc = self._T_s[pairs, groups]
        finish = np.empty(W, np.float64)
        for p in np.unique(pairs):              # FIFO within each pair
            m = pairs == p
            finish[m] = max(now, self._avail[p]) + np.cumsum(svc[m])
            self._avail[p] = finish[m][-1]
        np.add.at(self._depth, pairs, 1)
        self.submitted += W

        def arr(x, dtype=np.int64):
            return np.zeros(W, dtype) if x is None else np.asarray(x, dtype)

        resp = ResponseWindow(
            rids=arr(rids), stream_ids=arr(stream_ids), pairs=pairs,
            groups=groups, est_groups=arr(est_groups),
            arrival_s=np.full(W, float(now)), finish_s=finish,
            energy_mwh=self._E[pairs, groups],
            map_proxy=self._M[pairs, groups])
        self._pending.append(resp)
        return resp

    def poll(self, now: float) -> ResponseWindow:
        """Drain every completion with ``finish_s <= now``, merged across
        pairs into ONE window in completion order (possibly empty;
        ``poll(np.inf)`` drains everything)."""
        if not self._pending:
            return ResponseWindow()
        cat = {f: np.concatenate([getattr(w, f) for w in self._pending])
               for f in ("rids", "stream_ids", "pairs", "groups",
                         "est_groups", "arrival_s", "finish_s",
                         "energy_mwh", "map_proxy")}
        done = cat["finish_s"] <= now
        keep = {f: v[~done] for f, v in cat.items()}
        self._pending = [] if keep["pairs"].size == 0 \
            else [ResponseWindow(**keep)]
        order = np.argsort(cat["finish_s"][done], kind="stable")
        out = ResponseWindow(**{f: v[done][order] for f, v in cat.items()})
        np.subtract.at(self._depth, out.pairs, 1)
        self.polled += out.size
        return out
