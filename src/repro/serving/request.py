"""Request/response records for the heterogeneous serving fleet.

:class:`Request`/:class:`Response` are the per-request records of the
original engine; :class:`RequestWindow`/:class:`ResponseWindow` are their
batched struct-of-arrays forms — one record per admission window, fields
as (W,) arrays — used by the windowed request plane
(``repro.serving.engine.ServingPlane``)."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import numpy as np


@dataclass
class Request:
    rid: int
    stream_id: int            # video stream / user id (estimator state key)
    arrival_s: float
    payload: Any = None       # image array (or token array for LM cells)
    est_group: int = 0        # estimated complexity class (set by gateway)


@dataclass
class Response:
    rid: int
    stream_id: int
    pair: int                 # device-model pair the request ran on
    start_s: float
    finish_s: float
    detections: Any = None
    detected_count: int = 0
    energy_mwh: float = 0.0
    map_proxy: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.finish_s  # caller subtracts arrival


def _empty(dtype):
    return field(default_factory=lambda: np.empty((0,), dtype))


@dataclass
class RequestWindow:
    """One admission window, struct-of-arrays: W requests admitted at the
    same instant and routed by ONE ``route_window`` call."""

    stream_ids: np.ndarray                      # (W,) estimator state keys
    arrival_s: float = 0.0                      # window admission time
    rids: np.ndarray = _empty(np.int64)         # (W,) request ids
    payloads: Any = None                        # optional (W, ...) frames

    @property
    def size(self) -> int:
        return int(np.asarray(self.stream_ids).shape[0])


@dataclass
class ResponseWindow:
    """Completed requests surfaced by one executor-pool poll, in
    completion order (fields are parallel (W,) arrays). ``groups`` is the
    TRUE complexity group (drives modelled service/detections);
    ``est_groups`` the gateway's estimate at routing time (what
    observations are keyed by)."""

    rids: np.ndarray = _empty(np.int64)
    stream_ids: np.ndarray = _empty(np.int64)
    pairs: np.ndarray = _empty(np.int64)
    groups: np.ndarray = _empty(np.int64)
    est_groups: np.ndarray = _empty(np.int64)
    arrival_s: np.ndarray = _empty(np.float64)
    finish_s: np.ndarray = _empty(np.float64)
    energy_mwh: np.ndarray = _empty(np.float64)
    map_proxy: np.ndarray = _empty(np.float64)

    @property
    def size(self) -> int:
        return int(self.pairs.shape[0])

    @property
    def latency_ms(self) -> np.ndarray:
        return (self.finish_s - self.arrival_s) * 1000.0
