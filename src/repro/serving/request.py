"""Request/response records for the heterogeneous serving fleet."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any


@dataclass
class Request:
    rid: int
    stream_id: int            # video stream / user id (estimator state key)
    arrival_s: float
    payload: Any = None       # image array (or token array for LM cells)
    est_group: int = 0        # estimated complexity class (set by gateway)


@dataclass
class Response:
    rid: int
    stream_id: int
    pair: int                 # device-model pair the request ran on
    start_s: float
    finish_s: float
    detections: Any = None
    detected_count: int = 0
    energy_mwh: float = 0.0
    map_proxy: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.finish_s  # caller subtracts arrival
