from repro.serving.engine import ServingEngine
from repro.serving.executor import Executor
from repro.serving.gateway import Gateway
from repro.serving.request import Request, Response

__all__ = ["Request", "Response", "Gateway", "Executor", "ServingEngine"]
