from repro.serving.request import Request, Response
from repro.serving.gateway import Gateway
from repro.serving.executor import Executor
from repro.serving.engine import ServingEngine

__all__ = ["Request", "Response", "Gateway", "Executor", "ServingEngine"]
