from repro.serving.engine import ServingEngine, ServingPlane
from repro.serving.executor import AsyncExecutorPool, Executor
from repro.serving.gateway import Gateway, WindowedGateway
from repro.serving.request import (Request, RequestWindow, Response,
                                   ResponseWindow)

__all__ = ["Request", "Response", "RequestWindow", "ResponseWindow",
           "Gateway", "WindowedGateway", "Executor", "AsyncExecutorPool",
           "ServingEngine", "ServingPlane"]
