"""End-to-end serving engines: workload -> gateway -> executors -> metrics.

Two drivers share the stack:

* :class:`ServingPlane` — the windowed (micro-batched) request plane.
  Requests are admitted a window at a time; one jitted ``route_window``
  call routes the whole window against the live executor queue depths,
  the :class:`~repro.serving.executor.AsyncExecutorPool` enqueues it
  without blocking, and completions polled between windows feed the
  gateway's windowed observation hooks (dispatch-state belief and the
  detection-count estimator). Built from a
  :class:`~repro.core.scenario.Scenario`; this is the high-throughput
  path (``benchmarks/serving_throughput.py`` drives the same machinery).

* :class:`ServingEngine` — the original per-request closed loop, kept
  for ``real`` mode (actual tiny detectors on this host, wall-clock
  service times, real detection counts feeding the estimator — the full
  loop of the paper's §III). It now drives the SAME windowed gateway
  with windows of one, so it emits no deprecation warnings and stays
  bit-compatible with the windowed plane on a shared request stream.
"""

from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import estimator as EST
from repro.core.profiles import ProfileTable
from repro.data.workload import VideoStreamWorkload
from repro.serving.executor import AsyncExecutorPool, Executor
from repro.serving.gateway import WindowedGateway
from repro.serving.request import Request

# detection probability of one object given pair mAP (workload.noisy_count
# and the estimator's noisy_detected_count use the same ramp)
_P_DET = lambda m: np.minimum(1.0, 0.80 + 0.20 * m / 100.0)


@dataclass
class ServingPlane:
    """Windowed closed-ish loop over a modelled fleet.

    Per iteration: poll the pool for completions (feeding the gateway's
    windowed observation hooks), admit the next window of streams,
    route it in one jitted call against live queue depths, enqueue it on
    the pool, and advance simulated time by the window's offered-load
    interval. Scene complexity per stream follows the same Markov chain
    as the simulator/workload.

    Under a scenario :class:`~repro.core.faults.FaultSchedule` the plane
    closes the failover loop: before each window it kills in-flight work
    on pairs the fault plane took down (and work past the schedule's
    ``timeout_ms``) via :meth:`~repro.serving.executor
    .AsyncExecutorPool.fail_pairs`, re-admits the victims at the head of
    later windows (re-routed against the CURRENT health mask) up to
    ``max_attempts`` total tries — beyond that the request is dropped
    and counted in ``failed_share`` — and records a completed retry's
    latency from its FIRST arrival, so retries pay their full
    end-to-end price. Throttling bursts SET the pool's true-time
    multipliers per window (``truth = (prof x drift) x fault``)."""

    gateway: WindowedGateway
    pool: AsyncExecutorPool
    window: int = 64
    n_streams: int = 15
    stickiness: float = 0.85
    offered_rps: float | None = None   # None: ~90% of fleet capacity
    seed: int = 0
    _recs: dict = field(default=None, repr=False)

    @classmethod
    def build(cls, scenario, *, window: int = 64, backend: str = "auto",
              offered_rps: float | None = None) -> "ServingPlane":
        """One Scenario -> the whole plane: the gateway adopts the
        scenario's profile/policy/γ/Δ/dispatch/seed, the pool its fleet,
        the workload its user count and stickiness."""
        gw = WindowedGateway(scenario, backend=backend,
                             n_streams=max(1024, scenario.n_users))
        return cls(gw, AsyncExecutorPool(gw.prof), window=window,
                   n_streams=scenario.n_users,
                   stickiness=scenario.stickiness,
                   offered_rps=offered_rps, seed=scenario.seed)

    def capacity_rps(self) -> float:
        """Aggregate fleet service capacity (completions/sec) at the
        pool's CURRENT true mean service times (post-drift/throttle),
        not the offline profile. The default offered load is 90% of
        this; leave more headroom when faults are in play so failover
        has spare capacity to absorb the re-routed work."""
        return float(np.sum(1.0 / self.pool._T_s.mean(axis=1)))

    def _observe(self, resp, rng) -> None:
        """Feed one polled completion window back: measured latency and
        energy into the dispatch state (keyed by the ESTIMATED group the
        decision used), modelled detection counts into the estimator."""
        if resp.size == 0:
            return
        # belief updates see the per-SUBMISSION latency (the measurement
        # an executor would report); the recorded latency of a retried
        # request runs from its FIRST arrival instead
        self.gateway.observe_window(resp.pairs, resp.est_groups,
                                    resp.latency_ms, resp.energy_mwh)
        true_count = np.where(resp.groups < self.gateway.prof.n_groups - 1,
                              resp.groups, 5)
        det = rng.binomial(true_count, _P_DET(resp.map_proxy))
        det += rng.random(resp.size) < 0.05 * (1 - resp.map_proxy / 100.0)
        self.gateway.observe_detections_window(resp.stream_ids, det)
        lat_s = resp.latency_ms / 1000.0
        if getattr(self, "_first_arrival", None):
            for j, r in enumerate(resp.rids):
                fa = self._first_arrival.pop(int(r), None)
                if fa is not None:
                    lat_s[j] = resp.finish_s[j] - fa
                self._attempts.pop(int(r), None)
        r = self._recs
        r["latency"].append(lat_s)
        r["energy"].append(resp.energy_mwh)
        r["map"].append(resp.map_proxy)
        r["pair"].append(resp.pairs)
        r["g_true"].append(resp.groups)
        r["g_est"].append(resp.est_groups)

    def _requeue(self, failed) -> None:
        """Queue a :meth:`fail_pairs` window for retry: each victim gets
        re-admitted (original rid/stream/true group, so its identity and
        first-arrival clock survive) unless it has exhausted the
        schedule's ``max_attempts`` — then it is dropped for good."""
        cap = int(self.gateway.faults.max_attempts)
        for j in range(failed.size):
            rid = int(failed.rids[j])
            self._first_arrival.setdefault(rid, float(failed.arrival_s[j]))
            n = self._attempts.get(rid, 1) + 1
            if n > cap:
                self._attempts.pop(rid, None)
                self.failed_requests += 1
                continue
            self._attempts[rid] = n
            self.retried += 1
            self._retryq.append((rid, int(failed.stream_ids[j]),
                                 int(failed.groups[j])))

    def run(self, n_requests: int = 2048):
        """Drive ``n_requests`` through the plane; returns per-request
        record arrays (completion order) plus router timing:
        ``router_s`` (total wall-clock inside ``route_window``) and
        ``router_window_s`` (per-window wall-clock samples). Repeated
        calls CONTINUE the plane — clock, streams, queues and belief
        state persist — so drift can be injected between runs."""
        G = self.gateway.prof.n_groups
        meta = self.gateway._fault_meta
        if getattr(self, "_rng", None) is None:     # first run: cold plane
            self._rng = np.random.default_rng(self.seed)
            P_mat = np.asarray(EST.markov_transition(G, self.stickiness))
            self._cumP = P_mat.cumsum(axis=1)
            self._scene = self._rng.choice(
                G, self.n_streams, p=np.asarray(EST.stationary(P_mat)))
            self._now = 0.0
            self._served = 0
            self._retryq = []           # [(rid, stream, g_true), ...]
            self._attempts = {}         # rid -> submissions so far
            self._first_arrival = {}    # rid -> first arrival_s
            self.failed_requests = 0    # dropped past max_attempts
            self.retried = 0            # re-admissions
        rng, cumP, scene = self._rng, self._cumP, self._scene
        rps = self.offered_rps or 0.9 * self.capacity_rps()
        self._recs = {k: [] for k in ("latency", "energy", "map", "pair",
                                      "g_true", "g_est")}
        router_win = []
        failed0, retried0 = self.failed_requests, self.retried
        timeout_s = None if meta is None \
            else float(self.gateway.faults.timeout_ms) / 1000.0
        now, done = self._now, 0
        while done < n_requests or self._retryq:
            if meta is not None:
                step0 = self.gateway._step
                if meta.has_down:
                    down = np.asarray(meta.down_at(step0))
                    self._requeue(self.pool.fail_pairs(
                        down, now, timeout_s=timeout_s))
                if meta.has_throttle:
                    t_m, e_m = meta.throttle_at(step0)
                    self.pool.set_fault_throttle(
                        np.asarray(t_m)[:, None], np.asarray(e_m)[:, None])
            self._observe(self.pool.poll(now), rng)
            # admission: queued retries drain at the head of the window
            # (re-routed against the CURRENT health mask), new streams
            # fill the rest
            retry = self._retryq[:self.window]
            del self._retryq[:len(retry)]
            w_new = min(self.window - len(retry), n_requests - done)
            rid0 = self._served + done
            new_streams = np.arange(rid0, rid0 + w_new) % self.n_streams
            scene[new_streams] = (rng.random((w_new, 1))
                                  > cumP[scene[new_streams]]).sum(axis=1)
            streams = np.concatenate(
                [np.asarray([s for _, s, _ in retry], np.int64),
                 new_streams])
            rids = np.concatenate(
                [np.asarray([r for r, _, _ in retry], np.int64),
                 np.arange(rid0, rid0 + w_new)])
            groups = np.concatenate(
                [np.asarray([g for _, _, g in retry], np.int64),
                 scene[new_streams]])
            t0 = time.perf_counter()
            pairs, gs, _q = self.gateway.route_window(streams,
                                                      self.pool.depths())
            pairs = np.asarray(pairs)
            router_win.append(time.perf_counter() - t0)
            self.pool.submit_window(pairs, groups, now,
                                    est_groups=np.asarray(gs),
                                    stream_ids=streams, rids=rids)
            now += streams.shape[0] / rps
            done += w_new
        self._observe(self.pool.poll(np.inf), rng)   # drain the tail
        self._now = max(now, float(self.pool._avail.max(initial=0.0)))
        self._served += done
        recs = {k: np.concatenate(v) for k, v in self._recs.items()}
        recs["router_s"] = float(np.sum(router_win))
        recs["router_window_s"] = np.asarray(router_win)
        if meta is not None:
            recs["n_offered"] = float(n_requests)
            recs["failed_requests"] = float(self.failed_requests - failed0)
            recs["retried"] = float(self.retried - retried0)
        return recs

    @staticmethod
    def summarize(recs) -> dict:
        """:meth:`ServingEngine.summarize`, extended with the fault
        plane's availability metrics when the run carried them."""
        out = ServingEngine.summarize(recs)
        if "n_offered" in recs:
            n = max(1.0, float(recs["n_offered"]))
            out["failed_share"] = float(recs["failed_requests"]) / n
            out["retried_share"] = float(recs["retried"]) / n
            out["latency_p99_ms"] = float(
                np.percentile(recs["latency"], 99) * 1000)
        return out


@dataclass
class ServingEngine:
    prof: ProfileTable
    gateway: WindowedGateway
    executors: list
    workload: VideoStreamWorkload

    @classmethod
    def build(cls, prof: ProfileTable, *, policy="MO", gamma=0.5, delta=20.0,
              n_streams=8, mode="modelled", tiers=None, online=False,
              dispatch=None, img_res=64, seed=0):
        gw = WindowedGateway(prof, policy=policy, gamma=gamma, delta=delta,
                             online=online, dispatch=dispatch)
        tiers = tiers or ["ssd_v1"] * prof.n_pairs
        exs = [Executor(i, str(prof.names[i] if prof.names else i), prof,
                        mode=mode, tier=tiers[i])
               for i in range(prof.n_pairs)]
        wl = VideoStreamWorkload(n_streams=n_streams, img_res=img_res,
                                 n_groups=prof.n_groups, seed=seed)
        return cls(prof, gw, exs, wl)

    def run(self, n_requests: int = 200, concurrency: int | None = None):
        """Closed-loop: ``concurrency`` streams each keep one request in
        flight (Locust semantics). Returns per-request record arrays.
        Per-request = windows of one on the windowed gateway."""
        conc = concurrency or self.workload.n_streams
        recs = {k: [] for k in ("latency", "energy", "map", "pair", "g_true",
                                "g_est", "q")}
        # event heap of (ready_time, stream)
        heap = [(i * 1e-4, s) for i, s in enumerate(range(conc))]
        heapq.heapify(heap)
        done = 0
        while done < n_requests:
            now, stream = heapq.heappop(heap)
            frame, g_true = self.workload.next_frame(stream)
            req = Request(rid=done, stream_id=stream, arrival_s=now,
                          payload=frame)
            q = np.array([ex.outstanding(now) for ex in self.executors],
                         np.float32)
            ps, gs, _qa = self.gateway.route_window([stream], q)
            pair, g_est = int(ps[0]), int(gs[0])
            resp = self.executors[pair].submit(req, g_true, now)
            if resp.detected_count >= 0:      # real detector output
                self.gateway.observe_detections_window(
                    [stream], [resp.detected_count])
            else:                             # modelled detection count
                det = self.workload.noisy_count(
                    stream, float(self.prof.mAP[pair, g_true]))
                self.gateway.observe_detections_window([stream], [det])
            self.gateway.observe_window([pair], [g_est],
                                        [(resp.finish_s - now) * 1000.0],
                                        [resp.energy_mwh])
            recs["latency"].append(resp.finish_s - now)
            recs["energy"].append(resp.energy_mwh)
            recs["map"].append(resp.map_proxy)
            recs["pair"].append(pair)
            recs["g_true"].append(g_true)
            recs["g_est"].append(g_est)
            recs["q"].append(q[pair])
            heapq.heappush(heap, (resp.finish_s, stream))
            done += 1
        return {k: np.asarray(v) for k, v in recs.items()}

    @staticmethod
    def summarize(recs) -> dict:
        lat = recs["latency"]
        return {
            "latency_ms": float(lat.mean() * 1000),
            "latency_p90_ms": float(np.percentile(lat, 90) * 1000),
            "energy_mwh": float(recs["energy"].mean()),
            "map": float(recs["map"].mean()),
            "estimator_acc": float((recs["g_true"] == recs["g_est"]).mean()),
        }
