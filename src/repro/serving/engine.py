"""End-to-end serving engine: workload -> gateway -> executors -> metrics.

In ``real`` mode the fleet runs actual (tiny) detection models on this host
and the estimator consumes *real* detection counts — the full closed loop of
the paper (§III) with no modelled shortcuts except the profile tables that
drive the balancer's expectations (exactly the paper's offline-profiling
role)."""

from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np

from repro.core.profiles import ProfileTable
from repro.data.workload import VideoStreamWorkload
from repro.serving.executor import Executor
from repro.serving.gateway import Gateway
from repro.serving.request import Request


@dataclass
class ServingEngine:
    prof: ProfileTable
    gateway: Gateway
    executors: list
    workload: VideoStreamWorkload

    @classmethod
    def build(cls, prof: ProfileTable, *, policy="MO", gamma=0.5, delta=20.0,
              n_streams=8, mode="modelled", tiers=None, online=False,
              dispatch=None, img_res=64, seed=0):
        gw = Gateway(prof, policy=policy, gamma=gamma, delta=delta,
                     online=online, dispatch=dispatch)
        tiers = tiers or ["ssd_v1"] * prof.n_pairs
        exs = [Executor(i, str(prof.names[i] if prof.names else i), prof,
                        mode=mode, tier=tiers[i])
               for i in range(prof.n_pairs)]
        wl = VideoStreamWorkload(n_streams=n_streams, img_res=img_res,
                                 n_groups=prof.n_groups, seed=seed)
        return cls(prof, gw, exs, wl)

    def run(self, n_requests: int = 200, concurrency: int | None = None):
        """Closed-loop: ``concurrency`` streams each keep one request in
        flight (Locust semantics). Returns per-request record arrays."""
        conc = concurrency or self.workload.n_streams
        recs = {k: [] for k in ("latency", "energy", "map", "pair", "g_true",
                                "g_est", "q")}
        # event heap of (ready_time, stream)
        heap = [(i * 1e-4, s) for i, s in enumerate(range(conc))]
        heapq.heapify(heap)
        done = 0
        while done < n_requests:
            now, stream = heapq.heappop(heap)
            frame, g_true = self.workload.next_frame(stream)
            req = Request(rid=done, stream_id=stream, arrival_s=now,
                          payload=frame)
            q = np.array([ex.outstanding(now) for ex in self.executors],
                         np.float32)
            pair, g_est = self.gateway.route(stream, q)
            resp = self.executors[pair].submit(req, g_true, now)
            if resp.detected_count >= 0:      # real detector output
                self.gateway.observe_detections(stream, resp.detected_count)
            else:                             # modelled detection count
                det = self.workload.noisy_count(
                    stream, float(self.prof.mAP[pair, g_true]))
                self.gateway.observe_detections(stream, det)
            self.gateway.observe_latency(pair, g_est,
                                         (resp.finish_s - now) * 1000.0,
                                         resp.energy_mwh)
            recs["latency"].append(resp.finish_s - now)
            recs["energy"].append(resp.energy_mwh)
            recs["map"].append(resp.map_proxy)
            recs["pair"].append(pair)
            recs["g_true"].append(g_true)
            recs["g_est"].append(g_est)
            recs["q"].append(q[pair])
            heapq.heappush(heap, (resp.finish_s, stream))
            done += 1
        return {k: np.asarray(v) for k, v in recs.items()}

    @staticmethod
    def summarize(recs) -> dict:
        lat = recs["latency"]
        return {
            "latency_ms": float(lat.mean() * 1000),
            "latency_p90_ms": float(np.percentile(lat, 90) * 1000),
            "energy_mwh": float(recs["energy"].mean()),
            "map": float(recs["map"].mean()),
            "estimator_acc": float((recs["g_true"] == recs["g_est"]).mean()),
        }
