"""The gateway: per-request (or windowed) policy decisions.

Holds the offline ProfileTable, optional online-EWMA adaptation state, and
the per-stream estimator state (last detected count). Per-request decisions
use the jitted Algorithm-1 scorer; batched routing windows go through the
fused ``moscore`` Pallas kernel — identical results (tests assert so)."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator as EST
from repro.core import online as ONL
from repro.core.policies import POLICY_CODES, policy_scores
from repro.core.profiles import ProfileTable
from repro.kernels.moscore import moscore_route


@dataclass
class Gateway:
    prof: ProfileTable
    policy: str = "MO"
    gamma: float = 0.5
    delta: float = 20.0
    online: bool = False
    _rr: int = 0
    _stream_counts: dict = field(default_factory=dict)
    _online_state: Any = None
    _rng: Any = None

    def __post_init__(self):
        self._rng = jax.random.PRNGKey(1234)
        if self.online:
            self._online_state = ONL.init_state(self.prof)
        code = POLICY_CODES[self.policy]

        @jax.jit
        def _score(T, E, mAP, g, q, rnd, rr, gamma, delta):
            prof = ProfileTable(T, E, mAP)
            return policy_scores(code, prof, g, q, rnd, rr, gamma, delta)

        self._score = _score

    # -- estimator ----------------------------------------------------------
    def estimate_group(self, stream_id: int) -> int:
        cnt = self._stream_counts.get(stream_id, 0)
        return int(EST.group_of_count(jnp.asarray(cnt), self.prof.n_groups))

    def observe_detections(self, stream_id: int, detected_count: int) -> None:
        self._stream_counts[stream_id] = detected_count

    def observe_latency(self, pair: int, group: int, latency_ms: float,
                        energy_mwh: float | None = None) -> None:
        if self.online:
            self._online_state = ONL.observe(
                self._online_state, pair, group, latency_ms, energy_mwh)

    def _tables(self) -> ProfileTable:
        if self.online:
            return ONL.as_profile(self._online_state, self.prof)
        return self.prof

    # -- decisions ----------------------------------------------------------
    def route(self, stream_id: int, queue_depths) -> tuple[int, int]:
        """One request -> (pair, est_group)."""
        g = self.estimate_group(stream_id)
        self._rng, k = jax.random.split(self._rng)
        p = self._tables()
        scores = self._score(p.T, p.E, p.mAP, g,
                             jnp.asarray(queue_depths, jnp.float32), k,
                             self._rr % self.prof.n_pairs,
                             self.gamma, self.delta)
        self._rr += 1
        return int(jnp.argmin(scores)), g

    def route_window(self, stream_ids, queue_depths):
        """Batched routing window through the fused kernel (MO policy only);
        returns (pairs (W,), est_groups (W,), q_after)."""
        assert self.policy == "MO", "windowed routing is the MO fast path"
        gs = jnp.asarray([self.estimate_group(s) for s in stream_ids],
                         jnp.int32)
        p = self._tables()
        pairs, q = moscore_route(p.T, p.E, p.mAP, gs,
                                 jnp.asarray(queue_depths, jnp.float32),
                                 delta=self.delta, gamma=self.gamma)
        return np.asarray(pairs), np.asarray(gs), np.asarray(q)
