"""The request plane's router: micro-batched admission, device-resident
state.

:class:`WindowedGateway` is the serving plane's primary router. It admits
requests in *windows*: one jitted device program routes the whole window —
estimator gather (last detected count per stream, a device-resident
``(n_streams,)`` array, not a host dict), Algorithm-1 scoring with
intra-window queue feedback, and the dispatch-state advance — so the
router's cost per request is a window's worth of XLA work divided by W
instead of a Python loop body. The MO hot path runs the fused ``moscore``
kernel (``repro.kernels.moscore``), backend-aware: the compiled
invariant-hoisted Pallas kernel on TPU, the bit-identical hoisted XLA
scan elsewhere (``backend="auto"``; the ``REPRO_MOSCORE_BACKEND`` env
var overrides the auto choice, e.g. ``int8`` for quantized belief
tables under the bounded-mismatch contract — see ``docs/kernels.md``).
Every other policy routes through the dispatch
engine's :meth:`~repro.core.dispatch.DispatchEngine.select_window` scan —
the SAME ``init``/``select``/``observe`` code the batched simulator
threads through its scan, so simulation and serving still run one
stateful code path.

Observations flow back in windows too: ``observe_window`` folds a batch
of completed-request measurements into the dispatch engine's belief state
(one fused program, via the engine's ``observe_window`` hook), and
``observe_detections_window`` scatters detected counts into the
device-resident estimator state (duplicate streams resolve to the
*latest* entry, matching a sequential replay).

Per-request randomness is derived by ``fold_in(key, request_index)``
from an absolute request counter — NOT by chain-splitting a key per
call — so the key stream is invariant to how requests are partitioned
into windows: two gateways with the same seed and different window sizes
route identical request streams identically (regression-tested).

A gateway can be built straight from a
:class:`~repro.core.scenario.Scenario` — ``WindowedGateway(scenario)`` —
so simulation and serving share ONE config object: the scenario's
profile, policy, γ, Δ, dispatch engine and seed all apply to knobs left
at their constructor defaults, while any explicitly passed non-default
kwarg (``policy=``, ``gamma=``, ``dispatch=``, ...) wins.

:class:`Gateway` — the original per-request router — remains as a thin
deprecation-warned shim: ``route`` is ``route_window`` with a window of
one, proven bit-identical to the windowed path by
``tests/test_serving_plane.py``. See ``docs/serving.md`` for the
architecture guide and the migration table.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator as EST
from repro.core.dispatch import (DispatchEngine, OnlineDispatch,
                                 StaticDispatch)
from repro.core.hierarchy import hierarchical_select, pod_aggregate
from repro.core.policies import POLICY_CODES
from repro.core.profiles import ProfileTable
from repro.kernels.moscore import moscore_route, resolve_backend

i32 = jnp.int32
f32 = jnp.float32


@dataclass
class WindowedGateway:
    """Windowed (micro-batched) router over a heterogeneous fleet.

    ``prof`` is a :class:`~repro.core.profiles.ProfileTable` or a
    :class:`~repro.core.scenario.Scenario` (resolved in
    ``__post_init__``; its policy/γ/Δ/dispatch/seed apply to knobs left
    at their defaults). ``n_streams`` is the estimator-state capacity
    (stream ids must stay below it); ``backend`` picks the MO routing
    kernel (``"auto"`` | one of ``repro.kernels.moscore.BACKENDS`` —
    the fp32 backends are interchangeable bit-for-bit; ``"int8"``
    quantizes the belief tables handed to the kernel each window and
    routes under the bounded-mismatch contract of
    ``repro.core.quant``).

    ``cloud`` is an optional :class:`~repro.core.cloud.CloudTier`: the
    served fleet is extended with its remote pairs
    (``CloudTier.extend``), and latency-aware routing sees the uplink
    congestion penalty — which means cloud-active MO routes through the
    generic ``select_window`` scan (the fused ``moscore`` kernel scores
    raw tables and has no penalty hook). A scenario-built gateway
    adopts the scenario's cloud tier like any other knob.

    ``pods`` turns on hierarchical (two-level) routing
    (``repro.core.hierarchy``): a per-pair pod-id vector partitions the
    fleet, level 1 picks a pod by Algorithm 1 over pod-aggregate
    profiles with queue totals snapshotted at WINDOW ADMISSION (stale
    within the window — the price of decentralisation), level 2 runs
    Algorithm 1 inside the pod with exact in-window queue feedback.
    With a cloud tier, a ``pods`` vector covering only the local pairs
    puts the remote pairs in their own extra pod.

    ``faults`` is an optional :class:`~repro.core.faults.FaultSchedule`:
    a *visible* schedule routes every window through the generic
    ``select_window`` scan with the per-request health mask
    (``health_at`` of the absolute request index — window-partition
    invariant like the key stream), masking down pairs at the
    accuracy-feasibility stage with the degraded fallback; an
    *invisible* one leaves the router blind (the serving plane's truth
    model still faults). Adopted from a scenario like every other knob.
    Hierarchical (``pods``) routing has no fault mask yet — combining
    them raises."""

    prof: ProfileTable
    policy: str = "MO"
    gamma: float = 0.5
    delta: float = 20.0
    online: bool = False      # shorthand for dispatch=OnlineDispatch()
    seed: int = 1234          # seeds the per-request key stream (RND)
    dispatch: DispatchEngine | None = None
    n_streams: int = 1024
    backend: str = "auto"
    cloud: Any = None         # CloudTier | None — edge-to-cloud tier
    faults: Any = None        # FaultSchedule | None — the fault plane
    pods: Any = None          # (P,) pod ids | None — hierarchical routing
    _counts: Any = field(default=None, repr=False)
    _dstate: Any = field(default=None, repr=False)
    _step: int = field(default=0, repr=False)

    def __post_init__(self):
        from repro.core.scenario import Scenario
        if isinstance(self.prof, Scenario):
            sc = self.prof
            self.prof = sc.resolve_profile()
            # the scenario's knobs apply to every field still at its
            # constructor default; an explicitly passed kwarg wins, so
            # WindowedGateway(sc, policy="LT") tweaks one knob on a
            # shared spec (passing a kwarg AT its default defers to the
            # scenario — a dataclass cannot see the difference)
            for name, default, value in (
                    ("policy", "MO", sc.policy),
                    ("gamma", 0.5, sc.gamma),
                    ("delta", 20.0, sc.delta),
                    ("seed", 1234, sc.seed)):
                if getattr(self, name) == default:
                    setattr(self, name, value)
            # same precedence for the engine: explicit dispatch= wins; a
            # scenario that configures its own engine wins over the
            # online= shorthand (silently swapping a tuned engine for a
            # default OnlineDispatch() would be worse)
            if self.dispatch is None \
                    and not (self.online and sc.dispatch is None):
                self.dispatch = sc.resolve_dispatch()
            # estimator-state capacity follows the scenario's fleet
            # size: a 10^5-user scenario gets 10^5 stream slots without
            # the caller sizing state by hand. Monotone — an explicit
            # larger n_streams= wins, the default never shrinks
            if self.n_streams == 1024:
                self.n_streams = max(self.n_streams, sc.n_users)
            if self.cloud is None:
                self.cloud = sc.cloud
            if self.faults is None:
                self.faults = sc.faults
        if self.prof.is_stacked:
            raise ValueError("gateway serves one fleet; scenario/profile "
                             "is a stacked ensemble")
        self._cloud_meta = None
        if self.cloud is not None:
            self.prof, self._cloud_meta = self.cloud.extend(self.prof)
        # fault schedules bind to the EXTENDED pair axis (a scripted
        # outage can take down a cloud pair)
        self._fault_meta = None
        if self.faults is not None and self.faults.active:
            if self.pods is not None:
                raise ValueError(
                    "hierarchical (pods=) routing has no fault mask yet — "
                    "route the flat fleet under a FaultSchedule")
            self._fault_meta = self.faults.resolve(self.prof.n_pairs)
        self._pod_of_pair = None
        if self.pods is not None:
            if self.policy != "MO":
                raise ValueError("pods= hierarchical routing is two-level "
                                 "Algorithm 1 — MO policy only")
            pod = np.asarray(self.pods, np.int32)
            n_cloud = 0 if self._cloud_meta is None else int(
                np.asarray(self._cloud_meta.is_cloud).sum())
            if n_cloud and pod.shape == (self.prof.n_pairs - n_cloud,):
                # the remote pairs form their own pod under the global
                # balancer — the natural edge-clusters-plus-cloud shape
                pod = np.concatenate(
                    [pod, np.full((n_cloud,), pod.max() + 1, np.int32)])
            if pod.shape != (self.prof.n_pairs,):
                raise ValueError(
                    f"pods must give one pod id per pair "
                    f"({self.prof.n_pairs}), got shape {pod.shape}")
            self._pod_of_pair = jnp.asarray(pod, i32)
        if self.dispatch is None:
            self.dispatch = OnlineDispatch() if self.online \
                else StaticDispatch()
        self.online = isinstance(self.dispatch, OnlineDispatch)
        self.backend = resolve_backend(self.backend)
        self._key = jax.random.PRNGKey(self.seed)
        self._counts = jnp.zeros((self.n_streams,), i32)
        self._dstate = self.dispatch.init(self.prof)
        self._step = 0

        code = POLICY_CODES[self.policy]
        engine, prof = self.dispatch, self.prof
        n_groups, n_streams = prof.n_groups, self.n_streams
        gamma, delta = float(self.gamma), float(self.delta)
        backend, base_key = self.backend, self._key
        cloud_meta, pod_of_pair = self._cloud_meta, self._pod_of_pair
        penalty_fn = None if cloud_meta is None else cloud_meta.penalty
        fault_meta = self._fault_meta

        @jax.jit
        def _route_fused(state, counts, q0, ids):
            # MO fast path: estimator gather + the fused routing kernel
            # against the engine's current belief tables; rr advances by
            # W exactly as W select() calls would have advanced it
            gs = EST.group_of_count(counts[ids], n_groups)
            tbl = engine.tables(state, prof)
            pairs, q = moscore_route(tbl.T, tbl.E, tbl.mAP, gs,
                                     q0.astype(f32), delta=delta,
                                     gamma=gamma, backend=backend)
            state = {**state, "rr": state["rr"] + ids.shape[0]}
            return pairs, gs, q, state

        @jax.jit
        def _route_scan(state, counts, q0, ids, step0):
            # generic path (every policy): the engine's select_window
            # scan, with per-request keys folded from the ABSOLUTE
            # request index — window-partition invariant
            gs = EST.group_of_count(counts[ids], n_groups)
            idx = step0 + jnp.arange(ids.shape[0], dtype=i32)
            keys = jax.vmap(lambda i: jax.random.fold_in(base_key, i))(idx)
            pairs, q, state = engine.select_window(
                state, prof, code, gs, q0.astype(f32), keys,
                jnp.asarray(gamma, f32), jnp.asarray(delta, f32),
                penalty_fn=penalty_fn)
            return pairs, gs, q, state

        @jax.jit
        def _route_scan_masked(state, counts, q0, ids, step0):
            # fault-visible path: the same scan, plus the per-request
            # health mask drawn from the ABSOLUTE request index — fault
            # realizations are window-partition invariant exactly like
            # the key stream
            gs = EST.group_of_count(counts[ids], n_groups)
            idx = step0 + jnp.arange(ids.shape[0], dtype=i32)
            keys = jax.vmap(lambda i: jax.random.fold_in(base_key, i))(idx)
            healths = jax.vmap(fault_meta.health_at)(idx)
            pairs, q, state = engine.select_window(
                state, prof, code, gs, q0.astype(f32), keys,
                jnp.asarray(gamma, f32), jnp.asarray(delta, f32),
                penalty_fn=penalty_fn, healths=healths)
            return pairs, gs, q, state

        @jax.jit
        def _route_pods(state, counts, q0, ids):
            # hierarchical path: pod queue totals are snapshotted ONCE at
            # window admission (stale inside the window); level-2 exact
            # queues get in-window feedback like every other path
            gs = EST.group_of_count(counts[ids], n_groups)
            tbl = engine.tables(state, prof)
            pod_tbl = pod_aggregate(tbl, pod_of_pair)
            n_pods = pod_tbl.n_pairs
            q_pod0 = jax.ops.segment_sum(q0.astype(f32), pod_of_pair,
                                         num_segments=n_pods)

            def step(q, g):
                pen = None if cloud_meta is None \
                    else cloud_meta.penalty(g, q)
                p, _pod = hierarchical_select(
                    tbl, pod_tbl, pod_of_pair, g, q, q_pod0,
                    delta=delta, gamma=gamma, penalty=pen)
                return q.at[p].add(1.0), p.astype(i32)

            q, pairs = jax.lax.scan(step, q0.astype(f32), gs)
            state = {**state, "rr": state["rr"] + ids.shape[0]}
            return pairs, gs, q, state

        @jax.jit
        def _obs_counts(counts, ids, cnts):
            # last-write-wins scatter: scatter-MAX of the window index
            # per stream is well-defined under duplicates (unlike
            # .at[].set), so the result is bit-identical to a sequential
            # per-request replay
            w = ids.shape[0]
            pos = jnp.full((n_streams,), -1, i32).at[ids].max(
                jnp.arange(w, dtype=i32))
            latest = cnts[jnp.clip(pos, 0)]
            return jnp.where(pos >= 0, latest, counts)

        @jax.jit
        def _observe_win(state, pairs, groups, t_ms, e_mwh):
            return engine.observe_window(state, pairs, groups, t_ms,
                                         e_mwh)

        @jax.jit
        def _observe_one(state, p, g, t_ms, e_mwh):
            return engine.observe(state, p, g, t_ms, e_mwh)

        self._route_fused = _route_fused
        self._route_scan = _route_scan
        self._route_scan_masked = _route_scan_masked
        self._route_pods = _route_pods
        self._obs_counts = _obs_counts
        self._observe_win = _observe_win
        self._observe_one = _observe_one

    # -- estimator state ----------------------------------------------------

    def _check_streams(self, ids: np.ndarray):
        if ids.size and (ids.min() < 0 or ids.max() >= self.n_streams):
            raise ValueError(
                f"stream id out of range [0, {self.n_streams}) — raise "
                f"n_streams= (gateway estimator-state capacity)")

    def observe_detections_window(self, stream_ids, detected_counts):
        """Scatter a batch of detected object counts into the
        device-resident estimator state (one program; the latest entry
        wins for a stream that completes twice in one window)."""
        ids = np.asarray(stream_ids, np.int64)
        self._check_streams(ids)
        self._counts = self._obs_counts(
            self._counts, jnp.asarray(ids, i32),
            jnp.asarray(np.asarray(detected_counts), i32))

    # -- dispatch-state observation -----------------------------------------

    def observe_window(self, pairs, groups, latency_ms,
                       energy_mwh=None) -> None:
        """Fold a completed window's measurements into the dispatch
        state via the engine's ``observe_window`` hook — one fused device
        program (skipped entirely for non-adaptive engines: the hot
        serving path pays nothing under :class:`StaticDispatch`)."""
        if not self.dispatch.adaptive:
            return
        self._dstate = self._observe_win(
            self._dstate, jnp.asarray(np.asarray(pairs), i32),
            jnp.asarray(np.asarray(groups), i32),
            jnp.asarray(np.asarray(latency_ms), f32),
            None if energy_mwh is None
            else jnp.asarray(np.asarray(energy_mwh), f32))

    def _tables(self) -> ProfileTable:
        return self.dispatch.tables(self._dstate, self.prof)

    # -- decisions ----------------------------------------------------------

    def route_window(self, stream_ids, queue_depths):
        """Route one admission window in one jitted call.

        ``stream_ids``: (W,) ints below ``n_streams``; ``queue_depths``:
        (P,) live queue depths at admission. Returns ``(pairs (W,),
        est_groups (W,), q_after (P,))`` as device arrays — queue
        feedback is applied *within* the window (decision w+1 sees
        decision w's bump), and ``q_after`` is the depths to thread into
        the next window when no executor feedback arrives in between.
        Bit-identical for any window partition of the same request
        stream (the per-request :class:`Gateway` shim is the W=1 case).
        """
        ids = np.asarray(stream_ids, np.int64)
        self._check_streams(ids)
        ids_d = jnp.asarray(ids, i32)
        q0 = jnp.asarray(queue_depths, f32)   # no-copy for device arrays
        if self._pod_of_pair is not None:
            pairs, gs, q, self._dstate = self._route_pods(
                self._dstate, self._counts, q0, ids_d)
        elif self._fault_meta is not None and self._fault_meta.visible:
            # visible faults need the per-request health mask, which the
            # fused kernel (one mask per window) cannot express — the
            # generic scan carries it (cloud precedent)
            pairs, gs, q, self._dstate = self._route_scan_masked(
                self._dstate, self._counts, q0, ids_d,
                jnp.asarray(self._step, i32))
        elif self.policy == "MO" and self._cloud_meta is None:
            # the fused kernel scores raw tables with no penalty hook;
            # cloud-active MO takes the generic scan for the congestion
            # term (bit-identical scoring otherwise)
            pairs, gs, q, self._dstate = self._route_fused(
                self._dstate, self._counts, q0, ids_d)
        else:
            pairs, gs, q, self._dstate = self._route_scan(
                self._dstate, self._counts, q0, ids_d,
                jnp.asarray(self._step, i32))
        self._step += int(ids.shape[0])
        return pairs, gs, q


class Gateway(WindowedGateway):
    """Per-request shim over the windowed request plane (deprecated).

    ``route`` / ``observe_detections`` / ``observe_latency`` are the
    W=1 forms of the windowed hooks — bit-identical to
    :class:`WindowedGateway` on the same request stream (asserted in
    ``tests/test_serving_plane.py``), just W device programs where the
    windowed path needs one. New code should admit windows; see the
    migration table in ``docs/serving.md``."""

    def __post_init__(self):
        from repro.core.scenario import LegacyAPIWarning
        warnings.warn(
            "repro.serving.Gateway routes one request per device program; "
            "it is a deprecated shim over the windowed request plane — "
            "use WindowedGateway.route_window / ServingPlane (see "
            "docs/serving.md for the migration table)",
            LegacyAPIWarning, stacklevel=3)
        super().__post_init__()

    # -- estimator ----------------------------------------------------------
    def estimate_group(self, stream_id: int) -> int:
        return int(EST.group_of_count(self._counts[int(stream_id)],
                                      self.prof.n_groups))

    def observe_detections(self, stream_id: int, detected_count: int) -> None:
        self.observe_detections_window([stream_id], [detected_count])

    def observe_latency(self, pair: int, group: int, latency_ms: float,
                        energy_mwh: float | None = None) -> None:
        """Fold one completed request's measurements into the dispatch
        state (skipped entirely for non-adaptive engines)."""
        if not self.dispatch.adaptive:
            return
        self._dstate = self._observe_one(
            self._dstate, jnp.asarray(pair, i32), jnp.asarray(group, i32),
            jnp.asarray(latency_ms, f32),
            None if energy_mwh is None else jnp.asarray(energy_mwh, f32))

    # -- decisions ----------------------------------------------------------
    def route(self, stream_id: int, queue_depths) -> tuple[int, int]:
        """One request -> (pair, est_group): a window of one."""
        pairs, gs, _q = self.route_window([stream_id], queue_depths)
        return int(pairs[0]), int(gs[0])
