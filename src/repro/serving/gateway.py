"""The gateway: per-request (or windowed) policy decisions.

Holds the offline ProfileTable, a pluggable dispatch engine
(``repro.core.dispatch`` — the SAME ``init``/``select``/``observe`` code
the batched simulator threads through its scan), and the per-stream
estimator state (last detected count). Per-request decisions use the
jitted Algorithm-1 scorer via the engine; batched routing windows go
through the fused ``moscore`` Pallas kernel against the engine's belief
tables — identical results (tests assert so). With an
:class:`~repro.core.dispatch.OnlineDispatch` engine the gateway folds
every observed latency/energy back into the EWMA belief state
(per-request ``observe_latency`` or the batched ``observe_window``).

A gateway can be built straight from a
:class:`~repro.core.scenario.Scenario` — ``Gateway(scenario)`` — so
simulation and serving share ONE config object: the scenario's profile,
policy, γ, Δ, dispatch engine and seed all apply to knobs left at their
constructor defaults, while any explicitly passed non-default kwarg
(``policy=``, ``gamma=``, ``dispatch=``, ...) wins — tweak one knob on
a shared spec without losing the rest."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator as EST
from repro.core.dispatch import (DispatchEngine, OnlineDispatch,
                                 StaticDispatch)
from repro.core.policies import POLICY_CODES
from repro.core.profiles import ProfileTable
from repro.kernels.moscore import moscore_route


@dataclass
class Gateway:
    prof: ProfileTable    # or a repro.core.scenario.Scenario (resolved
                          # in __post_init__; its policy/γ/Δ/dispatch/
                          # seed apply)
    policy: str = "MO"
    gamma: float = 0.5
    delta: float = 20.0
    online: bool = False      # shorthand for dispatch=OnlineDispatch()
    seed: int = 1234          # seeds the RND baseline's stream
    dispatch: DispatchEngine | None = None
    _stream_counts: dict = field(default_factory=dict)
    _dstate: Any = None
    _rng: Any = None

    def __post_init__(self):
        from repro.core.scenario import Scenario
        if isinstance(self.prof, Scenario):
            sc = self.prof
            self.prof = sc.resolve_profile()
            # the scenario's knobs apply to every field still at its
            # constructor default; an explicitly passed kwarg wins, so
            # Gateway(sc, policy="LT") tweaks one knob on a shared spec
            # (passing a kwarg AT its default defers to the scenario —
            # a dataclass cannot see the difference)
            for name, default, value in (
                    ("policy", "MO", sc.policy),
                    ("gamma", 0.5, sc.gamma),
                    ("delta", 20.0, sc.delta),
                    ("seed", 1234, sc.seed)):
                if getattr(self, name) == default:
                    setattr(self, name, value)
            # same precedence for the engine: explicit dispatch= wins; a
            # scenario that configures its own engine wins over the
            # online= shorthand (silently swapping a tuned engine for a
            # default OnlineDispatch() would be worse)
            if self.dispatch is None \
                    and not (self.online and sc.dispatch is None):
                self.dispatch = sc.resolve_dispatch()
        if self.prof.is_stacked:
            raise ValueError("Gateway serves one fleet; scenario/profile "
                             "is a stacked ensemble")
        if self.dispatch is None:
            self.dispatch = OnlineDispatch() if self.online \
                else StaticDispatch()
        self.online = isinstance(self.dispatch, OnlineDispatch)
        self._rng = jax.random.PRNGKey(self.seed)
        self._dstate = self.dispatch.init(self.prof)
        code = POLICY_CODES[self.policy]
        engine, prof = self.dispatch, self.prof

        @jax.jit
        def _select(state, g, q, rnd, gamma, delta):
            return engine.select(state, prof, code, g, q, rnd, gamma, delta)

        @jax.jit
        def _observe(state, p, g, t_ms, e_mwh):
            return engine.observe(state, p, g, t_ms, e_mwh)

        self._select = _select
        self._observe = _observe

    # -- estimator ----------------------------------------------------------
    def estimate_group(self, stream_id: int) -> int:
        cnt = self._stream_counts.get(stream_id, 0)
        return int(EST.group_of_count(jnp.asarray(cnt), self.prof.n_groups))

    def observe_detections(self, stream_id: int, detected_count: int) -> None:
        self._stream_counts[stream_id] = detected_count

    def observe_latency(self, pair: int, group: int, latency_ms: float,
                        energy_mwh: float | None = None) -> None:
        """Fold one completed request's measurements into the dispatch
        state (skipped entirely for non-adaptive engines — the hot
        serving path pays nothing under :class:`StaticDispatch`)."""
        if not self.dispatch.adaptive:
            return
        self._dstate = self._observe(
            self._dstate, jnp.asarray(pair, jnp.int32),
            jnp.asarray(group, jnp.int32),
            jnp.asarray(latency_ms, jnp.float32),
            None if energy_mwh is None
            else jnp.asarray(energy_mwh, jnp.float32))

    def observe_window(self, pairs, groups, latency_ms,
                       energy_mwh=None) -> None:
        """Batched :meth:`observe_latency` over a completed routing window
        — the engine's own ``observe_window`` hook (for
        :class:`OnlineDispatch`, one fused device program equivalent to
        per-request observes)."""
        if not self.dispatch.adaptive:
            return
        self._dstate = self.dispatch.observe_window(
            self._dstate, jnp.asarray(pairs, jnp.int32),
            jnp.asarray(groups, jnp.int32),
            jnp.asarray(latency_ms, jnp.float32),
            None if energy_mwh is None
            else jnp.asarray(energy_mwh, jnp.float32))

    def _tables(self) -> ProfileTable:
        return self.dispatch.tables(self._dstate, self.prof)

    # -- decisions ----------------------------------------------------------
    def route(self, stream_id: int, queue_depths) -> tuple[int, int]:
        """One request -> (pair, est_group)."""
        g = self.estimate_group(stream_id)
        self._rng, k = jax.random.split(self._rng)
        p, self._dstate = self._select(
            self._dstate, jnp.asarray(g, jnp.int32),
            jnp.asarray(queue_depths, jnp.float32), k,
            jnp.asarray(self.gamma, jnp.float32),
            jnp.asarray(self.delta, jnp.float32))
        return int(p), g

    def route_window(self, stream_ids, queue_depths):
        """Batched routing window through the fused kernel (MO policy only);
        returns (pairs (W,), est_groups (W,), q_after). Scores against the
        dispatch engine's current belief tables, exactly like
        :meth:`route`."""
        assert self.policy == "MO", "windowed routing is the MO fast path"
        gs = jnp.asarray([self.estimate_group(s) for s in stream_ids],
                         jnp.int32)
        p = self._tables()
        pairs, q = moscore_route(p.T, p.E, p.mAP, gs,
                                 jnp.asarray(queue_depths, jnp.float32),
                                 delta=self.delta, gamma=self.gamma)
        return np.asarray(pairs), np.asarray(gs), np.asarray(q)
