"""Flux-style MMDiT: double-stream (img/txt) joint-attention blocks followed
by single-stream blocks; rectified-flow objective (BFL Flux tech report /
SD3 arXiv:2403.03206).

Frontends are stubs by assignment: ``input_specs`` provides VAE latents,
T5 text features (d_txt) and the CLIP pooled vector directly.
Positional encoding: 1D RoPE over the concatenated (txt ++ img) sequence —
a documented simplification of Flux's 3-axis RoPE (DESIGN.md §9).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.common import flags
from repro.common.configs import MMDiTConfig
from repro.common.precision import parse_dtype
from repro.distributed.sharding import constraint
from repro.models import layers as L
from repro.models.dit import timestep_embedding

f32 = jnp.float32


def param_specs(cfg: MMDiTConfig):
    dt = parse_dtype(cfg.dtype)
    D = cfg.d_model
    pdim = cfg.in_channels * cfg.patch ** 2
    Ld, Ls = cfg.n_double_blocks, cfg.n_single_blocks

    def stream(Ln):
        return {
            "adaln": L.sds((Ln, D, 6 * D), dt),
            "wqkv": L.sds((Ln, D, 3 * D), dt),
            "wo": L.sds((Ln, D, D), dt),
            "mlp_in": L.sds((Ln, D, 4 * D), dt),
            "mlp_out": L.sds((Ln, 4 * D, D), dt),
        }

    def stream_logical():
        return {
            "adaln": ("layer", "embed", "mlp"),
            "wqkv": ("layer", "embed", "heads"),
            "wo": ("layer", "heads", "embed"),
            "mlp_in": ("layer", "embed", "mlp"),
            "mlp_out": ("layer", "mlp", "embed"),
        }

    shapes: dict[str, Any] = {
        "img_in": L.sds((pdim, D), dt),
        "txt_in": L.sds((cfg.d_txt, D), dt),
        "vec_in": L.sds((cfg.d_pooled, D), dt),
        "t_mlp1": L.sds((256, D), dt),
        "t_mlp2": L.sds((D, D), dt),
        "double_img": stream(Ld),
        "double_txt": stream(Ld),
        "single": {
            "adaln": L.sds((Ls, D, 3 * D), dt),
            "wqkv_mlp": L.sds((Ls, D, 3 * D + 4 * D), dt),
            "wout": L.sds((Ls, D + 4 * D, D), dt),
        },
        "final_adaln": L.sds((D, 2 * D), dt),
        "final_w": L.sds((D, pdim), dt),
    }
    logical: dict[str, Any] = {
        "img_in": (None, "embed"),
        "txt_in": ("embed_nofsdp", "embed"),
        "vec_in": ("embed_nofsdp", "embed"),
        "t_mlp1": (None, "embed"),
        "t_mlp2": ("embed_nofsdp", "embed"),
        "double_img": stream_logical(),
        "double_txt": stream_logical(),
        "single": {
            "adaln": ("layer", "embed", "mlp"),
            "wqkv_mlp": ("layer", "embed", "mlp"),
            "wout": ("layer", "mlp", "embed"),
        },
        "final_adaln": ("embed_nofsdp", "mlp"),
        "final_w": ("embed", None),
    }
    if cfg.guidance_embed:
        shapes["g_mlp1"] = L.sds((256, D), dt)
        shapes["g_mlp2"] = L.sds((D, D), dt)
        logical["g_mlp1"] = (None, "embed")
        logical["g_mlp2"] = ("embed_nofsdp", "embed")
    return shapes, logical


def init_params(cfg: MMDiTConfig, rng):
    return L.init_tree(rng, param_specs(cfg)[0])


def _attn(q, k, v, nh):
    b, s, d = q.shape
    hd = d // nh
    o = L.mha(q.reshape(b, s, nh, hd), k.reshape(b, s, nh, hd),
              v.reshape(b, s, nh, hd), causal=False)
    return o.reshape(b, s, d)


def _rope_qk(q, k, nh, positions):
    b, s, d = q.shape
    hd = d // nh
    q = L.apply_rope(q.reshape(b, s, nh, hd), positions, 10_000.0)
    k = L.apply_rope(k.reshape(b, s, nh, hd), positions, 10_000.0)
    return q.reshape(b, s, d), k.reshape(b, s, d)


def forward(cfg: MMDiTConfig, params, latents, txt, pooled, t, guidance=None):
    """latents (B,Hl,Wl,C); txt (B,T,d_txt); pooled (B,d_pooled); t (B,) in
    [0,1]; guidance (B,) or None. Returns velocity prediction (B,Hl,Wl,C)."""
    from repro.models.dit import patchify, unpatchify

    b, hl, wl, c = latents.shape
    dt_ = params["img_in"].dtype
    img = patchify(latents.astype(dt_), cfg.patch) @ params["img_in"]
    txt = txt.astype(dt_) @ params["txt_in"]
    n_img, n_txt, d = img.shape[1], txt.shape[1], img.shape[2]
    nh = cfg.n_heads

    vec = timestep_embedding(t * 1000.0, 256) @ params["t_mlp1"].astype(f32)
    vec = jax.nn.silu(vec) @ params["t_mlp2"].astype(f32)
    vec = vec + pooled.astype(f32) @ params["vec_in"].astype(f32)
    if cfg.guidance_embed and guidance is not None:
        g = timestep_embedding(guidance * 1000.0, 256) @ params["g_mlp1"].astype(f32)
        vec = vec + jax.nn.silu(g) @ params["g_mlp2"].astype(f32)
    vec_act = jax.nn.silu(vec)

    img = constraint(img, ("batch", "seq", None))
    positions = jnp.arange(n_txt + n_img, dtype=jnp.int32)[None]
    pos_txt, pos_img = positions[:, :n_txt], positions[:, n_txt:]

    def mod6(w):
        m = (vec_act @ w["adaln"].astype(f32)).astype(dt_)
        return jnp.split(m, 6, axis=-1)

    def double_block(carry, w):
        img, txt = carry
        wi, wt = w
        i_sh1, i_sc1, i_g1, i_sh2, i_sc2, i_g2 = mod6(wi)
        t_sh1, t_sc1, t_g1, t_sh2, t_sc2, t_g2 = mod6(wt)

        iq, ik, iv = jnp.split(
            (L.layernorm(img, jnp.zeros((d,), f32)) * (1 + i_sc1[:, None])
             + i_sh1[:, None]) @ wi["wqkv"], 3, axis=-1)
        tq, tk, tv = jnp.split(
            (L.layernorm(txt, jnp.zeros((d,), f32)) * (1 + t_sc1[:, None])
             + t_sh1[:, None]) @ wt["wqkv"], 3, axis=-1)
        iq, ik = _rope_qk(iq, ik, nh, pos_img)
        tq, tk = _rope_qk(tq, tk, nh, pos_txt)
        q = jnp.concatenate([tq, iq], axis=1)
        k = jnp.concatenate([tk, ik], axis=1)
        v = jnp.concatenate([tv, iv], axis=1)
        o = _attn(q, k, v, nh)
        to, io = o[:, :n_txt], o[:, n_txt:]
        img = img + i_g1[:, None] * (io @ wi["wo"])
        txt = txt + t_g1[:, None] * (to @ wt["wo"])

        def mlp(x, w_, sh, sc, g):
            xn = L.layernorm(x, jnp.zeros((d,), f32)) * (1 + sc[:, None]) + sh[:, None]
            return x + g[:, None] * (jax.nn.gelu(xn @ w_["mlp_in"]) @ w_["mlp_out"])

        img = mlp(img, wi, i_sh2, i_sc2, i_g2)
        txt = mlp(txt, wt, t_sh2, t_sc2, t_g2)
        img = constraint(img, ("batch", "rep", "rep"))
        txt = constraint(txt, ("batch", "rep", "rep"))
        return (img, txt), None

    (img, txt), _ = jax.lax.scan(
        double_block, (img, txt), (params["double_img"], params["double_txt"]),
        unroll=flags.layer_unroll("double"))

    x = jnp.concatenate([txt, img], axis=1)

    def single_block(x, w):
        m = (vec_act @ w["adaln"].astype(f32)).astype(dt_)
        sh, sc, g = jnp.split(m, 3, axis=-1)
        xn = L.layernorm(x, jnp.zeros((d,), f32)) * (1 + sc[:, None]) + sh[:, None]
        h = xn @ w["wqkv_mlp"]
        qkv, mlp_h = h[..., : 3 * d], h[..., 3 * d:]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q, k = _rope_qk(q, k, nh, positions)
        o = _attn(q, k, v, nh)
        out = jnp.concatenate([o, jax.nn.gelu(mlp_h)], axis=-1) @ w["wout"]
        x = x + g[:, None] * out
        return constraint(x, ("batch", "rep", "rep")), None

    x, _ = jax.lax.scan(single_block, x, params["single"],
                        unroll=flags.layer_unroll("single"))
    img = x[:, n_txt:]

    m = (vec_act @ params["final_adaln"].astype(f32)).astype(dt_)
    sh, sc = jnp.split(m, 2, axis=-1)
    img = L.layernorm(img, jnp.zeros((d,), f32)) * (1 + sc[:, None]) + sh[:, None]
    out = img @ params["final_w"]
    return unpatchify(out, cfg.patch, hl, c)


def rectified_flow_loss(cfg: MMDiTConfig, params, batch):
    """x_t = (1-t)x0 + t*eps, target v = eps - x0."""
    lat, txt, pooled = batch["latents"], batch["txt"], batch["pooled"]
    t, eps = batch["t"], batch["noise"]
    tb = t[:, None, None, None].astype(f32)
    xt = (1 - tb) * lat.astype(f32) + tb * eps.astype(f32)
    guidance = batch.get("guidance")
    v = forward(cfg, params, xt.astype(lat.dtype), txt, pooled, t,
                guidance).astype(f32)
    target = eps.astype(f32) - lat.astype(f32)
    loss = jnp.mean(jnp.square(v - target))
    return loss, {"mse": loss}


def sample_step(cfg: MMDiTConfig, params, xt, txt, pooled, t, t_prev,
                guidance=None):
    """One rectified-flow Euler step from t to t_prev (< t)."""
    v = forward(cfg, params, xt, txt, pooled, t, guidance).astype(f32)
    x = xt.astype(f32) + (t_prev - t)[:, None, None, None] * v
    return x.astype(xt.dtype)
