"""Shared neural-net layers (pure functions over param dicts).

Conventions:
  * every ``*_specs(cfg)`` returns ``(shapes, logical)`` trees with identical
    structure: ``shapes`` of ``jax.ShapeDtypeStruct``, ``logical`` of tuples of
    logical axis names understood by ``repro.distributed.sharding``;
  * compute follows the precision policy: bf16 matmuls, fp32 softmax / norms.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import flags
from repro.distributed.sharding import constraint

f32 = jnp.float32


# ---------------------------------------------------------------- norms ----

def rmsnorm(x, scale, eps: float = 1e-6):
    xf = x.astype(f32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(f32))).astype(x.dtype)


def layernorm(x, scale, bias=None, eps: float = 1e-5):
    xf = x.astype(f32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * (1.0 + scale.astype(f32))
    if bias is not None:
        out = out + bias.astype(f32)
    return out.astype(x.dtype)


def norm_apply(kind: str, x, scale, bias=None):
    if kind == "rmsnorm":
        return rmsnorm(x, scale)
    return layernorm(x, scale, bias)


# ----------------------------------------------------------------- rope ----

def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (..., S, n_heads, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))               # (hd/2,)
    ang = positions[..., None].astype(f32) * freqs           # (...,S,hd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(f32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- attention ---

def _causal_mask(sq: int, sk: int, q_offset) -> jax.Array:
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    return qpos >= kpos


def mha(q, k, v, *, causal: bool, q_offset=0, kv_len=None):
    """Grouped-query attention, fp32 softmax.

    q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd). ``kv_len`` masks a partially-filled
    cache. Returns (B,Sq,H,hd).
    """
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(f32) * scale
    if causal:
        m = _causal_mask(sq, sk, q_offset)
        s = jnp.where(m[None, None, None], s, -1e30)
    if kv_len is not None:
        valid = jnp.arange(sk)[None, :] < jnp.reshape(kv_len, (-1, 1))
        s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return o.reshape(b, sq, h, hd)


def chunked_mha(q, k, v, *, causal: bool, chunk: int = 512, q_offset=0):
    """Streaming-softmax attention: scan over query chunks, never
    materialising the full (Sq,Sk) score matrix. Used for long-prefill cells
    in the XLA path (the Pallas flash kernel covers real-TPU execution).
    ``q_offset`` supports prefill-into-cache (queries live at positions
    q_offset..q_offset+Sq within the K/V sequence)."""
    b, sq, h, hd = q.shape
    if sq <= chunk:
        return mha(q, k, v, causal=causal, q_offset=q_offset)
    n = sq // chunk
    assert sq % chunk == 0, (sq, chunk)
    qc = q.reshape(b, n, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    offs = jnp.arange(n) * chunk + q_offset

    def step(_, qo):
        qi, off = qo
        return None, mha(qi, k, v, causal=causal, q_offset=off)

    _, oc = jax.lax.scan(step, None, (qc, offs),
                         unroll=flags.layer_unroll("attn"))
    return oc.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def attention_block(x, w, cfg, *, positions, causal=True, cache=None,
                    cache_pos=None, attn_impl: str = "auto"):
    """Full attention block: norm -> qkv -> rope -> attn -> out-proj.

    ``cache``: optional dict(k=(B,S,KV,hd), v=...) for decode; new kv written
    at ``cache_pos``. Returns (out, new_cache).
    """
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    xn = norm_apply(cfg.norm, x, w["norm"], w.get("norm_bias"))
    q = (xn @ w["wq"]).reshape(b, s, h, hd)
    kx = (xn @ w["wk"]).reshape(b, s, kv, hd)
    vx = (xn @ w["wv"]).reshape(b, s, kv, hd)
    q = constraint(q, ("batch", "seq", "heads", None))
    q = apply_rope(q, positions, cfg.rope_theta)
    kx = apply_rope(kx, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        quant = "k_scale" in cache

        def write(buf, new, pos):
            return jax.lax.dynamic_update_slice(
                buf, new.astype(buf.dtype), (0, pos) + (0,) * (buf.ndim - 2))

        if quant:
            # int8 cache with per-(position, kv-head) fp32 scales: halves
            # the decode-dominating HBM stream (EXPERIMENTS §Perf it.3)
            def quantize(xnew):
                sc = jnp.max(jnp.abs(xnew.astype(f32)), axis=-1,
                             keepdims=True) / 127.0 + 1e-12
                qv = jnp.clip(jnp.round(xnew.astype(f32) / sc), -127, 127)
                return qv.astype(jnp.int8), sc

            kq, ks = quantize(kx)
            vq, vs = quantize(vx)
            new_cache = {
                "k": write(cache["k"], kq, cache_pos),
                "v": write(cache["v"], vq, cache_pos),
                "k_scale": write(cache["k_scale"], ks, cache_pos),
                "v_scale": write(cache["v_scale"], vs, cache_pos),
            }
            ck = (new_cache["k"].astype(jnp.bfloat16)
                  * new_cache["k_scale"].astype(jnp.bfloat16))
            cv = (new_cache["v"].astype(jnp.bfloat16)
                  * new_cache["v_scale"].astype(jnp.bfloat16))
            ck, cv = ck.astype(x.dtype), cv.astype(x.dtype)
        else:
            ck = write(cache["k"], kx, cache_pos)
            cv = write(cache["v"], vx, cache_pos)
            new_cache = {"k": ck, "v": cv}
        # Causal mask with the query offset also masks the unfilled cache
        # tail (slots > cache_pos + s are in the future of every query).
        if s >= 4096:   # long prefill: stream query chunks (flash-style)
            o = chunked_mha(q, ck, cv, causal=True, q_offset=cache_pos)
        else:
            o = mha(q, ck, cv, causal=True, q_offset=cache_pos)
    else:
        if attn_impl == "chunked" or (attn_impl == "auto" and s >= 8192):
            o = chunked_mha(q, kx, vx, causal=causal)
        else:
            o = mha(q, kx, vx, causal=causal)
    o = constraint(o, ("batch", "seq", "heads", None))
    out = o.reshape(b, s, h * hd) @ w["wo"]
    return constraint(out, ("batch", "seq", "rep")), new_cache


# ------------------------------------------------------------------ mlp ----

def swiglu(x, w):
    h = jax.nn.silu(x @ w["w_gate"]) * (x @ w["w_up"])
    h = constraint(h, ("batch", "seq", "mlp"))
    return h @ w["w_down"]


def gelu_mlp(x, w):
    h = jax.nn.gelu(x @ w["w_up"] + w.get("b_up", 0))
    h = constraint(h, ("batch", "seq", "mlp"))
    return h @ w["w_down"] + w.get("b_down", 0)


# ------------------------------------------------------------ init utils ---

def trunc_init(rng, shape, dtype, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(rng, -2, 2, shape, f32) * std).astype(dtype)


def init_tree(rng, shapes, init_fn=trunc_init):
    leaves, treedef = jax.tree.flatten(shapes)
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for r, l in zip(rngs, leaves):
        if "norm" in str(l.dtype) or len(l.shape) == 1:
            out.append(jnp.zeros(l.shape, l.dtype))
        else:
            out.append(init_fn(r, l.shape, l.dtype))
    return jax.tree.unflatten(treedef, out)


def sds(shape, dtype=jnp.bfloat16):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)
