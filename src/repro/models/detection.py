"""Single-shot detection heads for the paper's own edge fleet.

The paper's device-model pairs run SSD v1 / SSD Lite / YOLOv8-{n,s,m} on
SBCs. For the end-to-end serving example we implement a family of small
single-shot detectors ("ssd_lite", "ssd_v1", "yolo_n/s/m"-class capacity
tiers) over the convnet substrate: a width/depth-scaled conv backbone plus a
dense per-cell prediction head (objectness, 4 box coords, class logits).

These are the *workload* models of the reproduction (they generate real
detections whose object counts feed the estimator); the assigned-architecture
backbones are served by the same machinery through `repro.serving`.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L

f32 = jnp.float32


# capacity tiers: (widths per stage, blocks per stage, grid)
TIERS = {
    "ssd_v1":   ((16, 32, 64), (1, 1, 1), 8),
    "ssd_lite": ((12, 24, 48), (1, 1, 1), 8),
    "effdet0":  ((16, 32, 64), (1, 2, 2), 8),
    "yolo_n":   ((16, 32, 64), (1, 2, 2), 8),
    "yolo_s":   ((24, 48, 96), (2, 2, 3), 8),
    "yolo_m":   ((32, 64, 128), (2, 4, 4), 8),
}


def param_specs(tier: str, n_classes: int = 4, img_res: int = 64,
                dtype=jnp.float32):
    widths, depths, grid = TIERS[tier]
    shapes: dict[str, Any] = {}
    cin = 3
    for si, (w, d) in enumerate(zip(widths, depths)):
        for bi in range(d):
            shapes[f"s{si}b{bi}/w"] = L.sds((3, 3, cin, w), dtype)
            shapes[f"s{si}b{bi}/b"] = L.sds((w,), f32)
            cin = w
    out_dim = 1 + 4 + n_classes           # obj, box, classes
    shapes["head/w"] = L.sds((1, 1, cin, out_dim), dtype)
    shapes["head/b"] = L.sds((out_dim,), f32)
    return shapes


def init_params(tier: str, rng, **kw):
    return L.init_tree(rng, param_specs(tier, **kw))


def forward(tier: str, params, images):
    """images (B,H,W,3) -> per-cell predictions (B,G,G,1+4+C)."""
    widths, depths, grid = TIERS[tier]
    x = images
    for si, (w, d) in enumerate(zip(widths, depths)):
        for bi in range(d):
            stride = 2 if bi == 0 else 1
            x = jax.lax.conv_general_dilated(
                x, params[f"s{si}b{bi}/w"].astype(x.dtype), (stride, stride),
                "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jax.nn.relu(x + params[f"s{si}b{bi}/b"].astype(x.dtype))
    # pool to fixed grid
    gh = max(1, x.shape[1] // grid)
    x = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, gh, gh, 1), (1, gh, gh, 1), "VALID") / (gh * gh)
    x = jax.lax.conv_general_dilated(
        x, params["head/w"].astype(x.dtype), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return x + params["head/b"].astype(x.dtype)


def count_objects(preds, threshold: float = 0.0) -> jax.Array:
    """Detected object count per image = number of cells with objectness
    above threshold (pre-sigmoid logits)."""
    obj = preds[..., 0]
    return jnp.sum((obj > threshold).astype(jnp.int32), axis=(-2, -1))


def detection_loss(tier: str, params, batch):
    """batch: images, obj_grid (B,G,G) {0,1}, cls_grid (B,G,G) int."""
    preds = forward(tier, params, batch["images"])
    obj_logit = preds[..., 0]
    obj = batch["obj_grid"].astype(f32)
    obj_loss = jnp.mean(
        jnp.maximum(obj_logit, 0) - obj_logit * obj
        + jnp.log1p(jnp.exp(-jnp.abs(obj_logit))))
    cls_lp = jax.nn.log_softmax(preds[..., 5:].astype(f32))
    cls_nll = -jnp.take_along_axis(cls_lp, batch["cls_grid"][..., None], -1)[..., 0]
    cls_loss = jnp.sum(cls_nll * obj) / (jnp.sum(obj) + 1e-6)
    return obj_loss + cls_loss, {"obj": obj_loss, "cls": cls_loss}
