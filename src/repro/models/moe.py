"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Design (TPU-native, GShard-style but without the O(tokens x E x C) one-hot):
  1. router top-k over experts (fp32),
  2. flatten (token, expert-choice) assignments, *sort by expert id* inside
     each token group (groups = batch shards, so the sort never crosses a
     device boundary under SPMD),
  3. compute each assignment's position within its expert via a cumulative
     count; positions >= capacity are dropped (capacity_factor controls drop
     rate exactly as in GShard/MaxText),
  4. scatter token ids into an (E, C) slot table, gather tokens -> (E, C, D),
  5. batched expert GEMMs (E-sharded over the ``model``/EP axis),
  6. weighted scatter-add back to token order.

FLOP overhead over the ideal is exactly ``capacity_factor``; no E-times dense
waste. Expert weights carry the ``expert`` logical axis so EP falls out of the
sharding rules; XLA inserts the dispatch all-to-all/all-gather.

``router_impl="balanced"`` applies the paper's two-stage idea *inside* the
model: expert affinity is the accuracy analogue (hard floor via top-2k
pre-filter), and a load penalty (EWMA tokens-per-expert = queue depth
analogue) is scalarised with the affinity gap — multi-objective expert
routing. This is a beyond-paper feature, off by default.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constraint
from repro.models.layers import sds

f32 = jnp.float32


def expert_specs(cfg, dtype):
    """Parameter shapes + logical axes for the MoE block of ONE layer stack.

    Leading dim L (scanned layers)."""
    L, D, E, Fe = cfg.n_layers, cfg.d_model, cfg.n_experts, cfg.d_exp
    shapes = {
        "router": sds((L, D, E), f32),
        "e_gate": sds((L, E, D, Fe), dtype),
        "e_up": sds((L, E, D, Fe), dtype),
        "e_down": sds((L, E, Fe, D), dtype),
    }
    logical = {
        "router": ("layer", "embed_nofsdp", None),
        "e_gate": ("layer", "expert", "embed", "expert_mlp"),
        "e_up": ("layer", "expert", "embed", "expert_mlp"),
        "e_down": ("layer", "expert", "expert_mlp", "embed"),
    }
    return shapes, logical


def _capacity(tokens_per_group: int, k: int, E: int, cf: float) -> int:
    c = int(tokens_per_group * k * cf / E) + 1
    return max(k, (c + 3) // 4 * 4)


def route_topk(logits, k: int):
    """Standard softmax-then-top-k routing (DeepSeek renormalised gates)."""
    probs = jax.nn.softmax(logits.astype(f32), axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / (jnp.sum(gate, -1, keepdims=True) + 1e-9)
    return gate, idx, probs


def route_balanced(logits, k: int, load_ewma, gamma: float = 0.5):
    """Multi-objective routing (paper Algorithm 1 transplanted to experts):

    Stage 1 (accuracy filter): keep the 2k highest-affinity experts per token
    — affinity may drop at most the 2k-th value (Δ analogue).
    Stage 2 (weighted sum): J = gamma * (1 - affinity_norm) + (1-gamma) *
    load_norm over the candidates; pick top-k by -J.
    """
    probs = jax.nn.softmax(logits.astype(f32), axis=-1)
    E = probs.shape[-1]
    kk = min(2 * k, E)
    thr = jax.lax.top_k(probs, kk)[0][..., -1:]
    feasible = probs >= thr
    a_min = jnp.min(jnp.where(feasible, probs, jnp.inf), -1, keepdims=True)
    a_max = jnp.max(jnp.where(feasible, probs, -jnp.inf), -1, keepdims=True)
    a_norm = (probs - a_min) / (a_max - a_min + 1e-9)
    l_min, l_max = jnp.min(load_ewma), jnp.max(load_ewma)
    l_norm = (load_ewma - l_min) / (l_max - l_min + 1e-9)
    score = gamma * (1.0 - a_norm) + (1.0 - gamma) * l_norm
    score = jnp.where(feasible, score, jnp.inf)
    _, idx = jax.lax.top_k(-score, k)
    gate = jnp.take_along_axis(probs, idx, axis=-1)
    gate = gate / (jnp.sum(gate, -1, keepdims=True) + 1e-9)
    return gate, idx, probs


def aux_load_loss(probs, idx, E: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss."""
    me = jnp.mean(probs.reshape(-1, E), axis=0)
    onehot = jax.nn.one_hot(idx.reshape(-1), E, dtype=f32)
    ce = jnp.mean(jnp.sum(onehot, axis=-2) > 0, axis=0) if onehot.ndim == 3 \
        else jnp.mean(onehot, axis=0)
    return E * jnp.sum(me * ce)


def _dispatch_one_group(xg, idx, gate, E: int, C: int):
    """xg (n,D), idx (n,k), gate (n,k) -> (y (n,D), n_dropped)."""
    n, k = idx.shape
    flat_e = idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    flat_g = gate.reshape(-1).astype(f32)

    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.zeros((E,), jnp.int32).at[se].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(n * k, dtype=jnp.int32) - starts[se]
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)      # E*C = overflow sentinel

    slot_token = jnp.zeros((E * C + 1,), jnp.int32).at[slot].set(st)
    slot_gate = jnp.zeros((E * C + 1,), f32).at[slot].set(
        jnp.where(keep, sg, 0.0))
    slot_token = slot_token[: E * C].reshape(E, C)
    slot_gate = slot_gate[: E * C].reshape(E, C)
    slot_valid = slot_gate > 0.0
    n_dropped = jnp.sum(~keep)
    return slot_token, slot_gate, slot_valid, n_dropped


def moe_ffn(x, w, cfg, *, num_groups: int = 1, load_ewma=None):
    """x: (B,S,D) -> (y, aux) where aux = {aux_loss, dropped_frac, load}."""
    B, S, D = x.shape
    E, k, cf = cfg.n_experts, cfg.top_k, cfg.capacity_factor
    N = B * S
    G = num_groups if N % max(num_groups, 1) == 0 else 1
    n = N // G
    C = _capacity(n, k, E, cf)

    xf = x.reshape(G, n, D)
    logits = jnp.einsum("gnd,de->gne", xf.astype(f32), w["router"].astype(f32))
    logits = constraint(logits, ("batch", None, None))
    if cfg.router_impl == "balanced" and load_ewma is not None:
        gate, idx, probs = route_balanced(logits, k, load_ewma)
    else:
        gate, idx, probs = route_topk(logits, k)

    slot_token, slot_gate, slot_valid, dropped = jax.vmap(
        functools.partial(_dispatch_one_group, E=E, C=C))(xf, idx, gate)

    # Gather: (G,E,C,D). The E dim carries the 'expert' logical axis -> EP.
    xe = jnp.take_along_axis(
        xf[:, None], slot_token[..., None], axis=2)      # (G,E,C,D)
    xe = xe * slot_valid[..., None].astype(xe.dtype)
    xe = constraint(xe, ("batch", "expert", None, None))

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, w["e_gate"])) \
        * jnp.einsum("gecd,edf->gecf", xe, w["e_up"])
    h = constraint(h, ("batch", "expert", None, "expert_mlp"))
    ye = jnp.einsum("gecf,efd->gecd", h, w["e_down"])
    ye = ye * slot_gate[..., None].astype(ye.dtype)

    # Combine: scatter-add back to token order (partial per EP shard; XLA
    # inserts the all-reduce over the expert/model axis).
    def combine(y_slots, tok):
        return jnp.zeros((n, D), y_slots.dtype).at[tok.reshape(-1)].add(
            y_slots.reshape(-1, D), mode="drop")

    y = jax.vmap(combine)(ye, slot_token).reshape(B, S, D)
    y = constraint(y, ("batch", "seq", "rep"))

    load = jnp.mean(jax.nn.one_hot(idx.reshape(-1), E, dtype=f32), axis=0)
    aux = {
        "aux_loss": aux_load_loss(probs, idx, E),
        "dropped_frac": jnp.sum(dropped).astype(f32) / (N * k),
        "load": load,
    }
    return y.astype(x.dtype), aux
