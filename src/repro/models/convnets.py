"""Convolutional vision backbones: ResNet-50/152, ConvNeXt-B, EfficientNet-B7.

A single *plan* (list of typed block specs, derived from the config) drives
both parameter-shape generation and the forward pass, so the two can never
diverge. Layout NHWC; BatchNorm runs in sync-BN semantics under SPMD (batch
statistics reduce over the sharded batch axis automatically).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.configs import VisionConfig
from repro.common.precision import parse_dtype
from repro.distributed.sharding import constraint
from repro.models import layers as L

f32 = jnp.float32


# ------------------------------------------------------------------ plan ---

def _round_filters(c: float, mult: float, divisor: int = 8) -> int:
    c *= mult
    new = max(divisor, int(c + divisor / 2) // divisor * divisor)
    if new < 0.9 * c:
        new += divisor
    return int(new)


def _round_repeats(r: int, mult: float) -> int:
    return int(math.ceil(r * mult))


_EFFNET_B0 = [  # (expand, channels, repeats, stride, kernel)
    (1, 16, 1, 1, 3), (6, 24, 2, 2, 3), (6, 40, 2, 2, 5), (6, 80, 3, 2, 3),
    (6, 112, 3, 1, 5), (6, 192, 4, 2, 5), (6, 320, 1, 1, 3),
]


def plan(cfg: VisionConfig) -> list[dict[str, Any]]:
    p: list[dict[str, Any]] = []
    if cfg.family == "resnet":
        w = cfg.width
        p.append({"t": "conv_bn", "k": 7, "s": 2, "cin": 3, "cout": w, "act": "relu"})
        p.append({"t": "maxpool", "k": 3, "s": 2})
        cin = w
        for si, depth in enumerate(cfg.depths):
            mid = w * (2 ** si)
            cout = mid * cfg.bottleneck
            for bi in range(depth):
                stride = 2 if (si > 0 and bi == 0) else 1
                p.append({"t": "resnet_block", "cin": cin, "mid": mid,
                          "cout": cout, "s": stride})
                cin = cout
        p.append({"t": "head", "cin": cin, "classes": cfg.n_classes})
    elif cfg.family == "convnext":
        dims = cfg.dims
        p.append({"t": "convnext_stem", "cin": 3, "cout": dims[0]})
        for si, depth in enumerate(cfg.depths):
            if si > 0:
                p.append({"t": "convnext_down", "cin": dims[si - 1],
                          "cout": dims[si]})
            for _ in range(depth):
                p.append({"t": "convnext_block", "dim": dims[si]})
        p.append({"t": "head", "cin": dims[-1], "classes": cfg.n_classes,
                  "pre_ln": True})
    elif cfg.family == "efficientnet":
        stem = _round_filters(32, cfg.width_mult)
        p.append({"t": "conv_bn", "k": 3, "s": 2, "cin": 3, "cout": stem,
                  "act": "silu"})
        cin = stem
        for (e, c, r, s, k) in _EFFNET_B0:
            cout = _round_filters(c, cfg.width_mult)
            for bi in range(_round_repeats(r, cfg.depth_mult)):
                stride = s if bi == 0 else 1
                p.append({"t": "mbconv", "cin": cin, "cout": cout,
                          "e": e, "k": k, "s": stride})
                cin = cout
        head_c = _round_filters(1280, cfg.width_mult)
        p.append({"t": "conv_bn", "k": 1, "s": 1, "cin": cin, "cout": head_c,
                  "act": "silu"})
        p.append({"t": "head", "cin": head_c, "classes": cfg.n_classes})
    else:
        raise ValueError(cfg.family)
    return p


# ------------------------------------------------------------ parameters ---

def _conv_spec(k, cin, cout, dt, depthwise=False):
    if depthwise:
        return L.sds((k, k, 1, cout), dt), (None, None, None, "channels")
    return L.sds((k, k, cin, cout), dt), (None, None, "channels_in", "channels")


def _bn_spec(c):
    return ({"scale": L.sds((c,), f32), "bias": L.sds((c,), f32)},
            {"scale": ("norm",), "bias": ("norm",)},
            {"mean": L.sds((c,), f32), "var": L.sds((c,), f32)})


def param_specs(cfg: VisionConfig):
    dt = parse_dtype(cfg.dtype)
    shapes: dict[str, Any] = {}
    logical: dict[str, Any] = {}
    state: dict[str, Any] = {}

    def add_bn(name, c):
        s, lg, st = _bn_spec(c)
        shapes[name], logical[name], state[name] = s, lg, st

    for i, b in enumerate(plan(cfg)):
        n = f"b{i}"
        t = b["t"]
        if t == "conv_bn":
            shapes[n + "/w"], logical[n + "/w"] = _conv_spec(
                b["k"], b["cin"], b["cout"], dt)
            add_bn(n + "/bn", b["cout"])
        elif t == "resnet_block":
            for j, (k, ci, co) in enumerate(
                    [(1, b["cin"], b["mid"]), (3, b["mid"], b["mid"]),
                     (1, b["mid"], b["cout"])]):
                shapes[f"{n}/w{j}"], logical[f"{n}/w{j}"] = _conv_spec(k, ci, co, dt)
                add_bn(f"{n}/bn{j}", co)
            if b["cin"] != b["cout"] or b["s"] > 1:
                shapes[n + "/wp"], logical[n + "/wp"] = _conv_spec(
                    1, b["cin"], b["cout"], dt)
                add_bn(n + "/bnp", b["cout"])
        elif t == "convnext_stem":
            shapes[n + "/w"], logical[n + "/w"] = _conv_spec(4, 3, b["cout"], dt)
            shapes[n + "/ln"] = {"scale": L.sds((b["cout"],), f32),
                                 "bias": L.sds((b["cout"],), f32)}
            logical[n + "/ln"] = {"scale": ("norm",), "bias": ("norm",)}
        elif t == "convnext_down":
            shapes[n + "/ln"] = {"scale": L.sds((b["cin"],), f32),
                                 "bias": L.sds((b["cin"],), f32)}
            logical[n + "/ln"] = {"scale": ("norm",), "bias": ("norm",)}
            shapes[n + "/w"], logical[n + "/w"] = _conv_spec(
                2, b["cin"], b["cout"], dt)
        elif t == "convnext_block":
            d = b["dim"]
            shapes[n + "/dw"], logical[n + "/dw"] = _conv_spec(7, d, d, dt, True)
            shapes[n + "/ln"] = {"scale": L.sds((d,), f32),
                                 "bias": L.sds((d,), f32)}
            logical[n + "/ln"] = {"scale": ("norm",), "bias": ("norm",)}
            shapes[n + "/pw1"] = L.sds((d, 4 * d), dt)
            logical[n + "/pw1"] = ("channels_in", "channels")
            shapes[n + "/pw2"] = L.sds((4 * d, d), dt)
            logical[n + "/pw2"] = ("channels", "channels_in")
            shapes[n + "/gamma"] = L.sds((d,), f32)
            logical[n + "/gamma"] = ("norm",)
        elif t == "mbconv":
            cin, cout, e, k = b["cin"], b["cout"], b["e"], b["k"]
            mid = cin * e
            if e != 1:
                shapes[n + "/we"], logical[n + "/we"] = _conv_spec(1, cin, mid, dt)
                add_bn(n + "/bne", mid)
            shapes[n + "/wd"], logical[n + "/wd"] = _conv_spec(k, mid, mid, dt, True)
            add_bn(n + "/bnd", mid)
            se = max(1, cin // 4)
            shapes[n + "/se1"], logical[n + "/se1"] = _conv_spec(1, mid, se, dt)
            shapes[n + "/se1b"] = L.sds((se,), f32)
            logical[n + "/se1b"] = ("norm",)
            shapes[n + "/se2"], logical[n + "/se2"] = _conv_spec(1, se, mid, dt)
            shapes[n + "/se2b"] = L.sds((mid,), f32)
            logical[n + "/se2b"] = ("norm",)
            shapes[n + "/wp"], logical[n + "/wp"] = _conv_spec(1, mid, cout, dt)
            add_bn(n + "/bnp", cout)
        elif t == "head":
            if b.get("pre_ln"):
                shapes[n + "/ln"] = {"scale": L.sds((b["cin"],), f32),
                                     "bias": L.sds((b["cin"],), f32)}
                logical[n + "/ln"] = {"scale": ("norm",), "bias": ("norm",)}
            shapes[n + "/w"] = L.sds((b["cin"], b["classes"]), dt)
            logical[n + "/w"] = ("channels_in", "classes")
            shapes[n + "/b"] = L.sds((b["classes"],), f32)
            logical[n + "/b"] = ("norm",)
        elif t == "maxpool":
            pass
        else:
            raise ValueError(t)
    return shapes, logical, state


def init_params(cfg: VisionConfig, rng):
    shapes, _, state = param_specs(cfg)
    params = L.init_tree(rng, shapes)
    # LayerScale gamma starts at 1e-6 (not zero); BN vars at 1.
    for k in params:
        if k.endswith("/gamma"):
            params[k] = jnp.full(params[k].shape, 1e-6, params[k].dtype)
    st = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), state)
    for k in st:
        st[k]["var"] = jnp.ones_like(st[k]["var"])
    return params, st


def count_params(cfg: VisionConfig) -> int:
    shapes, _, _ = param_specs(cfg)
    return sum(int(jnp.prod(jnp.array(s.shape))) for s in jax.tree.leaves(shapes))


# ---------------------------------------------------------------- forward --

def _conv(x, w, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def _bn(x, p, st, train: bool, momentum=0.9):
    """Returns (y, new_state). Batch stats reduce over (B,H,W) — sync-BN
    under SPMD since the batch axis is sharded."""
    xf = x.astype(f32)
    if train:
        mean = jnp.mean(xf, axis=(0, 1, 2))
        var = jnp.var(xf, axis=(0, 1, 2))
        new = {"mean": momentum * st["mean"] + (1 - momentum) * mean,
               "var": momentum * st["var"] + (1 - momentum) * var}
    else:
        mean, var = st["mean"], st["var"]
        new = st
    y = (xf - mean) * jax.lax.rsqrt(var + 1e-5)
    y = y * (1.0 + p["scale"]) + p["bias"]
    return y.astype(x.dtype), new


def _ln(x, p):
    return L.layernorm(x, p["scale"], p["bias"])


_ACT = {"relu": jax.nn.relu, "silu": jax.nn.silu, "gelu": jax.nn.gelu}


def forward(cfg: VisionConfig, params, state, images, train: bool = False):
    """images: (B,H,W,3) -> (logits (B,classes), new_state)."""
    x = images.astype(parse_dtype(cfg.dtype))
    new_state = dict(state)

    def bn(name, x):
        y, ns = _bn(x, params[name], state[name], train)
        new_state[name] = ns
        return y

    for i, b in enumerate(plan(cfg)):
        n = f"b{i}"
        t = b["t"]
        if t == "conv_bn":
            x = bn(n + "/bn", _conv(x, params[n + "/w"], b["s"]))
            x = _ACT[b["act"]](x)
        elif t == "maxpool":
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, b["k"], b["k"], 1),
                (1, b["s"], b["s"], 1), "SAME")
        elif t == "resnet_block":
            r = x
            y = jax.nn.relu(bn(n + "/bn0", _conv(x, params[n + "/w0"], 1)))
            y = jax.nn.relu(bn(n + "/bn1", _conv(y, params[n + "/w1"], b["s"])))
            y = bn(n + "/bn2", _conv(y, params[n + "/w2"], 1))
            if n + "/wp" in params:
                r = bn(n + "/bnp", _conv(r, params[n + "/wp"], b["s"]))
            x = jax.nn.relu(y + r)
            x = constraint(x, ("batch", None, None, None))
        elif t == "convnext_stem":
            x = jax.lax.conv_general_dilated(
                x, params[n + "/w"].astype(x.dtype), (4, 4), "VALID",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = _ln(x, params[n + "/ln"])
        elif t == "convnext_down":
            x = _ln(x, params[n + "/ln"])
            x = jax.lax.conv_general_dilated(
                x, params[n + "/w"].astype(x.dtype), (2, 2), "VALID",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
        elif t == "convnext_block":
            r = x
            x = _conv(x, params[n + "/dw"], 1, groups=b["dim"])
            x = _ln(x, params[n + "/ln"])
            x = jax.nn.gelu(x @ params[n + "/pw1"].astype(x.dtype))
            x = x @ params[n + "/pw2"].astype(x.dtype)
            x = r + x * params[n + "/gamma"].astype(x.dtype)
            x = constraint(x, ("batch", None, None, None))
        elif t == "mbconv":
            r = x
            mid_in = x
            if n + "/we" in params:
                mid_in = jax.nn.silu(bn(n + "/bne", _conv(x, params[n + "/we"])))
            y = jax.nn.silu(bn(n + "/bnd", _conv(
                mid_in, params[n + "/wd"], b["s"], groups=mid_in.shape[-1])))
            # squeeze-excite
            se = jnp.mean(y.astype(f32), axis=(1, 2), keepdims=True).astype(y.dtype)
            se = jax.nn.silu(_conv(se, params[n + "/se1"])
                             + params[n + "/se1b"].astype(y.dtype))
            se = jax.nn.sigmoid(_conv(se, params[n + "/se2"])
                                + params[n + "/se2b"].astype(y.dtype))
            y = y * se
            y = bn(n + "/bnp", _conv(y, params[n + "/wp"]))
            if b["s"] == 1 and b["cin"] == b["cout"]:
                y = y + r
            x = constraint(y, ("batch", None, None, None))
        elif t == "head":
            x = jnp.mean(x.astype(f32), axis=(1, 2))
            if b.get("pre_ln"):
                x = L.layernorm(x, params[n + "/ln"]["scale"],
                                params[n + "/ln"]["bias"])
            x = x.astype(params[n + "/w"].dtype)
            x = x @ params[n + "/w"] + params[n + "/b"].astype(x.dtype)
    return x.astype(f32), new_state


def xent_loss(cfg: VisionConfig, params, state, batch, train=True):
    logits, new_state = forward(cfg, params, state, batch["images"], train)
    lp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(lp, batch["labels"][:, None], axis=-1)
    loss = jnp.mean(nll)
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(f32))
    return loss, ({"xent": loss, "acc": acc}, new_state)
