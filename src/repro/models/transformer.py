"""Decoder-only LM (dense or MoE) with scan-over-layers.

Weights are stacked along a leading layer dim so the whole stack lowers to a
single ``lax.scan`` body -- compile time and HLO size stay O(1) in depth,
which is what makes 512-device dry-runs of 480B-parameter configs tractable.

Exposes:
  * param_specs(cfg)          -> (shapes, logical) trees
  * init_params(cfg, rng)     -> real params (reduced/smoke configs only)
  * forward(cfg, params, tokens, ...)            -> logits (chunked-vocab safe)
  * loss_and_metrics(cfg, params, batch, ...)    -> scalar loss, metrics
  * prefill / decode step builders with stacked KV caches.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.common import flags
from repro.common.configs import LMConfig
from repro.common.precision import parse_dtype
from repro.distributed.sharding import constraint
from repro.models import layers as L
from repro.models import moe as MOE

f32 = jnp.float32


# ------------------------------------------------------------ parameters ---

def param_specs(cfg: LMConfig):
    dt = parse_dtype(cfg.dtype)
    Ln, D, H, KV, hd = cfg.n_layers, cfg.d_model, cfg.n_heads, \
        cfg.n_kv_heads, cfg.hd
    shapes: dict[str, Any] = {
        "embed": L.sds((cfg.vocab_size, D), dt),
        "final_norm": L.sds((D,), f32),
        "layers": {
            "attn": {
                "norm": L.sds((Ln, D), f32),
                "wq": L.sds((Ln, D, H * hd), dt),
                "wk": L.sds((Ln, D, KV * hd), dt),
                "wv": L.sds((Ln, D, KV * hd), dt),
                "wo": L.sds((Ln, H * hd, D), dt),
            },
        },
    }
    logical: dict[str, Any] = {
        "embed": ("vocab", "embed"),
        "final_norm": ("norm",),
        "layers": {
            "attn": {
                "norm": ("layer", "norm"),
                "wq": ("layer", "embed", "heads"),
                "wk": ("layer", "embed", "kv_heads"),
                "wv": ("layer", "embed", "kv_heads"),
                "wo": ("layer", "heads", "embed"),
            },
        },
    }
    if cfg.norm == "layernorm":
        shapes["layers"]["attn"]["norm_bias"] = L.sds((Ln, D), f32)
        logical["layers"]["attn"]["norm_bias"] = ("layer", "norm")

    mlp_shapes: dict[str, Any] = {"norm": L.sds((Ln, D), f32)}
    mlp_logical: dict[str, Any] = {"norm": ("layer", "norm")}
    if cfg.norm == "layernorm":
        mlp_shapes["norm_bias"] = L.sds((Ln, D), f32)
        mlp_logical["norm_bias"] = ("layer", "norm")

    dense_ff = 0
    if not cfg.moe:
        dense_ff = cfg.d_ff
    else:
        if cfg.n_shared_experts:
            dense_ff = cfg.n_shared_experts * cfg.d_exp
        if cfg.moe_dense_residual:
            dense_ff = cfg.d_ff
    if dense_ff:
        mlp_shapes.update({
            "w_gate": L.sds((Ln, D, dense_ff), dt),
            "w_up": L.sds((Ln, D, dense_ff), dt),
            "w_down": L.sds((Ln, dense_ff, D), dt),
        })
        mlp_logical.update({
            "w_gate": ("layer", "embed", "mlp"),
            "w_up": ("layer", "embed", "mlp"),
            "w_down": ("layer", "mlp", "embed"),
        })
    if cfg.moe:
        e_shapes, e_logical = MOE.expert_specs(cfg, dt)
        mlp_shapes["moe"] = e_shapes
        mlp_logical["moe"] = e_logical
    shapes["layers"]["mlp"] = mlp_shapes
    logical["layers"]["mlp"] = mlp_logical

    if not cfg.tie_embeddings:
        shapes["lm_head"] = L.sds((cfg.vocab_size, D), dt)
        logical["lm_head"] = ("vocab", "embed")
    return shapes, logical


def init_params(cfg: LMConfig, rng):
    shapes, _ = param_specs(cfg)
    return L.init_tree(rng, shapes)


def abstract_params(cfg: LMConfig):
    return param_specs(cfg)[0]


# --------------------------------------------------------------- forward ---

def _layer_body(cfg: LMConfig, num_groups: int, attn_impl: str,
                x, w, positions, cache, cache_pos):
    """One transformer layer. cache: dict or None."""
    attn_out, new_cache = L.attention_block(
        x, w["attn"], cfg, positions=positions, causal=True,
        cache=cache, cache_pos=cache_pos, attn_impl=attn_impl)
    x = x + attn_out

    wm = w["mlp"]
    xn = L.norm_apply(cfg.norm, x, wm["norm"], wm.get("norm_bias"))
    aux = None
    if cfg.moe:
        y, aux = MOE.moe_ffn(xn, wm["moe"], cfg, num_groups=num_groups)
        if "w_gate" in wm:           # shared experts / Arctic dense residual
            y = y + L.swiglu(xn, wm)
    else:
        y = L.swiglu(xn, wm)
    x = x + y
    # pin the residual replicated on non-batch dims: remat saves it across
    # the fwd/bwd boundary, and unconstrained specs let SPMD re-shard it
    # pathologically across pods (EXPERIMENTS.md §Perf it.1)
    x = constraint(x, ("batch", "seq", "rep"))
    return x, new_cache, aux


def forward(cfg: LMConfig, params, tokens, *, positions=None,
            num_groups: int = 1, attn_impl: str = "auto",
            remat: str = "none", caches=None, cache_pos=None,
            return_hidden: bool = False):
    """tokens: (B,S) -> logits (B,S,V) [or hidden (B,S,D)].

    ``caches``: stacked (L, B, Smax, KV, hd) k/v arrays for serving; returns
    (out, new_caches) when provided.
    """
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constraint(x, ("batch", "seq", "rep"))
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :] + (
            0 if cache_pos is None else cache_pos)
        positions = jnp.broadcast_to(positions, (B, S))

    serving = caches is not None

    quant = serving and "k_scale" in caches

    def body(carry, wl):
        x = carry
        if serving:
            if quant:
                w, ck, cv, cks, cvs = wl
                cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
            else:
                w, ck, cv = wl
                cache = {"k": ck, "v": cv}
        else:
            w, cache = wl, None
        x, new_cache, aux = _layer_body(
            cfg, num_groups, attn_impl, x, w, positions, cache, cache_pos)
        if serving:
            ys = tuple(new_cache[f] for f in
                       (("k", "v", "k_scale", "v_scale") if quant
                        else ("k", "v")))
        else:
            ys = aux["aux_loss"] if (cfg.moe and aux is not None) else None
        return x, ys

    if remat != "none" and not serving:
        policy = None
        if remat == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        body = jax.checkpoint(body, policy=policy)

    if serving:
        xs = (params["layers"], caches["k"], caches["v"]) + (
            (caches["k_scale"], caches["v_scale"]) if quant else ())
    else:
        xs = params["layers"]
    x, ys = jax.lax.scan(body, x, xs,
                         unroll=flags.layer_unroll("layers"))

    x = L.rmsnorm(x, params["final_norm"]) if cfg.norm == "rmsnorm" \
        else L.layernorm(x, params["final_norm"])
    if return_hidden:
        out = x
    else:
        head = params.get("lm_head", params["embed"])
        out = x @ head.T.astype(x.dtype)
        out = constraint(out, ("batch", "seq", "vocab"))
    if serving:
        names = ("k", "v", "k_scale", "v_scale") if quant else ("k", "v")
        return out, dict(zip(names, ys))
    aux_loss = jnp.mean(ys) if (cfg.moe and ys is not None) else jnp.zeros((), f32)
    return out, aux_loss


# ------------------------------------------------------------------ loss ---

def chunked_xent(cfg: LMConfig, params, hidden, labels, *, chunk: int = 1024,
                 label_smoothing: float = 0.0):
    """Cross-entropy over a vocab-sharded head without materialising the full
    fp32 (B,S,V) logits: scan over sequence chunks."""
    B, S, D = hidden.shape
    V = cfg.vocab_size
    head = params.get("lm_head", params["embed"])
    chunk = min(chunk, S)
    n = S // chunk
    assert S % chunk == 0, (S, chunk)
    hc = hidden.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    def step(tot, xs):
        h, lbl = xs
        logits = (h @ head.T.astype(h.dtype)).astype(f32)
        logits = constraint(logits, ("batch", "seq", "vocab"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        oh = jax.nn.one_hot(lbl, V, dtype=logits.dtype)
        true_logit = jnp.sum(logits * oh, axis=-1)
        nll = lse - true_logit
        if label_smoothing:
            nll = (1 - label_smoothing) * nll + label_smoothing * (
                lse - jnp.mean(logits, axis=-1))
        return tot + jnp.sum(nll), None

    tot, _ = jax.lax.scan(step, jnp.zeros((), f32), (hc, lc),
                          unroll=flags.scan_unroll(n))
    return tot / (B * S)


def loss_and_metrics(cfg: LMConfig, params, batch, *, num_groups=1,
                     remat="none", aux_weight=0.01, label_smoothing=0.0):
    hidden, aux_loss = forward(
        cfg, params, batch["tokens"], num_groups=num_groups, remat=remat,
        return_hidden=True)
    xent = chunked_xent(cfg, params, hidden, batch["labels"],
                        label_smoothing=label_smoothing)
    loss = xent + aux_weight * aux_loss
    return loss, {"xent": xent, "aux_loss": aux_loss}


# --------------------------------------------------------------- serving ---

def cache_specs(cfg: LMConfig, batch: int, max_seq: int, dtype=None):
    if dtype is None:
        dtype = jnp.int8 if cfg.kv_cache_dtype == "int8" else jnp.bfloat16
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    lg = ("layer", "batch", "seq_kv", "kv_heads", None)
    shapes = {"k": L.sds(shape, dtype), "v": L.sds(shape, dtype)}
    logical = {"k": lg, "v": lg}
    if dtype == jnp.int8:   # per-(position, kv-head) fp32 scales (~3% extra)
        sshape = shape[:-1] + (1,)
        shapes["k_scale"] = L.sds(sshape, f32)
        shapes["v_scale"] = L.sds(sshape, f32)
        logical["k_scale"] = lg
        logical["v_scale"] = lg
    return shapes, logical


def init_cache(cfg: LMConfig, batch: int, max_seq: int, dtype=None):
    shapes, _ = cache_specs(cfg, batch, max_seq, dtype)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def prefill(cfg: LMConfig, params, tokens, caches, *, num_groups=1,
            attn_impl: str = "auto"):
    """Run the prompt through the model, filling ``caches`` from position 0.
    Returns (last-token logits, caches). Only the final position goes
    through the LM head — materialising (B,S,V) logits at a 32k prompt
    would cost 100s of GB/device."""
    hidden, caches = forward(cfg, params, tokens, caches=caches, cache_pos=0,
                             num_groups=num_groups, attn_impl=attn_impl,
                             return_hidden=True)
    head = params.get("lm_head", params["embed"])
    logits = hidden[:, -1] @ head.T.astype(hidden.dtype)
    logits = constraint(logits, ("batch", "vocab"))
    return logits, caches


def decode_step(cfg: LMConfig, params, token, caches, pos, *, num_groups=1):
    """One decode step: token (B,1) against caches filled up to ``pos``."""
    out, caches = forward(cfg, params, token, caches=caches, cache_pos=pos,
                          num_groups=num_groups)
    return out[:, -1], caches
