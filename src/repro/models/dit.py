"""DiT (Diffusion Transformer, adaLN-zero) — arXiv:2212.09748.

Operates on VAE latents (the VAE is a stubbed frontend: ``input_specs``
provides latents directly, as is standard for systems benchmarking of DiT).
Scan-over-layers with stacked block weights.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.common import flags
from repro.common.configs import DiTConfig
from repro.common.precision import parse_dtype
from repro.distributed.sharding import constraint
from repro.models import layers as L

f32 = jnp.float32


def timestep_embedding(t, dim: int, max_period: float = 10_000.0):
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=f32) / half)
    args = t.astype(f32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def param_specs(cfg: DiTConfig):
    dt = parse_dtype(cfg.dtype)
    Ln, D = cfg.n_layers, cfg.d_model
    pdim = cfg.in_channels * cfg.patch ** 2
    shapes: dict[str, Any] = {
        "patch_w": L.sds((pdim, D), dt),
        "patch_b": L.sds((D,), f32),
        "t_mlp1": L.sds((256, D), dt),
        "t_mlp2": L.sds((D, D), dt),
        "y_embed": L.sds((cfg.n_classes + 1, D), dt),
        "blocks": {
            "adaln": L.sds((Ln, D, 6 * D), dt),
            "adaln_b": L.sds((Ln, 6 * D), f32),
            "wqkv": L.sds((Ln, D, 3 * D), dt),
            "wo": L.sds((Ln, D, D), dt),
            "mlp_in": L.sds((Ln, D, 4 * D), dt),
            "mlp_out": L.sds((Ln, 4 * D, D), dt),
        },
        "final_adaln": L.sds((D, 2 * D), dt),
        "final_w": L.sds((D, pdim * 2), dt),
    }
    logical: dict[str, Any] = {
        "patch_w": (None, "embed"),
        "patch_b": ("norm",),
        "t_mlp1": (None, "embed"),
        "t_mlp2": ("embed_nofsdp", "embed"),
        "y_embed": ("classes", "embed"),
        "blocks": {
            "adaln": ("layer", "embed", "mlp"),
            "adaln_b": ("layer", "mlp"),
            "wqkv": ("layer", "embed", "heads"),
            "wo": ("layer", "heads", "embed"),
            "mlp_in": ("layer", "embed", "mlp"),
            "mlp_out": ("layer", "mlp", "embed"),
        },
        "final_adaln": ("embed_nofsdp", "mlp"),
        "final_w": ("embed", None),
    }
    return shapes, logical


def init_params(cfg: DiTConfig, rng):
    return L.init_tree(rng, param_specs(cfg)[0])


def patchify(x, patch: int):
    """(B,H,W,C) -> (B, N, patch*patch*C)."""
    b, h, w, c = x.shape
    x = x.reshape(b, h // patch, patch, w // patch, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, (h // patch) * (w // patch), patch * patch * c)


def unpatchify(x, patch: int, res: int, c: int):
    b, n, _ = x.shape
    g = res // patch
    x = x.reshape(b, g, g, patch, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, res, res, c)


def _modulate(x, shift, scale):
    return x * (1.0 + scale[:, None]) + shift[:, None]


def forward(cfg: DiTConfig, params, latents, t, y):
    """latents: (B,Hl,Wl,C) noisy latents; t: (B,) timesteps; y: (B,) labels.
    Returns (B,Hl,Wl,2C) [noise prediction, sigma]."""
    b, hl, wl, c = latents.shape
    x = patchify(latents.astype(params["patch_w"].dtype), cfg.patch)
    x = x @ params["patch_w"] + params["patch_b"].astype(x.dtype)
    n, d = x.shape[1], x.shape[2]
    # fixed sincos position embedding
    pos = jnp.arange(n, dtype=f32)
    pe = timestep_embedding(pos, d)[None].astype(x.dtype)
    x = x + pe
    x = constraint(x, ("batch", "seq", None))

    temb = timestep_embedding(t, 256) @ params["t_mlp1"].astype(f32)
    temb = jax.nn.silu(temb) @ params["t_mlp2"].astype(f32)
    cond = temb + params["y_embed"][y].astype(f32)          # (B,D)
    cond_act = jax.nn.silu(cond)

    nh = cfg.n_heads
    hd = d // nh

    def block(x, w):
        mod = (cond_act @ w["adaln"].astype(f32) + w["adaln_b"]).astype(x.dtype)
        sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
        xn = L.layernorm(x, jnp.zeros((d,), f32))
        xn = _modulate(xn, sh1, sc1)
        qkv = xn @ w["wqkv"]
        q, k, v = jnp.split(qkv.reshape(b, n, 3 * nh, hd), 3, axis=2)
        o = L.mha(q, k, v, causal=False)
        x = x + g1[:, None] * (o.reshape(b, n, d) @ w["wo"])
        xn = L.layernorm(x, jnp.zeros((d,), f32))
        xn = _modulate(xn, sh2, sc2)
        h = jax.nn.gelu(xn @ w["mlp_in"])
        x = x + g2[:, None] * (h @ w["mlp_out"])
        x = constraint(x, ("batch", "rep", "rep"))
        return x, None

    x, _ = jax.lax.scan(block, x, params["blocks"],
                        unroll=flags.layer_unroll("layers"))

    mod = (cond_act @ params["final_adaln"].astype(f32)).astype(x.dtype)
    sh, sc = jnp.split(mod, 2, axis=-1)
    x = _modulate(L.layernorm(x, jnp.zeros((d,), f32)), sh, sc)
    out = x @ params["final_w"]
    return unpatchify(out, cfg.patch, hl, 2 * c)


# ---------------------------------------------------------------- losses ---

def ddpm_alphas(T: int = 1000):
    """Cosine schedule (Nichol & Dhariwal)."""
    s = 0.008
    ts = jnp.arange(T + 1, dtype=f32) / T
    f = jnp.cos((ts + s) / (1 + s) * math.pi / 2) ** 2
    abar = f / f[0]
    return abar  # (T+1,)


def diffusion_loss(cfg: DiTConfig, params, batch):
    """batch: latents (B,H,W,C) clean, y (B,), t (B,) int, noise (B,H,W,C)."""
    lat, y, t, eps = batch["latents"], batch["labels"], batch["t"], batch["noise"]
    abar = ddpm_alphas()[t][:, None, None, None]
    xt = jnp.sqrt(abar) * lat.astype(f32) + jnp.sqrt(1 - abar) * eps.astype(f32)
    pred = forward(cfg, params, xt.astype(lat.dtype), t, y).astype(f32)
    eps_pred = pred[..., : lat.shape[-1]]
    loss = jnp.mean(jnp.square(eps_pred - eps.astype(f32)))
    return loss, {"mse": loss}


def sample_step(cfg: DiTConfig, params, xt, t, t_prev, y):
    """One DDIM step (eta=0). All shapes static; the sampler loop is
    ``steps`` sequential calls (this is what the gen_* cells lower)."""
    abar = ddpm_alphas()
    a_t = abar[t][:, None, None, None]
    a_p = abar[t_prev][:, None, None, None]
    pred = forward(cfg, params, xt, t, y).astype(f32)
    eps = pred[..., : xt.shape[-1]]
    x0 = (xt.astype(f32) - jnp.sqrt(1 - a_t) * eps) / jnp.sqrt(a_t)
    x_prev = jnp.sqrt(a_p) * x0 + jnp.sqrt(1 - a_p) * eps
    return x_prev.astype(xt.dtype)
