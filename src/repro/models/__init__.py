"""Model zoo: LM transformers (dense + MoE), diffusion (DiT / MMDiT),
and convolutional vision backbones, all as pure-functional JAX modules with
logical-axis sharding annotations."""
