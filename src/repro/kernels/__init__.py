"""Pallas TPU kernels for the performance-critical compute layers:

  flash_attention/  block-tiled causal attention (prefill cells)
  decode_attention/ split-K KV-cache decode with LSE combine (decode cells)
  moscore/          fused two-stage balancer window scan (the paper's
                    Algorithm 1, queue vector resident in VMEM)

Each kernel ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jitted
wrapper with interpret fallback) and ref.py (pure-jnp oracle); tests sweep
shapes/dtypes and assert_allclose against the oracle.
"""
