"""Block-tiled causal flash attention (forward) as a Pallas TPU kernel.

Tiling (v5e): grid = (B*H, Sq/BQ); each program streams the K/V sequence in
BK-sized chunks held in VMEM, maintaining the running max / sum / accumulator
of the online-softmax recurrence in fp32. Causal programs skip KV blocks
entirely above the diagonal, so the causal kernel does ~half the work of the
full one (the roofline win for the 32k prefill cells).

VMEM budget per program (BQ=128, BK=512, D=128, bf16):
  q 32 KiB + k/v 2x128 KiB + acc/m/l fp32 ~ 66 KiB  << 128 MiB/core.
MXU alignment: BQ, BK, D all multiples of 128 (D padded by ops.py if needed).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
               block_k: int, seq_k: int):
    # q_ref: (BQ, D); k_ref/v_ref: (Sk, D); o_ref: (BQ, D)
    bq, d = q_ref.shape
    q = q_ref[...].astype(jnp.float32) * scale
    q_offset = pl.program_id(1) * bq

    nk = seq_k // block_k

    def body(i, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (pl.ds(i * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.ds(i * block_k, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T                      # (BQ, BK)
        if causal:
            qpos = q_offset + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
            kpos = i * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)

    if causal:
        # only blocks with k_start <= q_end participate
        last = (q_offset + bq + block_k - 1) // block_k
        n_blocks = jnp.minimum(last, nk)
    else:
        n_blocks = nk
    acc, m, l = jax.lax.fori_loop(0, n_blocks, body, (acc0, m0, l0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool, scale: float,
                         block_q: int = 128, block_k: int = 512,
                         interpret: bool = True):
    """q: (BH, Sq, D); k/v: (BH, Sk, D) (kv heads already broadcast)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)

    kernel = functools.partial(_fa_kernel, scale=scale, causal=causal,
                               block_k=block_k, seq_k=sk)
    return pl.pallas_call(
        kernel,
        grid=(bh, sq // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, sk, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, sk, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
