"""Pure-jnp oracle for flash attention (GQA, causal or full)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_attention(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D). fp32 softmax, output in q.dtype."""
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qg = q.reshape(b, sq, kv, g, d).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d).astype(q.dtype)
