"""Jitted public wrapper: GQA layout handling, head broadcast, padding."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 512, interpret: bool = True):
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D) -> (B, Sq, H, D).

    ``interpret=True`` executes the kernel body in Python on CPU (the only
    mode available in this container); on real TPUs pass interpret=False.
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = 1.0 / (d ** 0.5)

    # broadcast kv heads to q heads, fold heads into batch
    kb = jnp.repeat(k, g, axis=2)
    vb = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = kb.transpose(0, 2, 1, 3).reshape(b * h, sk, d)
    vf = vb.transpose(0, 2, 1, 3).reshape(b * h, sk, d)

    o = flash_attention_bhsd(qf, kf, vf, causal=causal, scale=scale,
                             block_q=block_q, block_k=block_k,
                             interpret=interpret)
    return o.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
