"""Jitted wrapper: lane padding, transposition, unpadding — and the
backend-aware dispatch between the Pallas kernel and the XLA reference.

Both backends implement the same contract bit-for-bit (the kernel tests
assert it), so callers pick purely on speed: the Pallas kernel wins where
it compiles natively (TPU); everywhere else it runs in interpret mode and
*loses* to the XLA ``lax.scan`` reference (~0.3x on CPU — the ``kernels``
bench suite tracks the ratio). ``backend="auto"`` — what the serving
gateway's hot path uses — resolves that choice per platform.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.moscore.moscore import moscore_pallas
from repro.kernels.moscore.ref import ref_moscore_route

BIG = 1e30

BACKENDS = ("pallas", "xla", "auto")


def default_backend() -> str:
    """The fastest correct routing backend for this process' platform:
    the compiled Pallas kernel on TPU, the XLA reference scan elsewhere
    (where Pallas would fall back to interpret mode)."""
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def resolve_backend(backend: str) -> str:
    """Normalize a backend spec to a concrete one (``"auto"`` picks per
    platform via :func:`default_backend`)."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown moscore backend {backend!r}; one of "
                         f"{BACKENDS}")
    return default_backend() if backend == "auto" else backend


@functools.partial(jax.jit, static_argnames=("delta", "gamma", "interpret"))
def _pallas_route(T, E, mAP, gs, q0, *, delta: float, gamma: float,
                  interpret: bool):
    P, G = T.shape
    Pp = (P + 127) // 128 * 128
    padP = Pp - P

    def pad(x, fill):
        return jnp.pad(x.astype(jnp.float32), ((0, padP), (0, 0)),
                       constant_values=fill)

    Tt = pad(T, BIG).T
    Et = pad(E, BIG).T
    Mt = pad(mAP, -BIG).T          # padded pairs can never be feasible
    q0p = jnp.pad(q0.astype(jnp.float32), (0, padP))[None, :]
    gsc = gs.astype(jnp.int32)[:, None]

    choices, qf = moscore_pallas(Tt, Et, Mt, gsc, q0p, delta=delta,
                                 gamma=gamma, interpret=interpret)
    return choices[:, 0], qf[0, :P]


_xla_route = jax.jit(ref_moscore_route, static_argnames=("delta", "gamma"))


def moscore_route(T, E, mAP, gs, q0, *, delta: float = 20.0,
                  gamma: float = 0.5, interpret: bool = True,
                  backend: str = "pallas"):
    """Route a window of requests with queue feedback.

    T/E/mAP: (P, G) profile tables; gs: (W,) int32 estimated groups;
    q0: (P,) queue depths. Returns (choices (W,), q_final (P,)).

    ``backend`` selects the implementation: ``"pallas"`` (default — the
    fused kernel, in interpret mode unless ``interpret=False``),
    ``"xla"`` (the ``lax.scan`` reference, jitted), or ``"auto"``
    (:func:`default_backend` — Pallas compiled on TPU, XLA elsewhere).
    All backends return bit-identical choices; safe to call under an
    outer ``jit``."""
    backend = resolve_backend(backend)
    if backend == "xla":
        return _xla_route(T, E, mAP, gs, q0, delta=delta, gamma=gamma)
    if backend == "pallas" and jax.default_backend() == "tpu":
        interpret = False
    return _pallas_route(T, E, mAP, gs, q0, delta=delta, gamma=gamma,
                         interpret=interpret)
