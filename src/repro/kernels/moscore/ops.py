"""Jitted wrapper: lane padding, transposition, unpadding."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.moscore.moscore import moscore_pallas

BIG = 1e30


@functools.partial(jax.jit, static_argnames=("delta", "gamma", "interpret"))
def moscore_route(T, E, mAP, gs, q0, *, delta: float = 20.0,
                  gamma: float = 0.5, interpret: bool = True):
    """Route a window of requests with queue feedback.

    T/E/mAP: (P, G) profile tables; gs: (W,) int32 estimated groups;
    q0: (P,) queue depths. Returns (choices (W,), q_final (P,))."""
    P, G = T.shape
    Pp = (P + 127) // 128 * 128
    padP = Pp - P

    def pad(x, fill):
        return jnp.pad(x.astype(jnp.float32), ((0, padP), (0, 0)),
                       constant_values=fill)

    Tt = pad(T, BIG).T
    Et = pad(E, BIG).T
    Mt = pad(mAP, -BIG).T          # padded pairs can never be feasible
    q0p = jnp.pad(q0.astype(jnp.float32), (0, padP))[None, :]
    gsc = gs.astype(jnp.int32)[:, None]

    choices, qf = moscore_pallas(Tt, Et, Mt, gsc, q0p, delta=delta,
                                 gamma=gamma, interpret=interpret)
    return choices[:, 0], qf[0, :P]
