"""Jitted wrapper: lane padding, transposition, unpadding — and the
backend-aware dispatch between the moscore implementations.

Five concrete backends share one contract (``(T, E, mAP, gs, q0) ->
(choices, q_final)``), split across two accuracy classes:

bit-identical fp32 routing (interchangeable, asserted by the kernel
tests):

  * ``"xla"`` — the ``lax.scan`` reference (``core.policies
    .mo_select_batch``), every Algorithm-1 term recomputed per request;
  * ``"pallas"`` — the original fused kernel (same per-request work, one
    kernel launch);
  * ``"hoisted"`` — the invariant-hoisted XLA scan
    (``mo_select_batch_hoisted``): the queue-independent terms
    (feasibility mask, e_min/e_max, normalised energy) precomputed once
    per table, only the latency normalisation + argmin left in the scan;
  * ``"pallas_hoisted"`` — the hoisted Pallas kernel (same precompute,
    fused scan in VMEM).

bounded-error int8 routing:

  * ``"int8"`` — quantize the tables to int8 with per-group-column
    scales (``core.quant.QuantProfileTable``), dequantize, route via the
    hoisted scan. NOT bit-identical: decisions carry a bounded mismatch
    rate vs fp32 (tested in ``tests/test_quant_route.py``).

``backend="auto"`` — what the serving gateway's hot path uses — resolves
per platform: the compiled hoisted Pallas kernel on TPU, the hoisted XLA
scan elsewhere (where Pallas falls back to interpret mode and loses by
~3x). The ``REPRO_MOSCORE_BACKEND`` environment variable overrides the
``auto`` choice process-wide (ops experiments, A/B-ing int8 on a live
gateway) without touching call sites; explicit ``backend=`` arguments
always win over the env. The ``kernels`` bench suite tracks every
backend's speedup vs the ``"xla"`` reference.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.policies import mo_precompute, mo_select_batch_hoisted
from repro.core.profiles import ProfileTable
from repro.core.quant import quantize_roundtrip
from repro.kernels.moscore.moscore import moscore_hoisted_pallas, \
    moscore_pallas
from repro.kernels.moscore.ref import ref_moscore_route

BIG = 1e30

BACKENDS = ("pallas", "xla", "hoisted", "pallas_hoisted", "int8", "auto")

#: environment override for ``backend="auto"`` (see :func:`resolve_backend`)
BACKEND_ENV = "REPRO_MOSCORE_BACKEND"


def default_backend() -> str:
    """The fastest correct routing backend for this process' platform:
    the compiled hoisted Pallas kernel on TPU, the hoisted XLA scan
    elsewhere (both bit-identical to the reference)."""
    return "pallas_hoisted" if jax.default_backend() == "tpu" else "hoisted"


def resolve_backend(backend: str) -> str:
    """Normalize a backend spec to a concrete one. ``"auto"`` consults
    the ``REPRO_MOSCORE_BACKEND`` environment variable first (a concrete
    backend name — ``auto`` itself is rejected to avoid a resolution
    loop), then falls back to the per-platform :func:`default_backend`.
    Explicit backends pass through untouched — the env only steers
    callers that left the choice open."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown moscore backend {backend!r}; one of "
                         f"{BACKENDS}")
    if backend != "auto":
        return backend
    env = os.environ.get(BACKEND_ENV, "").strip()
    if env:
        if env not in BACKENDS or env == "auto":
            raise ValueError(
                f"{BACKEND_ENV}={env!r} is not a concrete moscore backend; "
                f"one of {tuple(b for b in BACKENDS if b != 'auto')}")
        return env
    return default_backend()


def _pad_transpose(T, E, mAP, gs, q0):
    """Lane-pad the (P, G) tables to P' (multiple of 128), transpose to
    (G, P') and shape gs/q0 for the 2-D kernels. Padded pairs get
    T=+BIG / mAP=-BIG so they are never feasible."""
    P, G = T.shape
    Pp = (P + 127) // 128 * 128
    padP = Pp - P

    def pad(x, fill):
        return jnp.pad(x.astype(jnp.float32), ((0, padP), (0, 0)),
                       constant_values=fill)

    q0p = jnp.pad(q0.astype(jnp.float32), (0, padP))[None, :]
    gsc = gs.astype(jnp.int32)[:, None]
    return pad(T, BIG).T, pad(E, BIG).T, pad(mAP, -BIG).T, gsc, q0p, P


@functools.partial(jax.jit, static_argnames=("delta", "gamma", "interpret"))
def _pallas_route(T, E, mAP, gs, q0, *, delta: float, gamma: float,
                  interpret: bool):
    Tt, Et, Mt, gsc, q0p, P = _pad_transpose(T, E, mAP, gs, q0)
    choices, qf = moscore_pallas(Tt, Et, Mt, gsc, q0p, delta=delta,
                                 gamma=gamma, interpret=interpret)
    return choices[:, 0], qf[0, :P]


@functools.partial(jax.jit, static_argnames=("delta", "gamma", "interpret"))
def _pallas_hoisted_route(T, E, mAP, gs, q0, *, delta: float, gamma: float,
                          interpret: bool, health=None):
    # the queue-independent precompute runs OUTSIDE the kernel, on the
    # unpadded tables — identical reductions to the XLA hoisted path, so
    # the kernel sees the exact same (G, P) constants; the fault plane's
    # health mask folds in here too (it is queue-independent), so the
    # kernel body needs no mask plumbing at all
    feasible, E_n = mo_precompute(T.astype(jnp.float32),
                                  E.astype(jnp.float32),
                                  mAP.astype(jnp.float32), delta=delta,
                                  health=health)
    Tt, Ent, Ft, gsc, q0p, P = _pad_transpose(
        T, E_n, feasible.astype(jnp.float32), gs, q0)
    # _pad_transpose pads E_n with +BIG and the mask with -BIG; the mask
    # just needs "not feasible" (<= 0) on padded pairs, which -BIG is,
    # and masked E_n values are never read
    choices, qf = moscore_hoisted_pallas(Tt, Ent, Ft, gsc, q0p,
                                         gamma=gamma, interpret=interpret)
    return choices[:, 0], qf[0, :P]


_xla_route = jax.jit(ref_moscore_route, static_argnames=("delta", "gamma"))


@functools.partial(jax.jit, static_argnames=("delta", "gamma"))
def _hoisted_route(T, E, mAP, gs, q0, *, delta: float, gamma: float,
                   health=None):
    ps, q = mo_select_batch_hoisted(ProfileTable(T, E, mAP), gs, q0,
                                    delta=delta, gamma=gamma,
                                    health=health)
    return ps.astype(jnp.int32), q


@functools.partial(jax.jit, static_argnames=("delta", "gamma"))
def _int8_route(T, E, mAP, gs, q0, *, delta: float, gamma: float,
                health=None):
    # quantize -> dequantize -> hoisted scan: the int8 grid is what both
    # CPU and TPU score against, so the quantisation error is identical
    # across platforms by construction. The health mask applies to the
    # dequantized grid — mAP (and so the masked feasibility) stays
    # fp32-exact, per the quantization contract.
    deq = quantize_roundtrip(ProfileTable(T.astype(jnp.float32),
                                          E.astype(jnp.float32),
                                          mAP.astype(jnp.float32)))
    ps, q = mo_select_batch_hoisted(deq, gs, q0, delta=delta, gamma=gamma,
                                    health=health)
    return ps.astype(jnp.int32), q


def moscore_route(T, E, mAP, gs, q0, *, delta: float = 20.0,
                  gamma: float = 0.5, interpret: bool = True,
                  backend: str = "pallas", health=None):
    """Route a window of requests with queue feedback.

    T/E/mAP: (P, G) profile tables; gs: (W,) int32 estimated groups;
    q0: (P,) queue depths. Returns (choices (W,), q_final (P,)).

    ``backend`` selects the implementation (see the module docstring):
    ``"xla"`` | ``"pallas"`` | ``"hoisted"`` | ``"pallas_hoisted"`` are
    bit-identical fp32 paths, ``"int8"`` routes on quantized tables
    under the bounded-mismatch contract, and ``"auto"`` resolves via
    :func:`resolve_backend` (``REPRO_MOSCORE_BACKEND`` env override,
    else per platform). Safe to call under an outer ``jit``.

    ``health`` (optional, (P,) bool) is the fault plane's mask for the
    whole window, applied at the feasibility stage with the degraded
    fallback (``core.policies.mo_scores``) — every fp32 backend agrees
    bit-identically under it. The unhoisted ``"pallas"`` kernel
    recomputes feasibility from raw mAP inside its body, so a masked
    window routes through the hoisted kernel instead (the mask enters
    via the precompute — same fp32 expressions, same decisions)."""
    backend = resolve_backend(backend)
    if backend == "xla":
        return _xla_route(T, E, mAP, gs, q0, delta=delta, gamma=gamma,
                          health=health)
    if backend == "hoisted":
        return _hoisted_route(T, E, mAP, gs, q0, delta=delta, gamma=gamma,
                              health=health)
    if backend == "int8":
        return _int8_route(T, E, mAP, gs, q0, delta=delta, gamma=gamma,
                           health=health)
    if jax.default_backend() == "tpu":
        interpret = False
    if health is not None:
        return _pallas_hoisted_route(T, E, mAP, gs, q0, delta=delta,
                                     gamma=gamma, interpret=interpret,
                                     health=health)
    route = _pallas_hoisted_route if backend == "pallas_hoisted" \
        else _pallas_route
    return route(T, E, mAP, gs, q0, delta=delta, gamma=gamma,
                 interpret=interpret)
