"""Fused multi-objective routing window as a Pallas TPU kernel.

The paper's gateway makes one Algorithm-1 decision per request with live
queue feedback — decision w+1 must see the queue bump of decision w, a
strictly sequential recurrence. Done naively (one jnp dispatch per request)
each step round-trips the queue vector through HBM; fused here, the profile
tables (P x G), the queue vector and the whole W-request scan live in VMEM
for a single kernel launch (TPU-native analogue of the paper's HAProxy+Lua
"microsecond-scale decision" requirement).

Layout: everything kept 2D with the pair axis last (lane dimension,
padded to a multiple of 128 by ops.py). Single program, grid=().
VMEM: 3 x (G x P') profile tables + (1 x P') queue + (W x 1) ids — a P'=1024,
G=8, W=4096 window uses ~130 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30


def _moscore_kernel(tg_ref, eg_ref, mg_ref, g_ref, q0_ref, out_ref, qf_ref,
                    *, delta: float, gamma: float, n_window: int):
    # tg/eg/mg: (G, P') profiles transposed; g: (W, 1) int32; q0: (1, P')
    _, p = tg_ref.shape

    def body(w, q):
        g = g_ref[w, 0]
        Tg = jax.lax.dynamic_slice(tg_ref[...], (g, 0), (1, p))   # (1, P')
        Eg = jax.lax.dynamic_slice(eg_ref[...], (g, 0), (1, p))
        Mg = jax.lax.dynamic_slice(mg_ref[...], (g, 0), (1, p))

        feasible = Mg >= jnp.max(Mg) - delta
        L = Tg * (1.0 + q)
        l_min = jnp.min(jnp.where(feasible, L, BIG))
        l_max = jnp.max(jnp.where(feasible, L, -BIG))
        e_min = jnp.min(jnp.where(feasible, Eg, BIG))
        e_max = jnp.max(jnp.where(feasible, Eg, -BIG))
        Ln = (L - l_min) / jnp.maximum(l_max - l_min, 1e-9)
        En = (Eg - e_min) / jnp.maximum(e_max - e_min, 1e-9)
        J = jnp.where(feasible, gamma * Ln + (1.0 - gamma) * En, BIG)

        sel = jnp.argmin(J[0]).astype(jnp.int32)
        # index with a traced scalar, not a python int: older jax pallas
        # rejects raw ints in store indexers
        pl.store(out_ref, (w, jnp.asarray(0, jnp.int32)), sel)
        onehot = (jax.lax.broadcasted_iota(jnp.int32, (1, p), 1) == sel)
        return q + onehot.astype(q.dtype)

    q = jax.lax.fori_loop(0, n_window, body, q0_ref[...].astype(jnp.float32))
    qf_ref[...] = q.astype(qf_ref.dtype)


def _moscore_hoisted_kernel(tg_ref, en_ref, fs_ref, g_ref, q0_ref, out_ref,
                            qf_ref, *, gamma: float, n_window: int):
    # The invariant-hoisted variant: the accuracy-feasibility mask and the
    # normalised energy term are queue-independent, so ops.py precomputes
    # them once per table (core.policies.mo_precompute) and the kernel's
    # W-step loop keeps only the L = T_g*(1+q) normalisation + argmin —
    # 2 masked reductions and 1 divide per step instead of 5 and 2, and
    # one fewer (G, P') table in VMEM doing per-step reduction work.
    # tg/en: (G, P') f32; fs: (G, P') f32 {0, 1}; g: (W, 1) int32;
    # q0: (1, P'). Decisions are bit-identical to _moscore_kernel's (the
    # surviving per-step expression is written identically).
    _, p = tg_ref.shape

    def body(w, q):
        g = g_ref[w, 0]
        Tg = jax.lax.dynamic_slice(tg_ref[...], (g, 0), (1, p))   # (1, P')
        En = jax.lax.dynamic_slice(en_ref[...], (g, 0), (1, p))
        feas = jax.lax.dynamic_slice(fs_ref[...], (g, 0), (1, p)) > 0.0

        L = Tg * (1.0 + q)
        l_min = jnp.min(jnp.where(feas, L, BIG))
        l_max = jnp.max(jnp.where(feas, L, -BIG))
        Ln = (L - l_min) / jnp.maximum(l_max - l_min, 1e-9)
        J = jnp.where(feas, gamma * Ln + (1.0 - gamma) * En, BIG)

        sel = jnp.argmin(J[0]).astype(jnp.int32)
        pl.store(out_ref, (w, jnp.asarray(0, jnp.int32)), sel)
        onehot = (jax.lax.broadcasted_iota(jnp.int32, (1, p), 1) == sel)
        return q + onehot.astype(q.dtype)

    q = jax.lax.fori_loop(0, n_window, body, q0_ref[...].astype(jnp.float32))
    qf_ref[...] = q.astype(qf_ref.dtype)


def moscore_hoisted_pallas(Tt, Ent, Ft, gs, q0, *, gamma: float,
                           interpret: bool = True):
    """Invariant-hoisted kernel: Tt (G, P') fp32 transposed profile, Ent
    (G, P') the precomputed normalised-energy term, Ft (G, P') fp32
    feasibility mask (1.0 feasible / 0.0 not — padded pairs 0), gs (W, 1)
    int32, q0 (1, P') fp32. Returns (choices (W, 1) int32, q_final
    (1, P') fp32), bit-identical to :func:`moscore_pallas` on the same
    unquantized tables."""
    g_dim, p = Tt.shape
    w = gs.shape[0]
    kernel = functools.partial(_moscore_hoisted_kernel, gamma=gamma,
                               n_window=w)
    return pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[pl.BlockSpec(Tt.shape, lambda: (0, 0)),
                  pl.BlockSpec(Ent.shape, lambda: (0, 0)),
                  pl.BlockSpec(Ft.shape, lambda: (0, 0)),
                  pl.BlockSpec(gs.shape, lambda: (0, 0)),
                  pl.BlockSpec(q0.shape, lambda: (0, 0))],
        out_specs=[pl.BlockSpec((w, 1), lambda: (0, 0)),
                   pl.BlockSpec((1, p), lambda: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((w, 1), jnp.int32),
                   jax.ShapeDtypeStruct((1, p), jnp.float32)],
        interpret=interpret,
    )(Tt, Ent, Ft, gs, q0)


def moscore_pallas(Tt, Et, Mt, gs, q0, *, delta: float, gamma: float,
                   interpret: bool = True):
    """Tt/Et/Mt: (G, P') fp32 transposed profiles (P' multiple of 128);
    gs: (W, 1) int32; q0: (1, P') fp32. Returns (choices (W,1) int32,
    q_final (1, P') fp32)."""
    g_dim, p = Tt.shape
    w = gs.shape[0]
    kernel = functools.partial(_moscore_kernel, delta=delta, gamma=gamma,
                               n_window=w)
    return pl.pallas_call(
        kernel,
        grid=(),
        in_specs=[pl.BlockSpec(Tt.shape, lambda: (0, 0)),
                  pl.BlockSpec(Et.shape, lambda: (0, 0)),
                  pl.BlockSpec(Mt.shape, lambda: (0, 0)),
                  pl.BlockSpec(gs.shape, lambda: (0, 0)),
                  pl.BlockSpec(q0.shape, lambda: (0, 0))],
        out_specs=[pl.BlockSpec((w, 1), lambda: (0, 0)),
                   pl.BlockSpec((1, p), lambda: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((w, 1), jnp.int32),
                   jax.ShapeDtypeStruct((1, p), jnp.float32)],
        interpret=interpret,
    )(Tt, Et, Mt, gs, q0)
