"""Oracle: the core-library reference implementation of Algorithm 1 with
queue feedback (lax.scan form) — the kernel must match it exactly."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.policies import mo_select_batch
from repro.core.profiles import ProfileTable


def ref_moscore_route(T, E, mAP, gs, q0, *, delta: float, gamma: float,
                      health=None):
    prof = ProfileTable(T, E, mAP)
    ps, q = mo_select_batch(prof, gs, q0, delta=delta, gamma=gamma,
                            health=health)
    return ps.astype(jnp.int32), q
