from repro.kernels.moscore.ops import moscore_route
from repro.kernels.moscore.ref import ref_moscore_route

__all__ = ["moscore_route", "ref_moscore_route"]
