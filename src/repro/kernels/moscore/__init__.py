from repro.kernels.moscore.ops import (BACKEND_ENV, BACKENDS,
                                       default_backend, moscore_route,
                                       resolve_backend)
from repro.kernels.moscore.ref import ref_moscore_route

__all__ = ["moscore_route", "ref_moscore_route", "default_backend",
           "resolve_backend", "BACKENDS", "BACKEND_ENV"]
