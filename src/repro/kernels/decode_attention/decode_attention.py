"""Split-K decode attention (FlashDecoding-style) as a Pallas TPU kernel.

Decode is HBM-bound: the whole KV cache is streamed once per token. To keep
every HBM channel busy at batch=1, the sequence is split into ``n_splits``
grid programs per (batch x kv-head); each computes a partial softmax
(numerator, logsumexp) over its chunk into its own output slot, and ops.py
combines the partials with a tiny fp32 logsumexp reduction. The same
(partial, LSE-combine) decomposition runs *across devices* for the
sequence-sharded long_500k cells (distributed split-K, DESIGN.md §6).

Grid: (B*KV, n_splits); block = (S/n_splits, D) of K and V in VMEM.
VMEM per program at S=32k, n_splits=8, D=128, bf16: 2 x 1 MiB + G-row
accumulators — well under budget; n_splits chosen by ops.py so the block
stays <= 4 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _dec_kernel(q_ref, k_ref, v_ref, kvlen_ref, o_ref, lse_ref, *,
                scale: float, block: int):
    # q_ref: (G, D); k/v_ref: (BLK, D); o_ref: (G, D); lse_ref: (G, 1)
    g, d = q_ref.shape
    q = q_ref[...].astype(jnp.float32) * scale
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    split = pl.program_id(1)
    base = split * block

    s = q @ k.T                                        # (G, BLK)
    pos = base + jax.lax.broadcasted_iota(jnp.int32, (g, block), 1)
    valid = pos < kvlen_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    m = jnp.max(s, axis=1)
    # all-invalid splits produce m = NEG_INF; guard the exp
    m_safe = jnp.maximum(m, NEG_INF / 2)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(valid, p, 0.0)
    l = jnp.sum(p, axis=1)
    # normalised partial: combine weights are then exactly exp(lse - LSE)
    o = (p @ v) / jnp.maximum(l, 1e-30)[:, None]       # (G, D)
    lse = jnp.where(l > 0, jnp.log(l) + m_safe, NEG_INF)
    o_ref[...] = o.astype(o_ref.dtype)
    lse_ref[...] = lse[:, None].astype(lse_ref.dtype)


def decode_attention_splits(q, k, v, kv_len, *, n_splits: int,
                            interpret: bool = True):
    """q: (BKV, G, D); k/v: (BKV, S, D); kv_len: (BKV, 1) int32.
    Returns partials o: (BKV, n_splits, G, D), lse: (BKV, n_splits, G, 1)."""
    bkv, g, d = q.shape
    s = k.shape[1]
    assert s % n_splits == 0, (s, n_splits)
    block = s // n_splits
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_dec_kernel, scale=scale, block=block)
    return pl.pallas_call(
        kernel,
        grid=(bkv, n_splits),
        in_specs=[
            pl.BlockSpec((None, g, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, block, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, block, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, 1), lambda b, i: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, g, d), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((None, None, g, 1), lambda b, i: (b, i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bkv, n_splits, g, d), jnp.float32),
            jax.ShapeDtypeStruct((bkv, n_splits, g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, kv_len)
