"""Jitted wrapper: layout, split-count heuristic, LSE combine."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import (
    decode_attention_splits)

NEG_INF = -1e30


def _pick_splits(s: int, d: int, target_block_bytes: int = 4 << 20) -> int:
    block = max(128, target_block_bytes // (2 * d * 2))   # bf16 k+v
    n = max(1, s // block)
    while s % n != 0:
        n -= 1
    return n


@functools.partial(jax.jit, static_argnames=("n_splits", "interpret"))
def decode_attention(q, k, v, kv_len=None, *, n_splits: int = 0,
                     interpret: bool = True):
    """q: (B, H, D); k/v: (B, S, KV, D); kv_len: (B,) valid length or None.
    Split-K partials from the Pallas kernel, fp32 LSE combine here."""
    b, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    if kv_len is None:
        kv_len = jnp.full((b,), s, jnp.int32)
    ns = n_splits or _pick_splits(s, d)

    qf = q.reshape(b, kv, g, d).reshape(b * kv, g, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    lens = jnp.repeat(kv_len.astype(jnp.int32), kv)[:, None]

    o_p, lse_p = decode_attention_splits(qf, kf, vf, lens, n_splits=ns,
                                         interpret=interpret)
    # combine partials: softmax over splits in fp32
    lse = lse_p[..., 0]                                   # (BKV, NS, G)
    m = jnp.max(lse, axis=1, keepdims=True)
    w = jnp.exp(lse - m)                                  # (BKV, NS, G)
    num = jnp.sum(o_p * w[..., None], axis=1)             # (BKV, G, D)
    den = jnp.sum(w, axis=1)                              # (BKV, G)
    out = num / den[..., None]
    return out.reshape(b, kv, g, d).reshape(b, h, d).astype(q.dtype)
