from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import ref_decode_attention

__all__ = ["decode_attention", "ref_decode_attention"]
