"""Pure-jnp oracle for KV-cache decode attention (one query token)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_decode_attention(q, k, v, kv_len=None):
    """q: (B, H, D); k/v: (B, S, KV, D); kv_len: (B,) valid prefix length
    (None -> full). Returns (B, H, D)."""
    b, h, d = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32))
    scores = scores / (d ** 0.5)
    if kv_len is not None:
        valid = jnp.arange(s)[None] < kv_len[:, None]
        scores = jnp.where(valid[:, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o.reshape(b, h, d).astype(q.dtype)
