"""Object-count estimator (paper §III-B.1, output-based / temporal
continuity): the group of an incoming frame is estimated from the detection
output of the *previous* frame of the same stream, produced by whichever
device-model pair processed it. No extra counting model runs.

The detection count is therefore accuracy-dependent: a weak model on a
complex scene undercounts, which can misclassify the *next* frame into an
easy group — the sticky-error dynamic analysed in EXPERIMENTS.md §Fig4."""

from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32


def group_of_count(count, n_groups: int = 5):
    """Paper grouping: {0, 1, 2, 3, 4+} objects."""
    return jnp.clip(count, 0, n_groups - 1).astype(jnp.int32)


def noisy_detected_count(rng, true_count, map_pg, max_count: int = 8):
    """Simulate the detector's count given per-(pair,group) accuracy.

    Each of the ``true_count`` objects is detected independently with
    probability ``p_det = 0.80 + 0.20 * mAP/100``: counting degrades much
    more gently with mAP than box quality does (mAP penalises localisation
    and classification, which barely affect a raw count; the ECORE estimator
    the paper builds on [6] reports high count accuracy even for small
    models). False positives occur with small probability scaled by
    (1 - mAP/100)."""
    p_det = jnp.clip(0.80 + 0.20 * map_pg / 100.0, 0.0, 1.0)
    u = jax.random.uniform(rng, (max_count,))
    present = jnp.arange(max_count) < true_count
    detected = jnp.sum((u < p_det) & present)
    fp_rng = jax.random.fold_in(rng, 1)
    p_fp = 0.05 * (1.0 - map_pg / 100.0)
    fp = (jax.random.uniform(fp_rng, ()) < p_fp).astype(jnp.int32)
    return detected.astype(jnp.int32) + fp


def markov_transition(n_states: int = 5, stickiness: float = 0.85,
                      drift_up: float = 0.62):
    """Scene-complexity Markov chain: consecutive frames usually keep their
    object count (temporal continuity), occasionally drift +-1, rarely jump.
    ``drift_up`` > 0.5 skews the stationary distribution toward crowded
    scenes (the paper's stream is a busy pedestrian crossing)."""
    eye = jnp.eye(n_states)
    up = jnp.roll(eye, 1, axis=1).at[-1].set(0.0)      # no wraparound
    down = jnp.roll(eye, -1, axis=1).at[0].set(0.0)
    drift = drift_up * up + (1 - drift_up) * down
    # boundary states put all drift mass on their single neighbour
    drift = drift.at[0, 1].set(1.0).at[-1, -2].set(1.0)
    jump = jnp.ones((n_states, n_states)) / n_states
    P = stickiness * eye + (1 - stickiness) * (0.8 * drift + 0.2 * jump)
    return P / jnp.sum(P, axis=1, keepdims=True)


def stationary(P):
    """Stationary distribution of a row-stochastic matrix (power iteration).
    A ``fori_loop`` rather than an unrolled Python loop: the op sequence —
    and hence the result, bit for bit — is identical, but the trace stays
    200x smaller, which keeps ``make_grid``'s per-``n_users``-level draw
    compiles cheap."""
    pi = jnp.ones((P.shape[0],)) / P.shape[0]
    return jax.lax.fori_loop(0, 200, lambda _, p: p @ P, pi)


def markov_step(rng, state, P):
    """Sample next state of the chain (state: (U,) int32)."""
    probs = P[state]                       # (U, S)
    return jax.random.categorical(rng, jnp.log(probs + 1e-9), axis=-1)
