"""The fault plane (ROADMAP item toward 4): device outages, throttling
bursts and stochastic WAN jitter as a declarative Scenario component.

The paper's premise is that heterogeneous SBC fleets are unreliable and
dynamically varying, yet the engine so far assumes every device-model
pair is always up and every cloud RTT is a constant. :class:`FaultSchedule`
describes what actually goes wrong:

  * **outages / flapping** — each fault *epoch* (``epoch`` scheduler
    steps) every pair is independently down with probability
    ``down_rate``; ``outages=((pair, start, end), ...)`` scripts
    deterministic outage windows on top (benchmarks use this for
    reproducible failover stories);
  * **throttling bursts** — per epoch, each pair is throttled with
    probability ``throttle_rate``; a throttled pair's TRUE service time
    and energy are scaled by ``throttle_t_mult`` / ``throttle_e_mult``
    (thermal throttling, a co-tenant burst). Composition with
    :class:`~repro.core.dispatch.DriftSchedule` is defined: drift scales
    apply first, fault throttles multiply on top
    (``truth = (prof x drift) x fault``);
  * **WAN jitter** — per scheduler step the cloud uplink transfer is
    scaled by ``1 + bw_jitter * U[0,1)`` and the RTT gains
    ``rtt_jitter_ms * U[0,1)`` ms (the ROADMAP's "stochastic RTT").

Every draw is a pure function of the absolute step index under
``fold_in``-derived keys — epoch draws key on ``fold_in(k, step //
epoch)``, jitter draws on ``fold_in(k, step)`` — so there is NO carried
fault state and realizations are bitwise invariant to window
partitioning, user blocks and sharding *by construction* (the same
invariance contract as the workload stream keys).

Routing semantics: the router sees the **health mask** ``health_at(step)``
(pairs up this step) and masks candidates at the accuracy-feasibility
stage (:func:`repro.core.policies.mo_scores`). Graceful degradation is a
defined rule: when no healthy pair clears the accuracy bar, routing falls
back to the **healthy argmin-latency pair** and the step counts an SLO
violation; when the whole fleet is down the mask relaxes to all-true
(there is nobody else to route to) and dispatching into the outage costs
a ``timeout_ms`` stall. ``visible=False`` keeps the router blind (static
routing) while the truth model still pays outage stalls — the benchmark
baseline that failover-aware routing is measured against.

A scenario with ``faults=None`` never builds any of this — the no-fault
engine path is bit-identical to PR 9 (``tests/golden_faults_pr9.json``).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

f32 = jnp.float32
i32 = jnp.int32

__all__ = ["FaultSchedule", "FaultMeta"]

# fold_in salts for the independent fault sub-streams
_SALT_DOWN, _SALT_THROTTLE, _SALT_RTT, _SALT_BW = 0, 1, 2, 3


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class FaultMeta:
    """The traced half of a fault schedule — what jitted code needs.

    Leaves are the base PRNG key, the scalar rates/multipliers and the
    scripted-outage arrays; the static aux data is the pair count plus
    which fault sources are active at all (python bools, so a schedule
    with e.g. only WAN jitter adds NOTHING to the outage/throttle graph).
    Every query below is a pure function of the absolute step index —
    module docstring — which is what makes realizations invariant to
    window partitioning, user blocks and sharding."""

    key: jax.Array           # (2,) uint32 base fault key
    down_rate: jax.Array     # () f32
    thr_rate: jax.Array      # () f32
    thr_t: jax.Array         # () f32
    thr_e: jax.Array         # () f32
    rtt_jitter_ms: jax.Array  # () f32
    bw_jitter: jax.Array     # () f32
    timeout_ms: jax.Array    # () f32
    epoch: jax.Array         # () i32
    script_pair: jax.Array   # (S,) i32
    script_start: jax.Array  # (S,) i32
    script_end: jax.Array    # (S,) i32
    n_pairs: int = 0         # static
    visible: bool = True     # static: does the router see the mask?
    has_random_down: bool = False   # static source flags
    has_script: bool = False
    has_throttle: bool = False
    has_rtt_jitter: bool = False
    has_bw_jitter: bool = False

    def tree_flatten(self):
        leaves = (self.key, self.down_rate, self.thr_rate, self.thr_t,
                  self.thr_e, self.rtt_jitter_ms, self.bw_jitter,
                  self.timeout_ms, self.epoch, self.script_pair,
                  self.script_start, self.script_end)
        aux = (self.n_pairs, self.visible, self.has_random_down,
               self.has_script, self.has_throttle, self.has_rtt_jitter,
               self.has_bw_jitter)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)

    @property
    def has_down(self) -> bool:
        return self.has_random_down or self.has_script

    # -- per-step queries (pure functions of the step index) ------------

    def down_at(self, step):
        """Raw outage mask at scheduler step ``step``: (P,) bool, True =
        the pair is DOWN. Epoch-keyed random outages OR'd with any
        scripted windows; the truth model uses this (a down pair really
        is down even when the router's mask has relaxed)."""
        step = jnp.asarray(step, i32)
        down = jnp.zeros((self.n_pairs,), bool)
        if self.has_random_down:
            e = step // self.epoch
            k = jax.random.fold_in(
                jax.random.fold_in(self.key, _SALT_DOWN), e)
            down = jax.random.uniform(k, (self.n_pairs,)) < self.down_rate
        if self.has_script:
            hit = (step >= self.script_start) & (step < self.script_end)
            down = down.at[self.script_pair].max(hit)
        return down

    def health_at(self, step):
        """The router's health mask: (P,) bool, True = routable. The
        complement of :meth:`down_at`, relaxed to all-true when the
        whole fleet is down (there is nobody else to route to; the
        truth model still pays the ``timeout_ms`` stall)."""
        up = ~self.down_at(step)
        return jnp.where(jnp.any(up), up, True)

    def throttle_at(self, step):
        """Per-pair throttling multipliers at ``step``: ``(t_scale,
        e_scale)``, each (P,) f32, 1.0 where not throttled. Epoch-keyed
        like outages, independent sub-stream."""
        if not self.has_throttle:
            ones = jnp.ones((self.n_pairs,), f32)
            return ones, ones
        e = jnp.asarray(step, i32) // self.epoch
        k = jax.random.fold_in(
            jax.random.fold_in(self.key, _SALT_THROTTLE), e)
        hot = jax.random.uniform(k, (self.n_pairs,)) < self.thr_rate
        return (jnp.where(hot, self.thr_t, 1.0),
                jnp.where(hot, self.thr_e, 1.0))

    def rtt_extra_ms(self, step):
        """Stochastic extra cloud RTT at ``step``: scalar f32 in
        ``[0, rtt_jitter_ms)``, drawn per step."""
        k = jax.random.fold_in(
            jax.random.fold_in(self.key, _SALT_RTT),
            jnp.asarray(step, i32))
        return self.rtt_jitter_ms * jax.random.uniform(k)

    def xfer_scale(self, step):
        """Uplink transfer slowdown at ``step``: scalar f32 in
        ``[1, 1 + bw_jitter)``, drawn per step."""
        k = jax.random.fold_in(
            jax.random.fold_in(self.key, _SALT_BW),
            jnp.asarray(step, i32))
        return 1.0 + self.bw_jitter * jax.random.uniform(k)


@dataclass(frozen=True, eq=False)
class FaultSchedule:
    """Device outages, throttling bursts and WAN jitter as a declarative
    Scenario component (module docstring for the fault model).

    ``down_rate`` / ``throttle_rate`` are per-epoch per-pair
    probabilities; ``epoch`` the fault-epoch length in scheduler steps;
    ``throttle_t_mult`` / ``throttle_e_mult`` the throttled pair's
    latency/energy inflation; ``rtt_jitter_ms`` / ``bw_jitter`` the WAN
    jitter amplitudes (only felt by cloud pairs); ``timeout_ms`` the
    stall a request pays when dispatched into an outage (and the serving
    plane's retry timeout); ``max_attempts`` the serving plane's retry
    bound; ``visible=False`` keeps the router blind (static routing)
    while the truth model still faults; ``outages`` scripts
    deterministic ``(pair, start_step, end_step)`` windows; ``seed``
    keys the fault RNG independently of the workload.

    Value-equal like a Scenario (two schedules are ``==`` iff their JSON
    specs match), so ``Results.sel(faults=fs)`` and scenario hashing
    work; ``Sweep(faults=[FaultSchedule(rtt_jitter_ms=j) for j in js])``
    sweeps a jitter axis."""

    down_rate: float = 0.0
    epoch: int = 50
    throttle_rate: float = 0.0
    throttle_t_mult: float = 3.0
    throttle_e_mult: float = 1.5
    rtt_jitter_ms: float = 0.0
    bw_jitter: float = 0.0
    timeout_ms: float = 1000.0
    max_attempts: int = 3
    visible: bool = True
    outages: tuple = ()
    seed: int = 0

    def __post_init__(self):
        if not (0.0 <= self.down_rate < 1.0):
            raise ValueError(f"down_rate must be in [0, 1), got "
                             f"{self.down_rate!r}")
        if not (0.0 <= self.throttle_rate < 1.0):
            raise ValueError(f"throttle_rate must be in [0, 1), got "
                             f"{self.throttle_rate!r}")
        if not (isinstance(self.epoch, int) and self.epoch >= 1):
            raise ValueError(f"epoch must be a positive int, got "
                             f"{self.epoch!r}")
        if not (self.throttle_t_mult > 0 and self.throttle_e_mult > 0):
            raise ValueError("throttle multipliers must be > 0, got "
                             f"{self.throttle_t_mult!r}/"
                             f"{self.throttle_e_mult!r}")
        if not (self.rtt_jitter_ms >= 0.0):
            raise ValueError(f"rtt_jitter_ms must be >= 0, got "
                             f"{self.rtt_jitter_ms!r}")
        if not (self.bw_jitter >= 0.0):
            raise ValueError(f"bw_jitter must be >= 0, got "
                             f"{self.bw_jitter!r}")
        if not (self.timeout_ms >= 0.0):
            raise ValueError(f"timeout_ms must be >= 0, got "
                             f"{self.timeout_ms!r}")
        if not (isinstance(self.max_attempts, int)
                and self.max_attempts >= 1):
            raise ValueError(f"max_attempts must be a positive int, got "
                             f"{self.max_attempts!r}")
        outs = []
        for o in self.outages:
            o = tuple(int(x) for x in o)
            if len(o) != 3:
                raise ValueError("outages entries must be (pair, "
                                 f"start_step, end_step), got {o!r}")
            p, s, e = o
            if p < 0 or s < 0 or e <= s:
                raise ValueError("outage needs pair >= 0 and 0 <= start "
                                 f"< end, got {o!r}")
            outs.append(o)
        object.__setattr__(self, "outages", tuple(outs))

    @property
    def active(self) -> bool:
        """Whether any fault source is configured at all."""
        return (self.down_rate > 0 or bool(self.outages)
                or self.throttle_rate > 0 or self.rtt_jitter_ms > 0
                or self.bw_jitter > 0)

    # -- resolution -----------------------------------------------------

    def resolve(self, n_pairs: int) -> FaultMeta:
        """Bind the schedule to a fleet of ``n_pairs`` pairs (the
        EXTENDED pair axis when a cloud tier is present, so scripted
        outages can take down cloud pairs too)."""
        for p, _, _ in self.outages:
            if p >= n_pairs:
                raise ValueError(f"scripted outage on pair {p} but the "
                                 f"fleet has {n_pairs} pairs")
        sp = np.asarray([o[0] for o in self.outages], np.int32)
        ss = np.asarray([o[1] for o in self.outages], np.int32)
        se = np.asarray([o[2] for o in self.outages], np.int32)
        return FaultMeta(
            key=jax.random.PRNGKey(self.seed),
            down_rate=jnp.asarray(self.down_rate, f32),
            thr_rate=jnp.asarray(self.throttle_rate, f32),
            thr_t=jnp.asarray(self.throttle_t_mult, f32),
            thr_e=jnp.asarray(self.throttle_e_mult, f32),
            rtt_jitter_ms=jnp.asarray(self.rtt_jitter_ms, f32),
            bw_jitter=jnp.asarray(self.bw_jitter, f32),
            timeout_ms=jnp.asarray(self.timeout_ms, f32),
            epoch=jnp.asarray(self.epoch, i32),
            script_pair=jnp.asarray(sp, i32),
            script_start=jnp.asarray(ss, i32),
            script_end=jnp.asarray(se, i32),
            n_pairs=int(n_pairs),
            visible=bool(self.visible),
            has_random_down=self.down_rate > 0,
            has_script=bool(self.outages),
            has_throttle=self.throttle_rate > 0,
            has_rtt_jitter=self.rtt_jitter_ms > 0,
            has_bw_jitter=self.bw_jitter > 0,
        )

    # -- serialization (the Scenario component contract) ---------------

    def to_json(self) -> dict:
        # defaults serialize as absent keys, so default-equivalent
        # schedules share one spec/hash (the CloudTier rule)
        spec = {}
        if self.down_rate != 0.0:
            spec["down_rate"] = float(self.down_rate)
        if self.epoch != 50:
            spec["epoch"] = int(self.epoch)
        if self.throttle_rate != 0.0:
            spec["throttle_rate"] = float(self.throttle_rate)
        if self.throttle_t_mult != 3.0:
            spec["throttle_t_mult"] = float(self.throttle_t_mult)
        if self.throttle_e_mult != 1.5:
            spec["throttle_e_mult"] = float(self.throttle_e_mult)
        if self.rtt_jitter_ms != 0.0:
            spec["rtt_jitter_ms"] = float(self.rtt_jitter_ms)
        if self.bw_jitter != 0.0:
            spec["bw_jitter"] = float(self.bw_jitter)
        if self.timeout_ms != 1000.0:
            spec["timeout_ms"] = float(self.timeout_ms)
        if self.max_attempts != 3:
            spec["max_attempts"] = int(self.max_attempts)
        if not self.visible:
            spec["visible"] = False
        if self.outages:
            spec["outages"] = [list(o) for o in self.outages]
        if self.seed != 0:
            spec["seed"] = int(self.seed)
        return spec

    @classmethod
    def from_json(cls, spec: dict | None) -> "FaultSchedule | None":
        if spec is None:
            return None
        return cls(
            down_rate=float(spec.get("down_rate", 0.0)),
            epoch=int(spec.get("epoch", 50)),
            throttle_rate=float(spec.get("throttle_rate", 0.0)),
            throttle_t_mult=float(spec.get("throttle_t_mult", 3.0)),
            throttle_e_mult=float(spec.get("throttle_e_mult", 1.5)),
            rtt_jitter_ms=float(spec.get("rtt_jitter_ms", 0.0)),
            bw_jitter=float(spec.get("bw_jitter", 0.0)),
            timeout_ms=float(spec.get("timeout_ms", 1000.0)),
            max_attempts=int(spec.get("max_attempts", 3)),
            visible=bool(spec.get("visible", True)),
            outages=tuple(tuple(o) for o in spec.get("outages", ())),
            seed=int(spec.get("seed", 0)),
        )

    def __eq__(self, other):
        if not isinstance(other, FaultSchedule):
            return NotImplemented
        return self.to_json() == other.to_json()

    def __hash__(self):
        spec = self.to_json()
        return hash(tuple(sorted(
            (k, v if not isinstance(v, list) else
             tuple(tuple(o) for o in v))
            for k, v in spec.items())))

    def __repr__(self):
        spec = self.to_json()
        body = ", ".join(f"{k}={v!r}" for k, v in spec.items())
        return f"FaultSchedule({body})"
