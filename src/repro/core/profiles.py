"""Offline profiling tables: per device-model pair p and complexity group g,
inference time T[p,g] (ms), energy E[p,g] (mWh, excl. idle base power) and
accuracy mAP[p,g] (0..100). Exactly the paper's profiling abstraction; the
same interface is fed by (a) the paper-testbed numbers, (b) synthetic fleets
for scale tests, and (c) roofline-derived TPU serving cells
(``repro.core.energy.derive_tpu_profile``).

A ``ProfileTable`` may also be *stacked*: :func:`stack_profiles` joins F
fleets of identical (P, G) shape into one table whose leaves carry a
leading fleet axis (F, P, G). The batched simulator
(``repro.core.simulator.simulate_batch`` / ``sweep_grid``) vmaps over that
axis, fusing a whole fleet ensemble into the same device program — see
``docs/sweep_engine.md``."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

f32 = jnp.float32

GROUP_NAMES = ("0_objects", "1_object", "2_objects", "3_objects", "4plus")


@jax.tree_util.register_pytree_node_class
@dataclass
class ProfileTable:
    """Per-(pair, group) profiling table — the paper's offline measurements.

    Leaves are either single-fleet, shape ``(P, G)`` float32, or *stacked*
    (:func:`stack_profiles`), shape ``(F, P, G)`` with a leading fleet
    axis; ``floor_mw`` is ``(P,)`` / ``(F, P)`` accordingly. Registered as
    a pytree (``names`` is static aux data) so it can be passed straight
    through ``jit`` / ``vmap`` / ``shard_map``.
    """

    T: jax.Array            # (P, G) ms
    E: jax.Array            # (P, G) mWh / request
    mAP: jax.Array          # (P, G) in [0, 100]
    names: tuple[str, ...] = ()
    floor_mw: jax.Array | None = None   # (P,) active-floor power above idle

    def tree_flatten(self):
        return (self.T, self.E, self.mAP, self.floor_mw), self.names

    @classmethod
    def tree_unflatten(cls, names, leaves):
        T, E, mAP, floor = leaves
        return cls(T, E, mAP, names, floor)

    @property
    def n_pairs(self) -> int:
        return self.T.shape[-2]

    @property
    def n_groups(self) -> int:
        return self.T.shape[-1]

    @property
    def is_stacked(self) -> bool:
        """True when the leaves carry a leading fleet axis (F, P, G)."""
        return self.T.ndim == 3

    @property
    def n_fleets(self) -> int:
        return self.T.shape[0] if self.is_stacked else 1

    def save(self, path: str) -> None:
        np.savez(path, T=np.asarray(self.T), E=np.asarray(self.E),
                 mAP=np.asarray(self.mAP),
                 floor_mw=np.asarray(self.floor_mw)
                 if self.floor_mw is not None
                 else np.zeros(self.T.shape[:-1]),
                 names=np.array(self.names, dtype=object))

    @classmethod
    def load(cls, path: str) -> "ProfileTable":
        z = np.load(path, allow_pickle=True)
        return cls(jnp.asarray(z["T"]), jnp.asarray(z["E"]),
                   jnp.asarray(z["mAP"]), tuple(z["names"].tolist()),
                   jnp.asarray(z["floor_mw"]))


def paper_fleet() -> ProfileTable:
    """The 5-node heterogeneous testbed of Table I/II, with profiles
    calibrated to reproduce the orderings and ratios of Fig. 2/4/5:

      n1 pi5-tpu/ssd_v1     fastest (Table I best inference time; best mAP G1)
      n2 pi5-tpu/ssd_lite   cheap + fast (best mAP G2)
      n3 pi5-aihat/yolov8s  most accurate on complex scenes (best mAP G4/G5)
      n4 orin/yolov8s       accurate, faster, energy-hungry (best mAP G3)
      n5 orin/ssd_v1        lowest energy (Table I best energy)
    """
    names = ("pi5tpu/ssd_v1", "pi5tpu/ssd_lite", "pi5aihat/yolov8s",
             "orin/yolov8s", "orin/ssd_v1")
    T = jnp.array([
        [92.0, 96.0, 100.0, 105.0, 110.0],      # n1 (fastest, Table I)
        [122.0, 126.0, 130.0, 136.0, 142.0],    # n2
        [390.0, 395.0, 400.0, 405.0, 410.0],    # n3 (HA pair, slowest)
        [145.0, 148.0, 150.0, 153.0, 156.0],    # n4
        [112.0, 116.0, 120.0, 125.0, 130.0],    # n5
    ])
    E = jnp.array([
        [0.10, 0.10, 0.11, 0.11, 0.12],
        [0.07, 0.07, 0.08, 0.08, 0.09],
        [0.38, 0.39, 0.40, 0.41, 0.42],
        [0.26, 0.27, 0.28, 0.29, 0.30],
        [0.04, 0.04, 0.05, 0.05, 0.06],
    ])
    mAP = jnp.array([
        # Table I: G1 best = pi5tpu/ssd_v1, G2 best = pi5tpu/ssd_lite (the
        # Fig.2 observation: ssd-class ~= yolo-class on simple scenes)
        [76.0, 68.0, 56.0, 30.0, 14.0],     # ssd_v1 on pi5-tpu
        [70.0, 78.5, 52.0, 26.0, 11.0],     # ssd_lite
        [75.0, 78.0, 78.5, 79.5, 80.0],     # yolov8s aihat
        [74.0, 77.0, 79.0, 78.0, 77.0],     # yolov8s orin
        [71.0, 67.0, 53.0, 28.0, 12.0],     # ssd_v1 orin
    ])
    floor = jnp.array([60.0, 55.0, 225.0, 300.0, 250.0])   # mW active floor
    return ProfileTable(T, E, mAP, names, floor)


def stack_profiles(profs: Sequence[ProfileTable]) -> ProfileTable:
    """Stack same-shape fleets into one table with a leading fleet axis.

    Every input must be unstacked and share one ``(P, G)`` shape; the
    result has ``T``/``E``/``mAP`` of shape ``(F, P, G)`` and ``floor_mw``
    of ``(F, P)`` (fleets without a floor contribute zeros). ``names`` are
    taken from the first fleet — the fleet axis is an ensemble of
    *hardware profiles*, not of node identities. The batched simulator
    vmaps over this axis, so an ensemble sweep is one device program.
    """
    profs = list(profs)
    if not profs:
        raise ValueError("stack_profiles: empty fleet list")
    if any(p.is_stacked for p in profs):
        raise ValueError("stack_profiles: inputs must be unstacked (P, G) "
                         "tables")
    shapes = {p.T.shape for p in profs}
    if len(shapes) > 1:
        raise ValueError(f"stack_profiles: fleets disagree on (P, G): "
                         f"{sorted(shapes)}")
    P = profs[0].n_pairs
    floors = [p.floor_mw if p.floor_mw is not None else jnp.zeros((P,))
              for p in profs]
    return ProfileTable(
        T=jnp.stack([p.T for p in profs]),
        E=jnp.stack([p.E for p in profs]),
        mAP=jnp.stack([p.mAP for p in profs]),
        names=profs[0].names,
        floor_mw=jnp.stack(floors),
    )


def synthetic_fleet(rng, n_pairs: int, n_groups: int = 5,
                    frac_strong: float = 0.4) -> ProfileTable:
    """Random heterogeneous fleet for scale tests: ``frac_strong`` of pairs
    are accurate-but-slow ("yolo-class"), the rest fast-but-weak on complex
    scenes ("ssd-class")."""
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    strong = jax.random.uniform(k1, (n_pairs, 1)) < frac_strong
    base_T = jnp.where(strong, 120.0, 40.0) \
        * jax.random.uniform(k2, (n_pairs, 1), minval=0.7, maxval=1.4)
    slope = jnp.linspace(1.0, 1.3, n_groups)[None, :]
    T = base_T * slope
    E = jnp.where(strong, 0.28, 0.09) \
        * jax.random.uniform(k3, (n_pairs, 1), minval=0.6, maxval=1.4) \
        * slope
    g = jnp.linspace(0.0, 1.0, n_groups)[None, :]
    strong_map = 74.0 + 6.0 * g
    weak_map = 70.0 - 60.0 * g
    noise = jax.random.uniform(k4, (n_pairs, n_groups), minval=-3, maxval=3)
    mAP = jnp.clip(jnp.where(strong, strong_map, weak_map) + noise, 1.0, 99.0)
    names = tuple(f"pair{i}" for i in range(n_pairs))
    floor = jnp.where(strong[:, 0], 500.0, 150.0)
    return ProfileTable(T, E, mAP, names, floor)
