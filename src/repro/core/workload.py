"""Workload sources: where per-user scene complexity comes from.

The batched simulator (``repro.core.simulator``) consumes scene
complexity through exactly two hooks, and this module turns them into an
interface (:class:`WorkloadSource`):

  * **initial counts** — at grid-build time (``make_grid``), each config
    needs an ``n_users``-shaped vector of initial true object counts plus
    its threefry scan key (:meth:`WorkloadSource.init_draws`, batched as
    :meth:`WorkloadSource.grid_draws`);
  * **per-dispatch step** — inside the ``lax.scan``, each dispatch of
    user ``u`` advances that user's count by one frame
    (:meth:`WorkloadSource.next_count`, with per-config constants built
    once per trace by :meth:`WorkloadSource.prepare`).

Implementations are registered jax pytrees so they pass through
``jit`` / ``vmap`` / ``shard_map`` like a ``ProfileTable``: device data
(e.g. a recorded trace) are leaves, everything else is static aux data.

:class:`MarkovWorkload` is the synthetic default — the paper's
busy-pedestrian-crossing chain (``repro.core.estimator``), bit-identical
to the engine before the interface existed, including the process-wide
``(seed, stickiness, n_users, n_groups)`` draw memoization
(:func:`grid_cache_info` / :func:`grid_cache_clear`). The recorded-trace
implementation lives in ``repro.data.traces``.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator as EST
from repro.core.useraxis import DEFAULT_STREAM_CHUNK

f32 = jnp.float32
i32 = jnp.int32

# Host-side draw key at grid-build time: (seed, stickiness, n_users,
# n_groups). Every WorkloadSource hook is keyed on it.
DrawKey = tuple[int, float, int, int]


class WorkloadSource:
    """Interface between the sweep engine and a scene-complexity source.

    Host-side hooks (grid-build time, numpy in/out):
      * :meth:`init_draws` — one config's initial counts, scan key and
        per-user phase offsets;
      * :meth:`grid_draws` — the batched form over distinct draw keys
        (override to memoize/vectorise; the default loops).

    Traced hooks (inside the scan, jax arrays):
      * :meth:`prepare` — per-config constants (e.g. a transition
        matrix, or the device-resident trace);
      * :meth:`next_count` — the next true object count for the
        dispatching user.

    Subclasses must be registered jax pytrees (device data as leaves)
    so the engine can close over them inside ``jit`` / ``vmap`` /
    ``shard_map`` and replicate them across the config axis.
    """

    def init_draws(self, seed: int, stickiness: float, *, n_groups: int,
                   n_users: int):
        """Initial state for one config -> ``(true0, rng, phase)``:
        ``true0`` (n_users,) int32 initial counts, ``rng`` (2,) uint32
        scan key, ``phase`` (n_users,) int32 per-user phase offsets
        (zeros when the source has no notion of position)."""
        raise NotImplementedError

    def grid_draws(self, keys: list[DrawKey]) -> dict:
        """Batched :meth:`init_draws` over one grid's per-config draw keys
        (duplicates allowed — one entry per config); returns
        ``{key: (true0, rng, phase)}`` as numpy arrays. Override to
        memoize or vectorise."""
        return {k: self.init_draws(k[0], k[1], n_users=k[2], n_groups=k[3])
                for k in keys}

    def validate_user_block(self, user_block: int) -> None:
        """Reject block sizes this source cannot serve coherently. The
        simulator's in-scan hooks see block-LOCAL user indices, so a
        source whose draws depend on the user index (a trace's stream
        assignment) must constrain ``user_block`` so local and global
        indexing agree; the default (index-free sources like the Markov
        chain) accepts everything."""

    def stream_key(self, seed: int) -> np.ndarray:
        """The config's scan key for the streamed draw path — the same
        ``(2,)`` uint32 threefry key :meth:`init_draws` returns, so the
        in-scan RNG stream is shared between the one-shot and streamed
        builders."""
        rng = jax.random.PRNGKey(int(seed))
        _, rng = jax.random.split(rng)
        return np.asarray(rng)

    def stream_chunk(self, seed: int, stickiness, *, n_groups: int,
                     users: np.ndarray):
        """Per-user initial draws for an arbitrary slice of the user
        axis: ``users`` is a 1-D int32 array of absolute user indices;
        returns ``(true0, phase)`` chunks of the same shape. Every user's
        draw is keyed by ``fold_in(key, u)`` on the absolute index, so
        the result is *bitwise independent of chunking* — any partition
        of ``range(n_users)`` reassembles to the same arrays. This is
        the scaling path; it intentionally does NOT reproduce the
        one-shot :meth:`init_draws` categorical (a shape-``(n,)`` draw
        is not a prefix of a larger one under threefry)."""
        raise NotImplementedError

    def stream_draws(self, seed: int, stickiness, *, n_groups: int,
                     n_users: int, chunk: int | None = None):
        """Streamed :meth:`init_draws`: assembles ``(true0, rng, phase)``
        for ``n_users`` users from fixed-width :meth:`stream_chunk`
        calls (default width ``useraxis.DEFAULT_STREAM_CHUNK``), so the
        device never materializes more than one chunk and every chunk
        width compiles exactly one program (the tail chunk is padded to
        full width and sliced host-side)."""
        chunk = DEFAULT_STREAM_CHUNK if chunk is None else int(chunk)
        if chunk <= 0:
            raise ValueError(f"stream chunk must be positive, got {chunk}")
        true0 = np.empty((n_users,), np.int32)
        phase = np.empty((n_users,), np.int32)
        for lo in range(0, n_users, chunk):
            hi = min(lo + chunk, n_users)
            users = np.arange(lo, lo + chunk, dtype=np.int32)
            t0, ph = self.stream_chunk(seed, stickiness,
                                       n_groups=n_groups, users=users)
            true0[lo:hi] = np.asarray(t0, np.int32)[:hi - lo]
            phase[lo:hi] = np.asarray(ph, np.int32)[:hi - lo]
        return true0, self.stream_key(seed), phase

    def prepare(self, n_groups: int, stickiness):
        """Per-config constants used by :meth:`next_count`; traced once
        outside the scan (``stickiness`` may be a traced scalar)."""
        raise NotImplementedError

    def next_count(self, ctx, key, cur_count, user, pos):
        """Next true object count (scalar int32) for the dispatching
        user. ``ctx`` is :meth:`prepare`'s result; ``key`` a fresh
        threefry key; ``cur_count`` the user's current count; ``user``
        the dispatching user index; ``pos`` the user's absolute frame
        position (phase offset + dispatch number). Sources ignore the
        arguments they don't need — the Markov chain uses (key,
        cur_count), a trace uses (user, pos)."""
        raise NotImplementedError


# ------------------------------------------------- Markov (the default) --

def _init_draws_impl(seed, stickiness, *, n_groups: int, n_users: int):
    """Initial user states + scan key for one config, with the config's own
    ``n_users``-shaped categorical draw (the shape-sensitive part)."""
    P_trans = EST.markov_transition(n_groups, stickiness)
    rng = jax.random.PRNGKey(seed)
    k_init, rng = jax.random.split(rng)
    pi0 = EST.stationary(P_trans)
    true0 = jax.random.categorical(k_init, jnp.log(pi0 + 1e-9),
                                   shape=(n_users,))
    return true0.astype(i32), rng


_init_draws = functools.partial(jax.jit, static_argnames=(
    "n_groups", "n_users"))(_init_draws_impl)


@functools.partial(jax.jit, static_argnames=("n_groups",))
def _init_priors_batch(seeds, stickiness, *, n_groups: int):
    """Shape-independent half of the batched initial draw: per (seed,
    stickiness) key, the stationary distribution and the split threefry
    keys. One compile serves every ``n_users`` level — only the categorical
    draw below is shape-sensitive. Threefry is counter-based, so each row
    is bit-identical to its own scalar :func:`_init_draws` call."""

    def one(seed, stick):
        P_trans = EST.markov_transition(n_groups, stick)
        rng = jax.random.PRNGKey(seed)
        k_init, rng = jax.random.split(rng)
        return EST.stationary(P_trans), k_init, rng

    return jax.vmap(one)(seeds, stickiness)


@functools.partial(jax.jit, static_argnames=("n_users",))
def _init_categorical_batch(k_init, pi0, *, n_users: int):
    """Shape-sensitive half: the config's own ``n_users``-shaped
    categorical draw (cheap per-level compile), vmapped over keys."""
    return jax.vmap(lambda k, p: jax.random.categorical(
        k, jnp.log(p + 1e-9), shape=(n_users,)).astype(i32))(k_init, pi0)


@functools.partial(jax.jit, static_argnames=("n_groups",))
def _stream_chunk_markov(seed, stickiness, users, *, n_groups: int):
    """One streamed-draw chunk of the Markov initial states: user ``u``
    draws its stationary-categorical state under ``fold_in(k_init, u)``,
    so any chunking of the user axis reassembles bitwise. One compile
    per chunk width (``users.shape``)."""
    P_trans = EST.markov_transition(n_groups, stickiness)
    k_init, _ = jax.random.split(jax.random.PRNGKey(seed))
    logits = jnp.log(EST.stationary(P_trans) + 1e-9)
    true0 = jax.vmap(lambda u: jax.random.categorical(
        jax.random.fold_in(k_init, u), logits))(users)
    return true0.astype(i32), jnp.zeros(users.shape, i32)


def _pow2_pad(items: list) -> list:
    """Pad a work list to a power of two by repeating its head, bounding
    the set of compiled batch shapes to O(log n) per static signature."""
    return items + [items[0]] * ((1 << (len(items) - 1).bit_length())
                                 - len(items))


# (seed, stickiness, n_users, n_groups) -> (true0 (n_users,) i32, rng (2,)
# u32) as numpy. The draw depends on nothing else, and a Fig. 4 grid of 168
# configs has only 24 distinct triples — memoizing + batching misses per
# n_users level is what lets 10^5-config grids build in milliseconds.
_DRAW_CACHE: dict[DrawKey, tuple[np.ndarray, np.ndarray]] = {}
_DRAW_STATS = {"hits": 0, "misses": 0}


def grid_cache_info() -> dict[str, int]:
    """Stats for the Markov initial-draw cache behind ``make_grid``:
    per-config ``hits``/``misses`` counters and the number of distinct
    draws held (``size``). Process-wide; reset with
    :func:`grid_cache_clear`."""
    return dict(_DRAW_STATS, size=len(_DRAW_CACHE))


def grid_cache_clear() -> None:
    """Drop all memoized initial draws and zero the hit/miss counters."""
    _DRAW_CACHE.clear()
    _DRAW_STATS.update(hits=0, misses=0)


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class MarkovWorkload(WorkloadSource):
    """The synthetic default: per-user complexity evolves by the paper's
    first-order chain (``repro.core.estimator.markov_transition``), with
    initial states drawn from its stationary distribution. Stateless —
    the chain's stickiness is a per-config ``ConfigGrid`` leaf, so one
    instance serves every grid. Bit-identical to the pre-interface
    engine, draw memoization included."""

    def tree_flatten(self):
        return (), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls()

    def init_draws(self, seed, stickiness, *, n_groups, n_users):
        true0, rng = _init_draws(seed, stickiness, n_groups=n_groups,
                                 n_users=n_users)
        return (np.asarray(true0), np.asarray(rng),
                np.zeros((n_users,), np.int32))

    def grid_draws(self, keys):
        """Memoized + vectorised batch draw: misses are computed in one
        shape-independent vmapped program plus one tiny categorical draw
        per ``n_users`` level (work lists pow2-padded so repeated builds
        reuse O(log n) compiled shapes); hits are free."""
        missing = sorted({k for k in keys if k not in _DRAW_CACHE})
        _DRAW_STATS["misses"] += len(missing)
        _DRAW_STATS["hits"] += len(keys) - len(missing)
        if missing:
            padded = _pow2_pad(missing)
            G = missing[0][3]
            pi0, k_init, rngs = _init_priors_batch(
                jnp.asarray([k[0] for k in padded], i32),
                jnp.asarray([k[1] for k in padded], f32), n_groups=G)
            rngs = np.asarray(rngs)
            for nu in sorted({k[2] for k in missing}):
                idx = [i for i, k in enumerate(missing) if k[2] == nu]
                sel = jnp.asarray(_pow2_pad(idx), i32)
                t0s = np.asarray(_init_categorical_batch(
                    k_init[sel], pi0[sel], n_users=nu))
                for j, i in enumerate(idx):
                    _DRAW_CACHE[missing[i]] = (t0s[j], rngs[i])
        return {k: (*_DRAW_CACHE[k], np.zeros((k[2],), np.int32))
                for k in keys}

    def stream_chunk(self, seed, stickiness, *, n_groups, users):
        return _stream_chunk_markov(jnp.asarray(seed, i32),
                                    jnp.asarray(stickiness, f32),
                                    jnp.asarray(users, i32),
                                    n_groups=n_groups)

    def prepare(self, n_groups, stickiness):
        return EST.markov_transition(n_groups, stickiness)

    def next_count(self, ctx, key, cur_count, user, pos):
        return EST.markov_step(key, cur_count[None], ctx)[0]


_DEFAULT_WORKLOAD = MarkovWorkload()


def default_workload() -> MarkovWorkload:
    """The engine's default scene-complexity source (the Markov chain)."""
    return _DEFAULT_WORKLOAD
