"""The user axis at scale: block decomposition + segment-reduced
aggregation.

The paper evaluates up to 15 concurrent users per balancer; the ROADMAP
north star is millions. The engine's config axis already fuses thousands
of configurations into one device program, so the scaled user axis rides
it: a configuration with ``n_users = N`` and ``user_block = C`` is
decomposed into ``K = ceil(N / C)`` **user blocks** — independent
balancer replicas, each serving its contiguous slice of ≤ C users with
its own queue/estimator/dispatch state. Block rows are ordinary config
rows, so the whole fleet of replicas vmaps, shards over a mesh
(``shard_map`` splits blocks across devices — per-user queue and
workload state is literally sharded alongside configs) and fleet-stacks
with zero new engine machinery. Per-config metrics come back by
**segment reduction** over each config's contiguous block rows.

Reduction contract (pinned by ``tests/test_useraxis.py``): every
reduction here is a *left fold in index order*. ``jax.ops.segment_sum``
scatter-adds elements sequentially, which makes it bit-stable across
eager/jit and across the padded-dense and ragged-flat layouts of the
same values. A plain ``where(mask, x, 0).sum(-1)`` is NOT that — XLA
vectorizes row reductions with reassociation and drifts by float ULPs —
so the dense masked reduction (:func:`masked_user_sum`) is implemented
via the same segment fold (pad entries map to a dropped segment) rather
than ``jnp.sum``. That is what makes the segment-reduced aggregation
bit-equal to the dense masked reference, including all-padded and
single-user edge cases, and what keeps ``K = 1`` configs bit-identical
through the aggregation pass (a one-element fold, a divide by 1.0 and a
one-element max are all exact).

Aggregation semantics over a config's blocks
(:func:`aggregate_block_summaries`): blocks are balancer replicas
running *concurrently*, each over the same scan length, so

  * per-request means (latency, energy, mAP, estimator accuracy) are
    request-weighted means = uniform means over blocks (every block
    contributes the same number of post-warmup requests);
  * ``throughput_rps`` sums over blocks (independent replicas serve in
    parallel);
  * ``makespan_s`` is the max over blocks (the slowest replica);
  * ``latency_p90_ms`` is the **exact fleet-wide percentile of the merged
    latency histogram**: each block row emits a fixed-bin log-spaced
    histogram (:func:`latency_histogram`; counts are integer-valued
    float32, exact under addition to 2^24), the block histograms
    segment-sum into the config's pooled histogram, and
    :func:`histogram_p90` interpolates the percentile from the pooled
    counts. Because histogram merging is exact, the K-block aggregate is
    bit-identical to running the same estimator on the pooled dense
    latency set — partition-invariant by construction, with quantization
    bounded by the bin resolution (~0.5% relative at 4096 log bins over
    [1e-5, 1e4] s). Single-block configs keep the exact
    ``jnp.percentile`` passthrough (the golden fixtures pin it).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DEFAULT_STREAM_CHUNK", "HIST_BINS", "HIST_LO_S", "HIST_HI_S",
           "n_user_blocks", "block_sizes", "block_segments",
           "segment_user_sum", "segment_user_mean", "segment_user_max",
           "masked_user_sum", "masked_user_mean", "latency_histogram",
           "histogram_p90", "aggregate_block_summaries", "grid_nbytes"]

f32 = jnp.float32
i32 = jnp.int32

#: Default per-device-call chunk width for streamed workload draws
#: (``WorkloadSource.stream_draws``): bounds the largest single draw
#: program at ~256 KiB of int32 per leaf regardless of ``n_users``.
DEFAULT_STREAM_CHUNK = 65536


# ------------------------------------------------- block decomposition --

def n_user_blocks(n_users: int, user_block: int) -> int:
    """How many balancer-replica blocks a config of ``n_users`` splits
    into at block size ``user_block`` (at least 1)."""
    if user_block <= 0:
        raise ValueError(f"user_block must be positive, got {user_block}")
    return max(1, math.ceil(n_users / user_block))


def block_sizes(n_users: int, user_block: int) -> list[int]:
    """Users per block: ``user_block`` for every full block, the
    remainder on the last (``[N]`` when ``N <= user_block``)."""
    k = n_user_blocks(n_users, user_block)
    return [min(user_block, n_users - b * user_block) for b in range(k)]


def block_segments(blocks_per_cfg) -> np.ndarray:
    """Config-id segment vector for an expanded grid: config ``i``'s
    ``blocks_per_cfg[i]`` block rows are contiguous, so the segment ids
    are ``[0]*K0 + [1]*K1 + ...`` (int32)."""
    return np.repeat(np.arange(len(blocks_per_cfg), dtype=np.int32),
                     np.asarray(blocks_per_cfg, np.int64))


# ------------------------------------------- canonical left-fold sums --

def segment_user_sum(values, segments, num_segments: int):
    """Segment sum over the LEADING axis, accumulated as a left fold in
    index order (``jax.ops.segment_sum``'s scatter-add order) — the one
    canonical reduction every user-axis aggregation goes through."""
    return jax.ops.segment_sum(jnp.asarray(values),
                               jnp.asarray(segments, i32),
                               num_segments=num_segments)


def segment_user_mean(values, segments, num_segments: int):
    """Left-fold segment mean; empty segments give 0 (safe divide), a
    one-element segment passes its value through bitwise (``x / 1.0``)."""
    values = jnp.asarray(values)
    seg = jnp.asarray(segments, i32)
    total = segment_user_sum(values, seg, num_segments)
    count = segment_user_sum(jnp.ones(seg.shape, values.dtype), seg,
                             num_segments)
    shape = count.shape + (1,) * (total.ndim - count.ndim)
    count = count.reshape(shape)
    return total / jnp.maximum(count, jnp.ones((), values.dtype))


def segment_user_max(values, segments, num_segments: int):
    """Segment max over the leading axis; empty segments give 0 (not
    ``-inf`` — the aggregation consumers treat absent as zero work)."""
    out = jax.ops.segment_max(jnp.asarray(values),
                              jnp.asarray(segments, i32),
                              num_segments=num_segments)
    return jnp.where(jnp.isneginf(out), jnp.zeros((), out.dtype), out)


def masked_user_sum(values, n_users):
    """Dense masked per-user reduction: ``values`` is ``(B, U)`` padded,
    row ``b``'s live entries are ``values[b, :n_users[b]]``; returns the
    ``(B,)`` per-row sums.

    Implemented via the SAME left fold as :func:`segment_user_sum` — pad
    entries map to segment ``B``, which is dropped — so it is bit-equal
    to the segment reduction of the ragged flat layout (property-tested
    in ``tests/test_useraxis.py``). ``where(mask, v, 0).sum(-1)`` would
    NOT be: XLA reassociates vectorized row sums.
    """
    values = jnp.asarray(values)
    if values.ndim != 2:
        raise ValueError(f"masked_user_sum wants (B, U), got "
                         f"{values.shape}")
    b, u = values.shape
    live = jnp.arange(u) < jnp.asarray(n_users, i32)[:, None]
    seg = jnp.where(live, jnp.arange(b, dtype=i32)[:, None], b)
    return jax.ops.segment_sum(values.reshape(-1), seg.reshape(-1),
                               num_segments=b)


def masked_user_mean(values, n_users):
    """Dense masked per-user mean (all-padded rows give 0); bit-equal to
    :func:`segment_user_mean` on the ragged layout."""
    n = jnp.asarray(n_users, i32)
    total = masked_user_sum(values, n)
    count = n.astype(jnp.asarray(values).dtype)
    return total / jnp.maximum(count, jnp.ones((), count.dtype))


# ------------------------------------------ latency histogram merge -----

#: Fixed latency histogram geometry: log-spaced bins over
#: [``HIST_LO_S``, ``HIST_HI_S``] seconds. 4096 bins over 9 decades is
#: ~0.5% relative resolution — far below the seed-to-seed noise of any
#: percentile metric — while one histogram is a 16 KiB leaf.
HIST_BINS = 4096
HIST_LO_S = 1e-5
HIST_HI_S = 1e4

_LOG_LO = math.log(HIST_LO_S)
_LOG_SPAN = math.log(HIST_HI_S) - math.log(HIST_LO_S)


def _hist_edges():
    """The NB+1 bin edges in seconds (float64 host-side geometry)."""
    return np.exp(_LOG_LO + _LOG_SPAN * np.arange(HIST_BINS + 1)
                  / HIST_BINS)


def latency_histogram(latencies):
    """Fixed-bin log-histogram of a latency sample (seconds) -> ``(NB,)``
    float32 counts. Counts are integer-valued float32, so histograms add
    EXACTLY (up to 2^24 total requests per config) — the property that
    makes the K-block percentile merge partition-invariant. Out-of-range
    samples clamp into the edge bins."""
    lat = jnp.asarray(latencies, f32).reshape(-1)
    idx = jnp.floor((jnp.log(jnp.maximum(lat, HIST_LO_S)) - _LOG_LO)
                    / _LOG_SPAN * HIST_BINS).astype(i32)
    idx = jnp.clip(idx, 0, HIST_BINS - 1)
    return jax.ops.segment_sum(jnp.ones(lat.shape, f32), idx,
                               num_segments=HIST_BINS)


def histogram_p90(hist, q: float = 90.0):
    """Percentile (default p90) of a ``(..., NB)`` latency histogram, in
    seconds: fractional rank ``q/100 * (n - 1)`` (``jnp.percentile``'s
    'linear' convention), located by the count CDF and linearly
    interpolated inside its bin. A deterministic pure function of the
    counts — so ``histogram_p90(sum_k hist_k)`` is bit-identical to the
    single-shot histogram of the pooled sample."""
    h = jnp.asarray(hist, f32)
    edges = jnp.asarray(_hist_edges(), f32)
    cum = jnp.cumsum(h, axis=-1)
    n = cum[..., -1:]
    rank = q / 100.0 * jnp.maximum(n - 1.0, 0.0)
    k = jnp.argmax(cum > rank, axis=-1)
    cum_before = jnp.take_along_axis(cum, k[..., None], -1) \
        - jnp.take_along_axis(h, k[..., None], -1)
    in_bin = jnp.take_along_axis(h, k[..., None], -1)
    frac = (rank - cum_before + 0.5) / jnp.maximum(in_bin, 1.0)
    frac = jnp.clip(frac, 0.0, 1.0)
    left = edges[k][..., None]
    right = edges[k + 1][..., None]
    return (left + frac * (right - left))[..., 0]


# --------------------------------------------- block-row aggregation ----

#: Summary metrics that SUM over a config's blocks (independent balancer
#: replicas serving concurrently) instead of averaging.
_SUM_METRICS = frozenset({"throughput_rps"})
#: Summary metrics that take the MAX over blocks (slowest replica).
_MAX_METRICS = frozenset({"makespan_s"})


def aggregate_block_summaries(out: dict, segments, num_configs: int,
                              block_axis: int = -1) -> dict:
    """Fold per-block summary metrics back to per-config metrics.

    ``out`` maps metric name -> array whose ``block_axis`` (default:
    trailing, the engine's config axis) runs over the expanded block
    rows; ``segments`` maps each block row to its config. Means stay
    means (uniform over blocks — every block contributes equally many
    requests), throughput sums, makespan maxes; see the module docstring
    for the exact contract. A config with a single block passes through
    bit-identically.

    When ``out`` carries a ``latency_hist`` leaf (bin axis trailing,
    block rows at ``block_axis`` counted from the metric leaves — i.e.
    one axis further in), ``latency_p90_ms`` is recomputed for
    multi-block configs as the exact percentile of the segment-summed
    histogram (:func:`histogram_p90`); single-block configs keep their
    ``jnp.percentile`` value bit-identically. The histogram leaf is
    consumed, not returned.
    """
    out = dict(out)
    hist = out.pop("latency_hist", None)
    seg = jnp.asarray(segments, i32)
    if int(seg.shape[0]) == num_configs:
        # K = 1 everywhere: the expanded grid IS the config grid
        return out

    def lead(v, axis=block_axis):
        return jnp.moveaxis(jnp.asarray(v), axis, 0)

    def unlead(v, axis=block_axis):
        return jnp.moveaxis(v, 0, axis)

    agg = {}
    for k, v in out.items():
        if k in _SUM_METRICS:
            agg[k] = unlead(segment_user_sum(lead(v), seg, num_configs))
        elif k in _MAX_METRICS:
            agg[k] = unlead(segment_user_max(lead(v), seg, num_configs))
        else:
            agg[k] = unlead(segment_user_mean(lead(v), seg, num_configs))
    if hist is not None:
        # the histogram's block axis sits one slot before its trailing
        # bin axis relative to the scalar metric leaves
        haxis = block_axis - 1 if block_axis < 0 else block_axis
        merged = segment_user_sum(lead(hist, haxis), seg, num_configs)
        p90_ms = 1000.0 * unlead(histogram_p90(merged), block_axis)
        bpc = segment_user_sum(jnp.ones((seg.shape[0],), f32), seg,
                               num_configs)
        agg["latency_p90_ms"] = jnp.where(bpc == 1.0,
                                          agg["latency_p90_ms"], p90_ms)
    return agg


# ------------------------------------------------- memory accounting ----

def grid_nbytes(grid) -> int:
    """Total bytes of a grid pytree's leaves — the array-size accounting
    the memory-ceiling tests assert on (RSS is too noisy to gate). The
    blocked layout keeps this at ``O(total_users)``: a 10^6-user config
    is ~8 MB of int32 leaves instead of an ``n_configs × n_users_max``
    dense pad."""
    return int(sum(np.prod(leaf.shape) * np.dtype(leaf.dtype).itemsize
                   for leaf in jax.tree_util.tree_leaves(grid)))
