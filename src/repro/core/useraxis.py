"""The user axis at scale: block decomposition + segment-reduced
aggregation.

The paper evaluates up to 15 concurrent users per balancer; the ROADMAP
north star is millions. The engine's config axis already fuses thousands
of configurations into one device program, so the scaled user axis rides
it: a configuration with ``n_users = N`` and ``user_block = C`` is
decomposed into ``K = ceil(N / C)`` **user blocks** — independent
balancer replicas, each serving its contiguous slice of ≤ C users with
its own queue/estimator/dispatch state. Block rows are ordinary config
rows, so the whole fleet of replicas vmaps, shards over a mesh
(``shard_map`` splits blocks across devices — per-user queue and
workload state is literally sharded alongside configs) and fleet-stacks
with zero new engine machinery. Per-config metrics come back by
**segment reduction** over each config's contiguous block rows.

Reduction contract (pinned by ``tests/test_useraxis.py``): every
reduction here is a *left fold in index order*. ``jax.ops.segment_sum``
scatter-adds elements sequentially, which makes it bit-stable across
eager/jit and across the padded-dense and ragged-flat layouts of the
same values. A plain ``where(mask, x, 0).sum(-1)`` is NOT that — XLA
vectorizes row reductions with reassociation and drifts by float ULPs —
so the dense masked reduction (:func:`masked_user_sum`) is implemented
via the same segment fold (pad entries map to a dropped segment) rather
than ``jnp.sum``. That is what makes the segment-reduced aggregation
bit-equal to the dense masked reference, including all-padded and
single-user edge cases, and what keeps ``K = 1`` configs bit-identical
through the aggregation pass (a one-element fold, a divide by 1.0 and a
one-element max are all exact).

Aggregation semantics over a config's blocks
(:func:`aggregate_block_summaries`): blocks are balancer replicas
running *concurrently*, each over the same scan length, so

  * per-request means (latency, energy, mAP, estimator accuracy) are
    request-weighted means = uniform means over blocks (every block
    contributes the same number of post-warmup requests);
  * ``throughput_rps`` sums over blocks (independent replicas serve in
    parallel);
  * ``makespan_s`` is the max over blocks (the slowest replica);
  * ``latency_p90_ms`` is the mean of per-block p90s — a documented
    approximation (the exact fleet-wide percentile would need the full
    ``(K, n_requests)`` latency set that block summaries exist to avoid).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DEFAULT_STREAM_CHUNK", "n_user_blocks", "block_sizes",
           "block_segments", "segment_user_sum", "segment_user_mean",
           "segment_user_max", "masked_user_sum", "masked_user_mean",
           "aggregate_block_summaries", "grid_nbytes"]

f32 = jnp.float32
i32 = jnp.int32

#: Default per-device-call chunk width for streamed workload draws
#: (``WorkloadSource.stream_draws``): bounds the largest single draw
#: program at ~256 KiB of int32 per leaf regardless of ``n_users``.
DEFAULT_STREAM_CHUNK = 65536


# ------------------------------------------------- block decomposition --

def n_user_blocks(n_users: int, user_block: int) -> int:
    """How many balancer-replica blocks a config of ``n_users`` splits
    into at block size ``user_block`` (at least 1)."""
    if user_block <= 0:
        raise ValueError(f"user_block must be positive, got {user_block}")
    return max(1, math.ceil(n_users / user_block))


def block_sizes(n_users: int, user_block: int) -> list[int]:
    """Users per block: ``user_block`` for every full block, the
    remainder on the last (``[N]`` when ``N <= user_block``)."""
    k = n_user_blocks(n_users, user_block)
    return [min(user_block, n_users - b * user_block) for b in range(k)]


def block_segments(blocks_per_cfg) -> np.ndarray:
    """Config-id segment vector for an expanded grid: config ``i``'s
    ``blocks_per_cfg[i]`` block rows are contiguous, so the segment ids
    are ``[0]*K0 + [1]*K1 + ...`` (int32)."""
    return np.repeat(np.arange(len(blocks_per_cfg), dtype=np.int32),
                     np.asarray(blocks_per_cfg, np.int64))


# ------------------------------------------- canonical left-fold sums --

def segment_user_sum(values, segments, num_segments: int):
    """Segment sum over the LEADING axis, accumulated as a left fold in
    index order (``jax.ops.segment_sum``'s scatter-add order) — the one
    canonical reduction every user-axis aggregation goes through."""
    return jax.ops.segment_sum(jnp.asarray(values),
                               jnp.asarray(segments, i32),
                               num_segments=num_segments)


def segment_user_mean(values, segments, num_segments: int):
    """Left-fold segment mean; empty segments give 0 (safe divide), a
    one-element segment passes its value through bitwise (``x / 1.0``)."""
    values = jnp.asarray(values)
    seg = jnp.asarray(segments, i32)
    total = segment_user_sum(values, seg, num_segments)
    count = segment_user_sum(jnp.ones(seg.shape, values.dtype), seg,
                             num_segments)
    shape = count.shape + (1,) * (total.ndim - count.ndim)
    count = count.reshape(shape)
    return total / jnp.maximum(count, jnp.ones((), values.dtype))


def segment_user_max(values, segments, num_segments: int):
    """Segment max over the leading axis; empty segments give 0 (not
    ``-inf`` — the aggregation consumers treat absent as zero work)."""
    out = jax.ops.segment_max(jnp.asarray(values),
                              jnp.asarray(segments, i32),
                              num_segments=num_segments)
    return jnp.where(jnp.isneginf(out), jnp.zeros((), out.dtype), out)


def masked_user_sum(values, n_users):
    """Dense masked per-user reduction: ``values`` is ``(B, U)`` padded,
    row ``b``'s live entries are ``values[b, :n_users[b]]``; returns the
    ``(B,)`` per-row sums.

    Implemented via the SAME left fold as :func:`segment_user_sum` — pad
    entries map to segment ``B``, which is dropped — so it is bit-equal
    to the segment reduction of the ragged flat layout (property-tested
    in ``tests/test_useraxis.py``). ``where(mask, v, 0).sum(-1)`` would
    NOT be: XLA reassociates vectorized row sums.
    """
    values = jnp.asarray(values)
    if values.ndim != 2:
        raise ValueError(f"masked_user_sum wants (B, U), got "
                         f"{values.shape}")
    b, u = values.shape
    live = jnp.arange(u) < jnp.asarray(n_users, i32)[:, None]
    seg = jnp.where(live, jnp.arange(b, dtype=i32)[:, None], b)
    return jax.ops.segment_sum(values.reshape(-1), seg.reshape(-1),
                               num_segments=b)


def masked_user_mean(values, n_users):
    """Dense masked per-user mean (all-padded rows give 0); bit-equal to
    :func:`segment_user_mean` on the ragged layout."""
    n = jnp.asarray(n_users, i32)
    total = masked_user_sum(values, n)
    count = n.astype(jnp.asarray(values).dtype)
    return total / jnp.maximum(count, jnp.ones((), count.dtype))


# --------------------------------------------- block-row aggregation ----

#: Summary metrics that SUM over a config's blocks (independent balancer
#: replicas serving concurrently) instead of averaging.
_SUM_METRICS = frozenset({"throughput_rps"})
#: Summary metrics that take the MAX over blocks (slowest replica).
_MAX_METRICS = frozenset({"makespan_s"})


def aggregate_block_summaries(out: dict, segments, num_configs: int,
                              block_axis: int = -1) -> dict:
    """Fold per-block summary metrics back to per-config metrics.

    ``out`` maps metric name -> array whose ``block_axis`` (default:
    trailing, the engine's config axis) runs over the expanded block
    rows; ``segments`` maps each block row to its config. Means stay
    means (uniform over blocks — every block contributes equally many
    requests), throughput sums, makespan maxes; see the module docstring
    for the exact contract. A config with a single block passes through
    bit-identically.
    """
    seg = jnp.asarray(segments, i32)
    if int(seg.shape[0]) == num_configs:
        # K = 1 everywhere: the expanded grid IS the config grid
        return dict(out)

    def lead(v):
        return jnp.moveaxis(jnp.asarray(v), block_axis, 0)

    def unlead(v):
        return jnp.moveaxis(v, 0, block_axis)

    agg = {}
    for k, v in out.items():
        if k in _SUM_METRICS:
            agg[k] = unlead(segment_user_sum(lead(v), seg, num_configs))
        elif k in _MAX_METRICS:
            agg[k] = unlead(segment_user_max(lead(v), seg, num_configs))
        else:
            agg[k] = unlead(segment_user_mean(lead(v), seg, num_configs))
    return agg


# ------------------------------------------------- memory accounting ----

def grid_nbytes(grid) -> int:
    """Total bytes of a grid pytree's leaves — the array-size accounting
    the memory-ceiling tests assert on (RSS is too noisy to gate). The
    blocked layout keeps this at ``O(total_users)``: a 10^6-user config
    is ~8 MB of int32 leaves instead of an ``n_configs × n_users_max``
    dense pad."""
    return int(sum(np.prod(leaf.shape) * np.dtype(leaf.dtype).itemsize
                   for leaf in jax.tree_util.tree_leaves(grid)))
