"""TPU serving-cell energy / latency model.

The paper profiles each device-model pair with a USB power meter. This
container has no TPUs, so the TPU analogue derives ProfileTable entries from
the *compiled dry-run artifacts*: step time from the three roofline terms,
utilisation from the compute term's share, power from a linear
idle->peak model. The interface is identical, so measured profiles can be
dropped in on real hardware.

Numbers (TPU v5e, public): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI; chip power ~ idle 70 W -> peak 170 W (board-level estimates).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.profiles import ProfileTable
from repro.roofline.hw import V5E


@dataclass(frozen=True)
class CellModel:
    """One TPU serving cell: a (model variant, slice, batching) triple with
    roofline terms per complexity group (seconds)."""
    name: str
    chips: int
    t_compute: tuple[float, ...]     # per-group compute-roofline seconds
    t_memory: tuple[float, ...]
    t_collective: tuple[float, ...] = ()


def step_time_s(t_compute: float, t_memory: float,
                t_collective: float) -> float:
    """Perfect-overlap lower bound: the dominant term is the step time.
    (No-overlap upper bound = sum; both are reported in benchmarks.)"""
    return max(t_compute, t_memory, t_collective)


def chip_power_w(util: float, idle_w: float = V5E.idle_w,
                 peak_w: float = V5E.peak_w) -> float:
    return idle_w + (peak_w - idle_w) * min(max(util, 0.0), 1.0)


def energy_mwh(step_s: float, util: float, chips: int) -> float:
    """Energy above idle per request (paper convention: idle base excluded)."""
    active_w = (chip_power_w(util) - V5E.idle_w) * chips
    return active_w * step_s / 3600.0 * 1000.0


def derive_tpu_profile(cells, accuracy_table) -> ProfileTable:
    """cells: list of dicts with name, chips, and per-group roofline terms
    {t_compute:[G], t_memory:[G], t_collective:[G]}; accuracy_table: (P,G)
    mAP. Returns a ProfileTable usable by the balancer/simulator unchanged --
    the paper's technique transplanted onto a TPU fleet."""
    P = len(cells)
    G = len(cells[0]["t_compute"])
    T = np.zeros((P, G))
    E = np.zeros((P, G))
    floor = np.zeros((P,))
    names = []
    for i, c in enumerate(cells):
        names.append(c["name"])
        floor[i] = 0.05 * V5E.idle_w * c["chips"] * 1000.0 / 1000.0  # mW
        for g in range(G):
            ts = step_time_s(c["t_compute"][g], c["t_memory"][g],
                             c["t_collective"][g])
            util = c["t_compute"][g] / max(ts, 1e-12)
            T[i, g] = ts * 1000.0
            E[i, g] = energy_mwh(ts, util, c["chips"])
    return ProfileTable(jnp.asarray(T), jnp.asarray(E),
                        jnp.asarray(accuracy_table), tuple(names),
                        jnp.asarray(floor))
