"""Hierarchical (two-level) load balancing — the paper's §V scalability
limit addressed (also listed as future work §VII).

A single central gateway is O(P) per request and a throughput bottleneck at
thousands of cells. The hierarchical design:

  level 1 (global): pick a *pod* by Algorithm 1 over pod-aggregate profiles
           (min-T/min-E/max-mAP per group across the pod's cells, queue =
           total outstanding of the pod, refreshed at sync_interval);
  level 2 (local):  the pod's own gateway runs Algorithm 1 over its cells
           with exact local queues.

Staleness of the level-1 queue snapshot is the price of decentralisation;
``tests/test_hierarchy.py`` bounds the regret vs the flat balancer."""

from __future__ import annotations


import jax.numpy as jnp
import numpy as np

from repro.core.policies import mo_scores
from repro.core.profiles import ProfileTable

f32 = jnp.float32


def pod_aggregate(prof: ProfileTable, pod_of_pair) -> ProfileTable:
    """Aggregate per-pair profiles into per-pod profiles.
    pod_of_pair: (P,) int32 pod id per pair (a CONCRETE array — the pod
    layout is deployment topology, known host-side; n_pods = max+1 is a
    static shape, so it is computed with numpy and stays usable inside
    jitted callers)."""
    n_pods = int(np.max(np.asarray(pod_of_pair))) + 1
    P, G = prof.T.shape

    def agg(col_min, table):
        out = []
        for k in range(n_pods):
            m = pod_of_pair == k
            big = jnp.where(m[:, None], table, jnp.inf if col_min else -jnp.inf)
            out.append(jnp.min(big, 0) if col_min else jnp.max(big, 0))
        return jnp.stack(out)

    return ProfileTable(agg(True, prof.T), agg(True, prof.E),
                        agg(False, prof.mAP),
                        tuple(f"pod{k}" for k in range(n_pods)))


def hierarchical_select(prof: ProfileTable, pod_prof: ProfileTable,
                        pod_of_pair, g, q_exact, q_pod_stale, *,
                        delta: float = 20.0, gamma: float = 0.5,
                        penalty=None):
    """Two-level Algorithm 1. q_exact: (P,) local queues (only the chosen
    pod's slice is consulted); q_pod_stale: (n_pods,) last-synced totals.
    ``penalty`` (optional, (P,) ms) is the cloud tier's per-pair uplink
    congestion term, applied at level 2 where exact queues live — the
    edge-cluster-under-global-balancer shape puts the cloud pairs in
    their own pod, so the level-1 choice already separates
    offload-vs-local."""
    Jp, _ = mo_scores(pod_prof.T[:, g], pod_prof.E[:, g], pod_prof.mAP[:, g],
                      q_pod_stale, delta=delta, gamma=gamma)
    pod = jnp.argmin(Jp)
    in_pod = pod_of_pair == pod
    T_g = jnp.where(in_pod, prof.T[:, g], jnp.inf)
    E_g = jnp.where(in_pod, prof.E[:, g], jnp.inf)
    mAP_g = jnp.where(in_pod, prof.mAP[:, g], -jnp.inf)
    J, _ = mo_scores(T_g, E_g, mAP_g, q_exact, delta=delta, gamma=gamma,
                     penalty=penalty)
    return jnp.argmin(J), pod
