"""Closed-loop discrete-event simulator of the heterogeneous serving fleet
(paper §IV: Locust-style concurrency — each of U users has exactly one
request in flight; the next request of a stream is issued when the previous
response returns).

Implemented as one ``lax.scan`` over dispatch events, so a full concurrency
sweep across all seven policies jits once and runs in milliseconds — the
property that lets the benchmarks sweep thousands of configurations and the
tests assert the paper's orderings statistically.

Faithfulness notes:
  * service time / energy / accuracy are drawn from ``ProfileTable`` at the
    *true* complexity group; the policy only sees the *estimated* group
    (output-based estimator, paper §III-B.1), so estimator staleness and
    accuracy-dependent undercounting are modelled;
  * queue depths q[p] are exact (outstanding requests at dispatch time);
  * reported energy = per-request profile energy + the amortised active-floor
    power of the fleet (reproduces Fig. 4e/5d's decreasing energy curves).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator as EST
from repro.core.policies import POLICY_CODES, policy_scores
from repro.core.profiles import ProfileTable

f32 = jnp.float32
i32 = jnp.int32


@dataclass(frozen=True)
class SimConfig:
    n_users: int = 15
    n_requests: int = 2000
    policy: str = "MO"
    gamma: float = 0.5
    delta: float = 20.0   # headline tolerance (paper leaves Δ_mAP to the
                          # operator; 20 pts reproduces the Fig.4 trade-off)
    stickiness: float = 0.85
    seed: int = 0
    warmup_frac: float = 0.1
    oracle_estimator: bool = False   # ablation: g_est = g_true (perfect
                                     # complexity knowledge; benchmarks)


def simulate(prof: ProfileTable, cfg: SimConfig):
    """Returns a dict of per-request record arrays (length n_requests)."""
    P = prof.n_pairs
    G = prof.n_groups
    U = cfg.n_users
    code = POLICY_CODES[cfg.policy]
    P_trans = EST.markov_transition(G, cfg.stickiness)
    rng = jax.random.PRNGKey(cfg.seed)
    k_init, rng = jax.random.split(rng)

    pi0 = EST.stationary(P_trans)
    true0 = jax.random.categorical(k_init, jnp.log(pi0 + 1e-9), shape=(U,))

    carry = {
        "t_next": jnp.arange(U, dtype=f32) * 1e-4,
        "true_cnt": true0.astype(i32),
        "est_cnt": true0.astype(i32),
        "server_by_user": jnp.full((U,), -1, i32),
        "finish_by_user": jnp.zeros((U,), f32),
        "avail": jnp.zeros((P,), f32),
        "rr": jnp.zeros((), i32),
        "rng": rng,
    }

    gamma = jnp.asarray(cfg.gamma, f32)
    delta = jnp.asarray(cfg.delta, f32)

    def step(c, _):
        u = jnp.argmin(c["t_next"])
        t = c["t_next"][u]
        rng, k1, k2, k3 = jax.random.split(c["rng"], 4)

        new_true = EST.markov_step(k1, c["true_cnt"][u][None], P_trans)[0]
        g_true = EST.group_of_count(new_true, G)
        g_est = g_true if cfg.oracle_estimator \
            else EST.group_of_count(c["est_cnt"][u], G)

        active = (c["finish_by_user"] > t) & (c["server_by_user"] >= 0)
        q = jnp.zeros((P,), f32).at[c["server_by_user"]].add(
            active.astype(f32), mode="drop")

        scores = policy_scores(code, prof, g_est, q, k2, c["rr"] % P,
                               gamma, delta)
        p = jnp.argmin(scores).astype(i32)

        t_serv = prof.T[p, g_true] / 1000.0                   # ms -> s
        start = jnp.maximum(t, c["avail"][p])
        finish = start + t_serv

        detected = EST.noisy_detected_count(k3, new_true, prof.mAP[p, g_true])

        nc = dict(c)
        nc["rng"] = rng
        nc["true_cnt"] = c["true_cnt"].at[u].set(new_true.astype(i32))
        nc["est_cnt"] = c["est_cnt"].at[u].set(detected)
        nc["server_by_user"] = c["server_by_user"].at[u].set(p)
        nc["finish_by_user"] = c["finish_by_user"].at[u].set(finish)
        nc["avail"] = c["avail"].at[p].set(finish)
        nc["t_next"] = c["t_next"].at[u].set(finish)
        nc["rr"] = c["rr"] + 1

        rec = {
            "t_arrival": t,
            "latency": finish - t,
            "energy": prof.E[p, g_true],
            "map": prof.mAP[p, g_true],
            "server": p,
            "g_true": g_true,
            "g_est": g_est,
            "q_at_dispatch": q[p],
            "correct_group": (g_true == g_est).astype(f32),
        }
        return nc, rec

    _, recs = jax.lax.scan(step, carry, None, length=cfg.n_requests)
    return recs


def summarize(recs, prof: ProfileTable, cfg: SimConfig):
    """Aggregate a record set into the paper's Fig. 4/5 metrics."""
    n = recs["latency"].shape[0]
    w = int(n * cfg.warmup_frac)
    sl = {k: v[w:] for k, v in recs.items()}
    makespan = jnp.max(sl["t_arrival"] + sl["latency"]) - jnp.min(sl["t_arrival"])
    n_eff = n - w
    floor = prof.floor_mw if prof.floor_mw is not None \
        else jnp.zeros((prof.n_pairs,))
    floor_mwh = jnp.sum(floor) * makespan / 3600.0
    return {
        "latency_ms": 1000.0 * jnp.mean(sl["latency"]),
        "latency_p90_ms": 1000.0 * jnp.percentile(sl["latency"], 90),
        "throughput_rps": n_eff / makespan,
        "energy_mwh": jnp.mean(sl["energy"]) + floor_mwh / n_eff,
        "energy_compute_mwh": jnp.mean(sl["energy"]),
        "map": jnp.mean(sl["map"]),
        "estimator_acc": jnp.mean(sl["correct_group"]),
        "makespan_s": makespan,
    }


def run_policy(prof: ProfileTable, policy: str, n_users: int,
               n_requests: int = 2000, gamma: float = 0.5,
               delta: float = 20.0, seed: int = 0, stickiness: float = 0.85):
    cfg = SimConfig(n_users=n_users, n_requests=n_requests, policy=policy,
                    gamma=gamma, delta=delta, seed=seed,
                    stickiness=stickiness)
    recs = simulate(prof, cfg)
    out = summarize(recs, prof, cfg)
    return {k: float(v) for k, v in out.items()}


def sweep(prof: ProfileTable, policies, user_levels, n_requests: int = 2000,
          gamma: float = 0.5, delta: float = 20.0, seeds=(0, 1, 2)):
    """Full Fig. 4-style sweep; returns {policy: {metric: [per-level mean]}}.
    Each configuration runs ``len(seeds)`` times (paper: 3 repetitions)."""
    out: dict[str, dict[str, list[float]]] = {}
    for pol in policies:
        out[pol] = {}
        for nu in user_levels:
            vals = [run_policy(prof, pol, nu, n_requests, gamma, delta, s)
                    for s in seeds]
            for k in vals[0]:
                out[pol].setdefault(k, []).append(
                    float(np.mean([v[k] for v in vals])))
    return out
