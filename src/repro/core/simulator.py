"""Closed-loop discrete-event simulator of the heterogeneous serving fleet
(paper §IV: Locust-style concurrency — each of U users has exactly one
request in flight; the next request of a stream is issued when the previous
response returns).

Implemented as one ``lax.scan`` over dispatch events whose per-config
parameters (policy code, γ, Δ, stickiness, RNG state) are *traced*
arguments, so an entire Fig. 4-style grid — policy × concurrency × γ ×
seed — runs as ONE ``jax.vmap``-ped scan inside ONE jit
(:func:`simulate_batch` / :func:`sweep_grid`): a single device program
instead of one trace + launch per configuration. Differing concurrency
levels share the trace by padding users to ``n_users_max`` and masking the
padded streams to ``t = +inf`` so they never dispatch.

Bit-exactness across batching: jax's threefry draws are not prefix-stable
across shapes (the first U samples of a ``(U_max,)`` draw differ from a
``(U,)`` draw), so the initial per-user complexity states are drawn
per-config at grid-build time (:func:`make_grid`) with each config's own
``n_users`` shape and passed into the scan as data. Every other draw in
the loop is shape-independent, which makes a padded batched run reproduce
each config's unpadded trajectory exactly.

Faithfulness notes:
  * service time / energy / accuracy are drawn from ``ProfileTable`` at the
    *true* complexity group; the policy only sees the *estimated* group
    (output-based estimator, paper §III-B.1), so estimator staleness and
    accuracy-dependent undercounting are modelled;
  * queue depths q[p] are exact (outstanding requests at dispatch time);
  * reported energy = per-request profile energy + the amortised active-floor
    power of the fleet (reproduces Fig. 4e/5d's decreasing energy curves).
"""

from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import estimator as EST
from repro.core.policies import POLICY_CODES, policy_scores
from repro.core.profiles import ProfileTable

f32 = jnp.float32
i32 = jnp.int32


@dataclass(frozen=True)
class SimConfig:
    n_users: int = 15
    n_requests: int = 2000
    policy: str = "MO"
    gamma: float = 0.5
    delta: float = 20.0   # headline tolerance (paper leaves Δ_mAP to the
                          # operator; 20 pts reproduces the Fig.4 trade-off)
    stickiness: float = 0.85
    seed: int = 0
    warmup_frac: float = 0.1
    oracle_estimator: bool = False   # ablation: g_est = g_true (perfect
                                     # complexity knowledge; benchmarks)


class ConfigGrid(NamedTuple):
    """Struct-of-arrays batch of simulator configs — the traced leaves of a
    ``SimConfig``. All fields have leading dim (B,); ``rng`` is the (B, 2)
    uint32 scan key and ``true0`` the (B, n_users_max) initial true object
    counts, both drawn host-side per config (see module docstring).
    ``simulate`` also uses it batch-less (scalar leaves, (U,) true0) so
    single and vmapped paths share one by-name field access path."""

    policy_code: jax.Array      # (B,) int32 index into POLICY_CODES
    n_users: jax.Array          # (B,) int32 live concurrency (<= n_users_max)
    gamma: jax.Array            # (B,) float32
    delta: jax.Array            # (B,) float32
    stickiness: jax.Array       # (B,) float32
    oracle: jax.Array           # (B,) bool   g_est = g_true ablation
    rng: jax.Array              # (B, 2) uint32
    true0: jax.Array            # (B, n_users_max) int32

    @property
    def n_configs(self) -> int:
        return int(self.policy_code.shape[0]) if self.policy_code.ndim \
            else 1

    @property
    def n_users_max(self) -> int:
        return int(self.true0.shape[-1])


@functools.partial(jax.jit, static_argnames=("n_groups", "n_users"))
def _init_draws(seed, stickiness, *, n_groups: int, n_users: int):
    """Initial user states + scan key for one config, with the config's own
    ``n_users``-shaped categorical draw (the shape-sensitive part)."""
    P_trans = EST.markov_transition(n_groups, stickiness)
    rng = jax.random.PRNGKey(seed)
    k_init, rng = jax.random.split(rng)
    pi0 = EST.stationary(P_trans)
    true0 = jax.random.categorical(k_init, jnp.log(pi0 + 1e-9),
                                   shape=(n_users,))
    return true0.astype(i32), rng


def make_grid(prof: ProfileTable, configs,
              n_users_max: int | None = None) -> ConfigGrid:
    """Pack an iterable of ``SimConfig`` into a padded ``ConfigGrid``.

    ``n_requests``/``warmup_frac`` are scan-shape parameters, not grid
    leaves — all configs in one batch must agree on them (they are passed
    separately to :func:`simulate_batch` / :func:`summarize_batch`)."""
    cfgs = list(configs)
    if not cfgs:
        raise ValueError("empty config grid")
    if len({(c.n_requests, c.warmup_frac) for c in cfgs}) > 1:
        raise ValueError(
            "configs in one grid must agree on n_requests/warmup_frac "
            "(they are scan-shape parameters, passed separately to "
            "simulate_batch/summarize_batch)")
    U = max(c.n_users for c in cfgs) if n_users_max is None else n_users_max
    true0 = np.zeros((len(cfgs), U), np.int32)
    rngs = np.zeros((len(cfgs), 2), np.uint32)
    for i, c in enumerate(cfgs):
        t0, r = _init_draws(c.seed, c.stickiness,
                            n_groups=prof.n_groups, n_users=c.n_users)
        true0[i, :c.n_users] = np.asarray(t0)
        rngs[i] = np.asarray(r)
    return ConfigGrid(
        policy_code=jnp.asarray([POLICY_CODES[c.policy] for c in cfgs], i32),
        n_users=jnp.asarray([c.n_users for c in cfgs], i32),
        gamma=jnp.asarray([c.gamma for c in cfgs], f32),
        delta=jnp.asarray([c.delta for c in cfgs], f32),
        stickiness=jnp.asarray([c.stickiness for c in cfgs], f32),
        oracle=jnp.asarray([c.oracle_estimator for c in cfgs], bool),
        rng=jnp.asarray(rngs),
        true0=jnp.asarray(true0),
    )


def _simulate_core(prof: ProfileTable, policy_code, n_users, gamma, delta,
                   oracle, stickiness, rng, true0, *, n_requests: int):
    """Trace body shared by the single and batched paths. Every config
    parameter is a traced array; the only static shapes are ``n_requests``
    (scan length) and ``true0``'s length (``n_users_max``). Padded users
    (index >= n_users) sit at ``t_next = +inf`` and never dispatch."""
    P = prof.n_pairs
    G = prof.n_groups
    U = true0.shape[0]
    code = jnp.asarray(policy_code, i32)
    P_trans = EST.markov_transition(G, stickiness)
    mask = jnp.arange(U) < n_users

    carry = {
        "t_next": jnp.where(mask, jnp.arange(U, dtype=f32) * 1e-4, jnp.inf),
        "true_cnt": true0.astype(i32),
        "est_cnt": true0.astype(i32),
        "server_by_user": jnp.full((U,), -1, i32),
        "finish_by_user": jnp.zeros((U,), f32),
        "avail": jnp.zeros((P,), f32),
        "rr": jnp.zeros((), i32),
        "rng": rng,
    }

    gamma = jnp.asarray(gamma, f32)
    delta = jnp.asarray(delta, f32)
    oracle = jnp.asarray(oracle, bool)

    def step(c, _):
        u = jnp.argmin(c["t_next"])
        t = c["t_next"][u]
        rng, k1, k2, k3 = jax.random.split(c["rng"], 4)

        new_true = EST.markov_step(k1, c["true_cnt"][u][None], P_trans)[0]
        g_true = EST.group_of_count(new_true, G)
        g_est = jnp.where(oracle, g_true,
                          EST.group_of_count(c["est_cnt"][u], G))

        active = (c["finish_by_user"] > t) & (c["server_by_user"] >= 0)
        q = jnp.zeros((P,), f32).at[c["server_by_user"]].add(
            active.astype(f32), mode="drop")

        scores = policy_scores(code, prof, g_est, q, k2, c["rr"] % P,
                               gamma, delta)
        p = jnp.argmin(scores).astype(i32)

        t_serv = prof.T[p, g_true] / 1000.0                   # ms -> s
        start = jnp.maximum(t, c["avail"][p])
        finish = start + t_serv

        detected = EST.noisy_detected_count(k3, new_true, prof.mAP[p, g_true])

        nc = dict(c)
        nc["rng"] = rng
        nc["true_cnt"] = c["true_cnt"].at[u].set(new_true.astype(i32))
        nc["est_cnt"] = c["est_cnt"].at[u].set(detected)
        nc["server_by_user"] = c["server_by_user"].at[u].set(p)
        nc["finish_by_user"] = c["finish_by_user"].at[u].set(finish)
        nc["avail"] = c["avail"].at[p].set(finish)
        nc["t_next"] = c["t_next"].at[u].set(finish)
        nc["rr"] = c["rr"] + 1

        rec = {
            "t_arrival": t,
            "latency": finish - t,
            "energy": prof.E[p, g_true],
            "map": prof.mAP[p, g_true],
            "server": p,
            "g_true": g_true,
            "g_est": g_est,
            "q_at_dispatch": q[p],
            "correct_group": (g_true == g_est).astype(f32),
        }
        return nc, rec

    _, recs = jax.lax.scan(step, carry, None, length=n_requests)
    return recs


def _simulate_config(prof, g: ConfigGrid, *, n_requests: int):
    """One config (scalar ConfigGrid leaves) -> record arrays; fields are
    accessed by name so batched and single paths can't transpose leaves."""
    return _simulate_core(prof, g.policy_code, g.n_users, g.gamma, g.delta,
                          g.oracle, g.stickiness, g.rng, g.true0,
                          n_requests=n_requests)


@functools.partial(jax.jit, static_argnames=("n_requests",))
def _simulate_one(prof, g: ConfigGrid, *, n_requests: int):
    return _simulate_config(prof, g, n_requests=n_requests)


@functools.partial(jax.jit, static_argnames=("n_requests",))
def _simulate_vmapped(prof, grid: ConfigGrid, *, n_requests: int):
    return jax.vmap(
        lambda g: _simulate_config(prof, g, n_requests=n_requests))(grid)


@functools.partial(jax.jit, static_argnames=("n_requests", "warmup"))
def _sweep_fused(prof, grid: ConfigGrid, *, n_requests: int, warmup: int):
    """simulate + summarize for every config, fused into one program so a
    sweep returns (B,) metric vectors without materialising (B, N) records
    on the host."""

    def one(g):
        recs = _simulate_config(prof, g, n_requests=n_requests)
        return _summarize_core(recs, prof, warmup)

    return jax.vmap(one)(grid)


def simulate(prof: ProfileTable, cfg: SimConfig):
    """Returns a dict of per-request record arrays (length n_requests)."""
    true0, rng = _init_draws(cfg.seed, cfg.stickiness,
                             n_groups=prof.n_groups, n_users=cfg.n_users)
    g = ConfigGrid(
        policy_code=jnp.asarray(POLICY_CODES[cfg.policy], i32),
        n_users=jnp.asarray(cfg.n_users, i32),
        gamma=jnp.asarray(cfg.gamma, f32),
        delta=jnp.asarray(cfg.delta, f32),
        stickiness=jnp.asarray(cfg.stickiness, f32),
        oracle=jnp.asarray(cfg.oracle_estimator, bool),
        rng=rng, true0=true0)
    return _simulate_one(prof, g, n_requests=cfg.n_requests)


def simulate_batch(prof: ProfileTable, grid: ConfigGrid, n_requests: int):
    """Run every config in ``grid`` as ONE vmapped scan in ONE jit.

    ``n_requests`` is required (no default) and must match the configs the
    grid was built from — the grid carries only traced leaves, not scan
    shapes. Returns record arrays with leading dims (B, n_requests); row b
    is bit-identical to ``simulate(prof, cfg_b)`` for the matching
    config."""
    return _simulate_vmapped(prof, grid, n_requests=n_requests)


def _summarize_core(recs, prof: ProfileTable, warmup: int):
    n = recs["latency"].shape[0]
    sl = {k: v[warmup:] for k, v in recs.items()}
    makespan = jnp.max(sl["t_arrival"] + sl["latency"]) \
        - jnp.min(sl["t_arrival"])
    n_eff = n - warmup
    floor = prof.floor_mw if prof.floor_mw is not None \
        else jnp.zeros((prof.n_pairs,))
    floor_mwh = jnp.sum(floor) * makespan / 3600.0
    return {
        "latency_ms": 1000.0 * jnp.mean(sl["latency"]),
        "latency_p90_ms": 1000.0 * jnp.percentile(sl["latency"], 90),
        "throughput_rps": n_eff / makespan,
        "energy_mwh": jnp.mean(sl["energy"]) + floor_mwh / n_eff,
        "energy_compute_mwh": jnp.mean(sl["energy"]),
        "map": jnp.mean(sl["map"]),
        "estimator_acc": jnp.mean(sl["correct_group"]),
        "makespan_s": makespan,
    }


def summarize(recs, prof: ProfileTable, cfg: SimConfig):
    """Aggregate a record set into the paper's Fig. 4/5 metrics."""
    n = recs["latency"].shape[0]
    return _summarize_core(recs, prof, int(n * cfg.warmup_frac))


@functools.partial(jax.jit, static_argnames=("warmup",))
def summarize_batch(recs, prof: ProfileTable, *, warmup: int):
    """Batched :func:`summarize` over (B, n_requests) record arrays."""
    return jax.vmap(lambda r: _summarize_core(r, prof, warmup))(recs)


def run_policy(prof: ProfileTable, policy: str, n_users: int,
               n_requests: int = 2000, gamma: float = 0.5,
               delta: float = 20.0, seed: int = 0, stickiness: float = 0.85):
    cfg = SimConfig(n_users=n_users, n_requests=n_requests, policy=policy,
                    gamma=gamma, delta=delta, seed=seed,
                    stickiness=stickiness)
    recs = simulate(prof, cfg)
    out = summarize(recs, prof, cfg)
    return {k: float(v) for k, v in out.items()}


SWEEP_AXES = ("policy", "users", "gamma", "delta", "oracle", "seed")


def sweep_grid(prof: ProfileTable, policies=("MO",), user_levels=(15,),
               gammas=(0.5,), deltas=(20.0,), oracle=(False,),
               seeds=(0, 1, 2), n_requests: int = 2000,
               stickiness: float = 0.85, warmup_frac: float = 0.1):
    """Cartesian-product sweep as a single fused device program.

    Returns ``{metric: ndarray}`` with shape ``(len(policies),
    len(user_levels), len(gammas), len(deltas), len(oracle), len(seeds))``
    — axis order as in :data:`SWEEP_AXES`. The whole grid is one
    ``vmap(simulate + summarize)`` under one jit; the trace is cached
    across calls with the same batch size and scan length."""
    combos = list(itertools.product(policies, user_levels, gammas, deltas,
                                    oracle, seeds))
    cfgs = [SimConfig(n_users=nu, n_requests=n_requests, policy=pol,
                      gamma=ga, delta=de, stickiness=stickiness, seed=sd,
                      warmup_frac=warmup_frac, oracle_estimator=orc)
            for pol, nu, ga, de, orc, sd in combos]
    grid = make_grid(prof, cfgs)
    out = _sweep_fused(prof, grid, n_requests=n_requests,
                       warmup=int(n_requests * warmup_frac))
    shape = (len(policies), len(user_levels), len(gammas), len(deltas),
             len(oracle), len(seeds))
    return {k: np.asarray(v, np.float64).reshape(shape)
            for k, v in out.items()}


def sweep(prof: ProfileTable, policies, user_levels, n_requests: int = 2000,
          gamma: float = 0.5, delta: float = 20.0, seeds=(0, 1, 2)):
    """Full Fig. 4-style sweep; returns {policy: {metric: [per-level mean]}}.
    Each configuration runs ``len(seeds)`` times (paper: 3 repetitions).
    The entire policies × user_levels × seeds grid executes as one batched
    device program (:func:`sweep_grid`)."""
    m = sweep_grid(prof, policies=policies, user_levels=user_levels,
                   gammas=(gamma,), deltas=(delta,), seeds=seeds,
                   n_requests=n_requests)
    out: dict[str, dict[str, list[float]]] = {}
    for i, pol in enumerate(policies):
        out[pol] = {k: [float(np.mean(v[i, j, 0, 0, 0, :]))
                        for j in range(len(user_levels))]
                    for k, v in m.items()}
    return out
