"""Closed-loop discrete-event simulator of the heterogeneous serving fleet
(paper §IV: Locust-style concurrency — each of U users has exactly one
request in flight; the next request of a stream is issued when the previous
response returns).

NOTE — public API: scenarios are declared through ``repro.core.scenario``
(``Scenario`` / ``Sweep`` / ``run`` / ``records``); the kwarg entry
points here (``make_grid`` / ``simulate`` / ``simulate_batch`` /
``sweep_grid`` / ``run_policy`` / ``sweep``) are deprecation-warned thin
shims over that path, kept bit-identical to the pre-scenario engine
(``tests/golden_static_pr3.json`` pins it). This module remains the
*engine*: the traced core, the batched/sharded execution paths and the
summarizers all live here and are driven by the scenario layer.

Implemented as one ``lax.scan`` over dispatch events whose per-config
parameters (policy code, γ, Δ, stickiness, RNG state) are *traced*
arguments, so an entire Fig. 4-style grid — policy × concurrency × γ ×
seed — runs as ONE ``jax.vmap``-ped scan inside ONE jit
(:func:`simulate_batch` / :func:`sweep_grid`): a single device program
instead of one trace + launch per configuration. Differing concurrency
levels share the trace by padding users to ``n_users_max`` and masking the
padded streams to ``t = +inf`` so they never dispatch.

Scene complexity comes from a pluggable :class:`~repro.core.workload.
WorkloadSource` (the ``workload=`` argument throughout): the source owns
the initial per-user count draw at grid-build time and the per-dispatch
count step inside the scan. The default is the paper's synthetic Markov
chain (``repro.core.workload.MarkovWorkload``, bit-identical to the
engine before the interface existed); ``repro.data.traces.TraceWorkload``
plays recorded object-count traces instead. Sources are pytrees
replicated across the config axis, so both compose with vmap, sharding
and fleet stacking unchanged.

Dispatch state is pluggable the same way (``dispatch=`` throughout,
``repro.core.dispatch``): the per-decision state — round-robin counter,
online-EWMA belief tables — lives in a ``DispatchState`` pytree carried
through the scan, with ``init``/``select``/``observe`` hooks shared with
the serving gateway. ``StaticDispatch`` (default) is bit-identical to
the pre-interface engine; ``OnlineDispatch`` adapts to observations, and
a ``DriftSchedule`` (``drift=``) perturbs the *true* profile mid-run to
model throttling or model swaps.

Bit-exactness across batching: jax's threefry draws are not prefix-stable
across shapes (the first U samples of a ``(U_max,)`` draw differ from a
``(U,)`` draw), so the initial per-user complexity states are drawn
per-config at grid-build time (:func:`make_grid`) with each config's own
``n_users`` shape and passed into the scan as data. Every other draw in
the loop is shape-independent, which makes a padded batched run reproduce
each config's unpadded trajectory exactly.

Scaling axes (see ``docs/sweep_engine.md`` for the full architecture
guide): the batched engine composes three orthogonal batch dims in the
fixed order **(fleet, config, user, time)** —

  * **config** — the flat struct-of-arrays axis of :class:`ConfigGrid`
    (one entry per policy × users × γ × Δ × oracle × seed combination),
    vmapped always and optionally *sharded across devices* via
    ``sweep_grid(..., mesh=...)`` (``shard_map`` over the config axis,
    padded to a multiple of the device count, bit-identical results);
  * **fleet** — an optional leading ensemble axis over same-shape
    ``ProfileTable`` stacks (``repro.core.profiles.stack_profiles``),
    vmapped outside the config axis;
  * **user / time** — the per-config padded user streams and the
    ``lax.scan`` over dispatch events.

Grid building is memoized and vectorised: per-config initial draws depend
only on (seed, stickiness, n_users), so :func:`make_grid` computes each
distinct triple once per workload source (process-wide for the Markov
default, see ``repro.core.workload.grid_cache_info``) and batches cache
misses per ``n_users`` level with one vmapped threefry draw — a
10^5-config grid builds in milliseconds.

Faithfulness notes:
  * service time / energy / accuracy are drawn from ``ProfileTable`` at the
    *true* complexity group; the policy only sees the *estimated* group
    (output-based estimator, paper §III-B.1), so estimator staleness and
    accuracy-dependent undercounting are modelled;
  * queue depths q[p] are exact (outstanding requests at dispatch time);
  * reported energy = per-request profile energy + the amortised active-floor
    power of the fleet (reproduces Fig. 4e/5d's decreasing energy curves).
"""

from __future__ import annotations

import functools
import warnings
from dataclasses import dataclass, field
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from repro.core import estimator as EST
from repro.core.dispatch import (DispatchEngine, DriftSchedule,
                                 default_dispatch)
from repro.core.policies import POLICY_CODES
from repro.core.profiles import ProfileTable
from repro.core.useraxis import (aggregate_block_summaries, block_segments,
                                 block_sizes, latency_histogram)
from repro.core.workload import (MarkovWorkload, WorkloadSource,
                                 _init_draws, default_workload,
                                 grid_cache_clear, grid_cache_info)
from repro.distributed.sharding import config_axis_spec, pad_leading

# Historical home of the grid draw machinery — tests and callers import
# these from here; the implementations moved to repro.core.workload with
# the WorkloadSource split.
__all__ = ["SimConfig", "ConfigGrid", "make_grid", "simulate",
           "simulate_batch", "summarize", "summarize_batch", "run_policy",
           "sweep", "sweep_grid", "SWEEP_AXES", "grid_cache_info",
           "grid_cache_clear", "_init_draws", "default_workload",
           "default_dispatch"]

f32 = jnp.float32
i32 = jnp.int32


@dataclass(frozen=True)
class SimConfig:
    n_users: int = 15
    n_requests: int = 2000
    policy: str = "MO"
    gamma: float = 0.5
    delta: float = 20.0   # headline tolerance (paper leaves Δ_mAP to the
                          # operator; 20 pts reproduces the Fig.4 trade-off)
    stickiness: float = 0.85
    seed: int = 0
    warmup_frac: float = 0.1
    oracle_estimator: bool = False   # ablation: g_est = g_true (perfect
                                     # complexity knowledge; benchmarks)
    workload: WorkloadSource | None = field(default=None, compare=False)
    # scene-complexity source; None = the Markov default. All configs in
    # one grid must share a single source (it is grid data, like prof).
    dispatch: DispatchEngine | None = field(default=None, compare=False)
    # dispatch-state engine; None = StaticDispatch. Like the workload, it
    # is grid data: every config in one grid must share a single engine.


class ConfigGrid(NamedTuple):
    """Struct-of-arrays batch of simulator configs — the traced leaves of a
    ``SimConfig``. All fields have leading dim (B,); ``rng`` is the (B, 2)
    uint32 scan key and ``true0`` the (B, n_users_max) initial true object
    counts, both drawn host-side per config (see module docstring);
    ``phase`` is the (B, n_users_max) per-user frame phase offset of the
    workload source (zeros for the Markov chain). ``simulate`` also uses
    it batch-less (scalar leaves, (U,) true0/phase) so single and vmapped
    paths share one by-name field access path."""

    policy_code: jax.Array      # (B,) int32 index into POLICY_CODES
    n_users: jax.Array          # (B,) int32 live concurrency (<= n_users_max)
    gamma: jax.Array            # (B,) float32
    delta: jax.Array            # (B,) float32
    stickiness: jax.Array       # (B,) float32
    oracle: jax.Array           # (B,) bool   g_est = g_true ablation
    rng: jax.Array              # (B, 2) uint32
    true0: jax.Array            # (B, n_users_max) int32
    phase: jax.Array            # (B, n_users_max) int32 workload phase

    @property
    def n_configs(self) -> int:
        return int(self.policy_code.shape[0]) if self.policy_code.ndim \
            else 1

    @property
    def n_users_max(self) -> int:
        return int(self.true0.shape[-1])


def _resolve_workload(workload, cfgs=()) -> WorkloadSource:
    """One workload source for a whole grid: the explicit argument wins;
    otherwise the single source the configs agree on (None = Markov
    default). Mixing sources in one grid is an error — the source is grid
    data shared by every config, exactly like the profile table."""
    found = {id(c.workload): c.workload for c in cfgs
             if c.workload is not None}
    if workload is None and found:
        if len(found) > 1:
            raise ValueError("configs in one grid must share a single "
                             "workload source")
        (workload,) = found.values()
    elif workload is not None and any(w is not workload
                                      for w in found.values()):
        raise ValueError("workload= argument conflicts with the configs' "
                         "own workload source")
    return workload if workload is not None else default_workload()


def _resolve_dispatch(dispatch, cfgs=()) -> DispatchEngine:
    """One dispatch engine for a whole grid, mirroring
    :func:`_resolve_workload`: the explicit argument wins, otherwise the
    single engine the configs agree on (None = :class:`StaticDispatch`).
    Mixing engines in one grid is an error — the engine is grid data
    shared by every config, exactly like the profile table. Unlike
    workload sources (identity-keyed: a trace's equality IS identity),
    engines are frozen hyper-parameter dataclasses, so two separately
    constructed but equal engines count as the same one."""
    found: list[DispatchEngine] = []
    for c in cfgs:
        if c.dispatch is not None and c.dispatch not in found:
            found.append(c.dispatch)
    if dispatch is None and found:
        if len(found) > 1:
            raise ValueError("configs in one grid must share a single "
                             "dispatch engine")
        (dispatch,) = found
    elif dispatch is not None and any(d != dispatch for d in found):
        raise ValueError("dispatch= argument conflicts with the configs' "
                         "own dispatch engine")
    return dispatch if dispatch is not None else default_dispatch()


def _warn_legacy(name: str, alt: str) -> None:
    """Issue the deprecation warning for a legacy kwarg entry point.
    The category lives in repro.core.scenario (imported lazily — the
    scenario module imports this one); ``stacklevel=3`` points the
    warning at the shim's caller."""
    from repro.core.scenario import LegacyAPIWarning
    warnings.warn(
        f"repro.core.simulator.{name} is deprecated: {alt} — see the "
        "migration table in docs/sweep_engine.md",
        LegacyAPIWarning, stacklevel=3)


def make_grid(prof: ProfileTable, configs,
              n_users_max: int | None = None,
              workload: WorkloadSource | None = None,
              dispatch: DispatchEngine | None = None) -> ConfigGrid:
    """Deprecated: declare the grid as a ``Scenario`` + ``Sweep`` and
    call ``repro.core.scenario.run`` / ``records`` instead (the engine
    builds the grid internally). Same contract as :func:`_make_grid`."""
    _warn_legacy("make_grid", "use repro.core.scenario.run(Scenario, "
                 "Sweep) — grids are built internally")
    return _make_grid(prof, configs, n_users_max, workload, dispatch)


def _make_grid(prof: ProfileTable, configs,
               n_users_max: int | None = None,
               workload: WorkloadSource | None = None,
               dispatch: DispatchEngine | None = None) -> ConfigGrid:
    """Pack an iterable of :class:`SimConfig` into a padded
    :class:`ConfigGrid`.

    Args:
      prof: the fleet the grid will run against; only its ``n_groups``
        enters the build (the initial complexity draw). A stacked table is
        fine — all fleets share one group count.
      configs: iterable of :class:`SimConfig`. All must agree on
        ``n_requests``/``warmup_frac``: those are scan-*shape* parameters,
        not traced grid leaves, and are passed separately to
        :func:`simulate_batch` / :func:`summarize_batch`.
      n_users_max: pad width of the user axis; defaults to the largest
        ``n_users`` in the batch. Padded streams are masked to never
        dispatch, so the pad width does not change results.
      workload: scene-complexity source drawing the initial states (and
        later stepped inside the scan — pass the SAME source to
        ``simulate_batch``). Defaults to the configs' shared source, else
        the Markov chain.
      dispatch: dispatch-state engine the grid will run under
        (``repro.core.dispatch``). It holds no grid-build data — the
        argument is validated here (one engine per grid, like the
        workload) and must be passed again to ``simulate_batch``.

    Returns:
      A :class:`ConfigGrid` with leading dim ``B = len(configs)``
      (struct-of-arrays; see the class docstring for leaf shapes/dtypes).

    Determinism: each config's initial state is drawn with its own
    ``n_users``-shaped threefry stream keyed on (seed, stickiness), so row
    ``b`` of any batched/sharded run is bit-identical to the unbatched
    ``simulate`` of config ``b``. Markov draws are memoized process-wide
    on (seed, stickiness, n_users, n_groups) and cache misses are computed
    in one vmapped batch per ``n_users`` level (see
    ``repro.core.workload.grid_cache_info``).
    """
    cfgs = list(configs)
    if not cfgs:
        raise ValueError("empty config grid")
    if len({(c.n_requests, c.warmup_frac) for c in cfgs}) > 1:
        raise ValueError(
            "configs in one grid must agree on n_requests/warmup_frac "
            "(they are scan-shape parameters, passed separately to "
            "simulate_batch/summarize_batch)")
    workload = _resolve_workload(workload, cfgs)
    _resolve_dispatch(dispatch, cfgs)
    U = max(c.n_users for c in cfgs) if n_users_max is None else n_users_max
    G = prof.n_groups

    keys = [(c.seed, float(c.stickiness), c.n_users, G) for c in cfgs]
    draws = workload.grid_draws(keys)

    true0 = np.zeros((len(cfgs), U), np.int32)
    rng = np.zeros((len(cfgs), 2), np.uint32)
    phase = np.zeros((len(cfgs), U), np.int32)
    for i, k in enumerate(keys):
        t0, r, ph = draws[k]
        true0[i, :k[2]] = t0
        rng[i] = r
        phase[i, :k[2]] = ph
    return ConfigGrid(
        policy_code=jnp.asarray([POLICY_CODES[c.policy] for c in cfgs], i32),
        n_users=jnp.asarray([c.n_users for c in cfgs], i32),
        gamma=jnp.asarray([c.gamma for c in cfgs], f32),
        delta=jnp.asarray([c.delta for c in cfgs], f32),
        stickiness=jnp.asarray([c.stickiness for c in cfgs], f32),
        oracle=jnp.asarray([c.oracle_estimator for c in cfgs], bool),
        rng=jnp.asarray(rng),
        true0=jnp.asarray(true0),
        phase=jnp.asarray(phase),
    )


def _expand_user_blocks(cfgs, user_block: int):
    """Decompose each config into its user blocks (balancer replicas, see
    ``repro.core.useraxis``): returns ``(rows, segments)`` where ``rows``
    is a flat list of ``(cfg_index, block_index, block_users)`` — one
    entry per expanded grid row, configs' blocks contiguous — and
    ``segments`` maps each row back to its config (int32)."""
    rows: list[tuple[int, int, int]] = []
    blocks_per_cfg = []
    for ci, c in enumerate(cfgs):
        sizes = block_sizes(c.n_users, user_block)
        blocks_per_cfg.append(len(sizes))
        rows.extend((ci, bi, bu) for bi, bu in enumerate(sizes))
    return rows, block_segments(blocks_per_cfg)


def _make_user_grid(prof: ProfileTable, configs, user_block: int,
                    workload: WorkloadSource | None = None,
                    dispatch: DispatchEngine | None = None,
                    chunk: int | None = None):
    """Pack configs into a user-blocked :class:`ConfigGrid`: a config
    with ``n_users = N > user_block`` becomes ``ceil(N / user_block)``
    block rows of ≤ ``user_block`` users each — independent balancer
    replicas riding the ordinary config axis, so the grid vmaps, shards
    over a mesh and fleet-stacks with zero new engine machinery, and its
    leaves stay ``O(total_users)`` instead of ``O(B × n_users_max)``.

    Returns ``(grid, segments)``; feed both to
    :func:`_sweep_user_summaries` to recover per-config metrics by
    segment reduction over each config's contiguous block rows.

    Determinism contract:
      * single-block configs (``n_users <= user_block``) draw through the
        legacy memoized one-shot path (:meth:`WorkloadSource.grid_draws`)
        and aggregate as one-element folds, so they stay bit-identical
        to the un-blocked engine (the golden fixtures pin this);
      * multi-block configs draw through the streamed per-user-keyed path
        (:meth:`WorkloadSource.stream_draws`, device memory bounded by
        ``chunk``) and block ``b`` scans under ``fold_in(rng0, b)`` — a
        distinct physical system (K replicas, not one balancer), declared
        as such by ``user_block`` entering the scenario identity/hash.

    ``n_requests`` stays the PER-BLOCK scan length (it is a static scan
    shape): a K-block config serves ``K × n_requests`` requests total.
    """
    cfgs = list(configs)
    if not cfgs:
        raise ValueError("empty config grid")
    if len({(c.n_requests, c.warmup_frac) for c in cfgs}) > 1:
        raise ValueError(
            "configs in one grid must agree on n_requests/warmup_frac "
            "(they are scan-shape parameters, passed separately to "
            "simulate_batch/summarize_batch)")
    workload = _resolve_workload(workload, cfgs)
    _resolve_dispatch(dispatch, cfgs)
    G = prof.n_groups
    rows, segments = _expand_user_blocks(cfgs, user_block)
    U = max(bu for _, _, bu in rows)
    B = len(rows)

    multi = {ci for ci, bi, _ in rows if bi > 0}
    if multi:
        workload.validate_user_block(user_block)
    legacy_keys = {ci: (c.seed, float(c.stickiness), c.n_users, G)
                   for ci, c in enumerate(cfgs) if ci not in multi}
    draws = workload.grid_draws(list(legacy_keys.values())) \
        if legacy_keys else {}
    streams: dict[tuple, tuple] = {}
    for ci in sorted(multi):
        c = cfgs[ci]
        sk = (c.seed, float(c.stickiness), c.n_users)
        if sk not in streams:
            streams[sk] = workload.stream_draws(
                c.seed, c.stickiness, n_groups=G, n_users=c.n_users,
                chunk=chunk)

    true0 = np.zeros((B, U), np.int32)
    rng = np.zeros((B, 2), np.uint32)
    phase = np.zeros((B, U), np.int32)
    fold_rows: list[int] = []
    fold_keys: list[np.ndarray] = []
    for i, (ci, bi, bu) in enumerate(rows):
        c = cfgs[ci]
        if ci in multi:
            t0, r0, ph = streams[(c.seed, float(c.stickiness), c.n_users)]
            lo = bi * user_block
            true0[i, :bu] = t0[lo:lo + bu]
            phase[i, :bu] = ph[lo:lo + bu]
            fold_rows.append(i)
            fold_keys.append(r0)
        else:
            t0, r0, ph = draws[legacy_keys[ci]]
            true0[i, :bu] = t0
            phase[i, :bu] = ph
            rng[i] = r0
    if fold_rows:
        # per-block scan keys: fold the block index into the config's
        # stream key, one vmapped threefry program for all multi rows
        folded = np.asarray(jax.vmap(jax.random.fold_in)(
            jnp.asarray(np.stack(fold_keys), jnp.uint32),
            jnp.asarray([rows[i][1] for i in fold_rows], i32)))
        rng[fold_rows] = folded

    grid = ConfigGrid(
        policy_code=jnp.asarray([POLICY_CODES[cfgs[ci].policy]
                                 for ci, _, _ in rows], i32),
        n_users=jnp.asarray([bu for _, _, bu in rows], i32),
        gamma=jnp.asarray([cfgs[ci].gamma for ci, _, _ in rows], f32),
        delta=jnp.asarray([cfgs[ci].delta for ci, _, _ in rows], f32),
        stickiness=jnp.asarray([cfgs[ci].stickiness
                                for ci, _, _ in rows], f32),
        oracle=jnp.asarray([cfgs[ci].oracle_estimator
                            for ci, _, _ in rows], bool),
        rng=jnp.asarray(rng),
        true0=jnp.asarray(true0),
        phase=jnp.asarray(phase),
    )
    return grid, segments


def _sweep_user_summaries(prof, workload, dispatch, drift, cloud, faults,
                          grid: ConfigGrid, segments, n_cfgs: int, *,
                          n_requests: int, warmup: int, mesh: Mesh | None):
    """Fused sweep over a user-blocked grid: the expanded block rows run
    through the ordinary single-device/sharded paths (per-user workload
    state rides the sharded config axis), then segment-reduce back to
    per-config metrics on device. Single-block configs pass through the
    aggregation bit-identically; multi-block configs additionally carry
    the per-block latency histogram so the fleet-wide p90 is an exact
    merge, not a mean of per-block percentiles."""
    multi = int(np.asarray(segments).shape[0]) > n_cfgs
    out = _sweep_summaries(prof, workload, dispatch, drift, cloud, faults,
                           grid, n_requests=n_requests, warmup=warmup,
                           mesh=mesh, with_hist=multi)
    return aggregate_block_summaries(out, segments, n_cfgs, block_axis=-1)


def _simulate_core(prof: ProfileTable, workload: WorkloadSource,
                   dispatch: DispatchEngine, drift: DriftSchedule | None,
                   cloud, faults, policy_code, n_users, gamma, delta,
                   oracle, stickiness, rng, true0, phase, *,
                   n_requests: int):
    """Trace body shared by the single and batched paths. Every config
    parameter is a traced array; the only static shapes are ``n_requests``
    (scan length), ``true0``'s length (``n_users_max``) and the workload /
    dispatch / drift pytrees' own data. Padded users (index >= n_users)
    sit at ``t_next = +inf`` and never dispatch.

    The dispatch engine's :class:`~repro.core.dispatch.DispatchState`
    rides in the scan carry: ``select`` scores each request against the
    engine's belief tables, ``observe`` folds the request's TRUE service
    time and energy back in afterwards. ``drift`` (when given) perturbs
    the *true* profile per step — the policy never sees it except through
    observations.

    ``cloud`` (:class:`~repro.core.cloud.CloudMeta` or ``None``) marks
    the trailing pairs of ``prof`` as remote: their profiled latency
    already includes RTT + transfer, so the truth model splits it back
    into uplink occupancy (a single shared uplink serialises transfers —
    the ``up_avail`` carry key, present only when a cloud tier exists),
    remote compute (occupies the cloud pair) and downlink RTT (occupies
    neither). The dispatcher additionally sees a congestion penalty
    (:meth:`CloudMeta.penalty`) on latency-aware policies. ``None``
    leaves the traced graph exactly as before — the no-cloud fixtures
    stay bit-identical.

    ``faults`` (:class:`~repro.core.faults.FaultMeta` or ``None``) is
    the fault plane: per-step outage/throttle/jitter draws keyed purely
    on the step index (no carried fault state). A visible schedule
    passes the health mask to dispatch (down pairs leave the candidate
    set, with MO's degraded argmin-latency fallback); the TRUTH model
    always applies faults — dispatching into an outage stalls the
    request by ``timeout_ms``, throttling scales the drifted truth
    (drift first, fault throttle on top — the defined composition
    order), and WAN jitter perturbs the cloud transfer/RTT terms.
    Fault-active records additionally carry ``slo_violation`` (no
    healthy pair cleared the accuracy bar at dispatch) and ``failed``
    (the request hit a down pair). ``None`` leaves the traced graph
    exactly as before — the no-fault fixtures stay bit-identical."""
    P = prof.n_pairs
    G = prof.n_groups
    U = true0.shape[0]
    code = jnp.asarray(policy_code, i32)
    wctx = workload.prepare(G, stickiness)
    mask = jnp.arange(U) < n_users

    carry = {
        "t_next": jnp.where(mask, jnp.arange(U, dtype=f32) * 1e-4, jnp.inf),
        "true_cnt": true0.astype(i32),
        "est_cnt": true0.astype(i32),
        "pos": jnp.zeros((U,), i32),     # dispatches so far per user
        "server_by_user": jnp.full((U,), -1, i32),
        "finish_by_user": jnp.zeros((U,), f32),
        "avail": jnp.zeros((P,), f32),
        "dispatch": dispatch.init(prof),
        "rng": rng,
    }
    if cloud is not None:
        carry["up_avail"] = jnp.asarray(0.0, f32)   # shared uplink frontier

    gamma = jnp.asarray(gamma, f32)
    delta = jnp.asarray(delta, f32)
    oracle = jnp.asarray(oracle, bool)
    phase = jnp.asarray(phase, i32)

    def step(c, i):
        u = jnp.argmin(c["t_next"])
        t = c["t_next"][u]
        rng, k1, k2, k3 = jax.random.split(c["rng"], 4)

        new_true = workload.next_count(wctx, k1, c["true_cnt"][u], u,
                                       phase[u] + c["pos"][u] + 1)
        g_true = EST.group_of_count(new_true, G)
        g_est = jnp.where(oracle, g_true,
                          EST.group_of_count(c["est_cnt"][u], G))

        active = (c["finish_by_user"] > t) & (c["server_by_user"] >= 0)
        q = jnp.zeros((P,), f32).at[c["server_by_user"]].add(
            active.astype(f32), mode="drop")

        if faults is not None:
            down = faults.down_at(i)
            up = ~down
            health = jnp.where(jnp.any(up), up, True)

        penalty = None if cloud is None else cloud.penalty(g_est, q)
        p, dstate = dispatch.select(
            c["dispatch"], prof, code, g_est, q, k2, gamma, delta,
            penalty=penalty,
            health=health if faults is not None and faults.visible
            else None)

        # the TRUE fleet this step: the offline profile, or its drifted
        # copy — service time, energy and the observation all come from
        # it. Fault throttling multiplies ON TOP of drift (the defined
        # composition order: truth = (prof x drift) x fault).
        truth = prof if drift is None else drift.at_step(prof, i)
        if faults is not None and faults.has_throttle:
            t_sc, e_sc = faults.throttle_at(i)
            truth = ProfileTable(truth.T * t_sc[:, None],
                                 truth.E * e_sc[:, None],
                                 truth.mAP, truth.names, truth.floor_mw)
        t_serv = truth.T[p, g_true] / 1000.0                  # ms -> s
        # dispatching into an outage stalls the request by timeout_ms —
        # the truth model pays it whether or not the router could see
        # the mask (blind routing is the static-routing baseline)
        stall = None
        if faults is not None and faults.has_down:
            stall = jnp.where(down[p], faults.timeout_ms, 0.0) / 1000.0
        if cloud is None:
            start = jnp.maximum(t, c["avail"][p])
            finish = start + t_serv
            if stall is not None:
                finish = finish + stall
        else:
            # split the profiled total back into uplink / compute / RTT:
            # the uplink is a single shared resource (transfers serialise),
            # remote compute occupies the cloud pair, the downlink RTT
            # occupies neither. Local pairs have zero network terms, so
            # their timeline is the exact no-cloud expression. WAN jitter
            # perturbs the REALIZED transfer/RTT; the compute split keeps
            # the profiled base terms (the remote GPU is not jittered).
            isc = cloud.is_cloud[p]
            xfer_s = jnp.where(isc, cloud.xfer_ms[g_true], 0.0) / 1000.0
            rtt_s = jnp.where(isc, cloud.rtt_ms, 0.0) / 1000.0
            xfer_j, rtt_j = xfer_s, rtt_s
            if faults is not None and faults.has_bw_jitter:
                xfer_j = xfer_s * faults.xfer_scale(i)
            if faults is not None and faults.has_rtt_jitter:
                rtt_j = rtt_s + jnp.where(
                    isc, faults.rtt_extra_ms(i), 0.0) / 1000.0
            up_start = jnp.maximum(t, c["up_avail"])
            arrive = jnp.where(isc, up_start + xfer_j, t)
            start = jnp.maximum(arrive, c["avail"][p])
            compute_s = jnp.maximum(t_serv - xfer_s - rtt_s, 0.0)
            finish = start + compute_s + rtt_j
            if stall is not None:
                finish = finish + stall
            nc_up = jnp.where(isc, up_start + xfer_j, c["up_avail"])

        detected = EST.noisy_detected_count(k3, new_true, prof.mAP[p, g_true])
        dstate = dispatch.observe(dstate, p, g_est, truth.T[p, g_true],
                                  truth.E[p, g_true])

        nc = dict(c)
        nc["rng"] = rng
        nc["true_cnt"] = c["true_cnt"].at[u].set(new_true.astype(i32))
        nc["est_cnt"] = c["est_cnt"].at[u].set(detected)
        nc["pos"] = c["pos"].at[u].add(1)
        nc["server_by_user"] = c["server_by_user"].at[u].set(p)
        nc["finish_by_user"] = c["finish_by_user"].at[u].set(finish)
        if cloud is None:
            nc["avail"] = c["avail"].at[p].set(finish)
        else:
            nc["avail"] = c["avail"].at[p].set(finish - rtt_j)
            nc["up_avail"] = nc_up
        nc["t_next"] = c["t_next"].at[u].set(finish)
        nc["dispatch"] = dstate

        rec = {
            "t_arrival": t,
            "latency": finish - t,
            "energy": truth.E[p, g_true],
            "map": prof.mAP[p, g_true],
            "server": p,
            "g_true": g_true,
            "g_est": g_est,
            "q_at_dispatch": q[p],
            "correct_group": (g_true == g_est).astype(f32),
        }
        if faults is not None:
            # SLO violation = the degraded-mode condition: no UP pair
            # clears the accuracy bar (belief mAP == offline mAP — it is
            # never adapted or drifted); failed = dispatched into an
            # outage (always true-model ``down``, not the relaxed mask)
            feas = prof.mAP[:, g_est] >= jnp.max(prof.mAP[:, g_est]) - delta
            rec["slo_violation"] = (~jnp.any(feas & up)).astype(f32)
            rec["failed"] = down[p].astype(f32)
        return nc, rec

    _, recs = jax.lax.scan(step, carry, jnp.arange(n_requests, dtype=i32))
    return recs


def _simulate_config(prof, workload, dispatch, drift, cloud, faults,
                     g: ConfigGrid, *, n_requests: int):
    """One config (scalar ConfigGrid leaves) -> record arrays; fields are
    accessed by name so batched and single paths can't transpose leaves."""
    return _simulate_core(prof, workload, dispatch, drift, cloud, faults,
                          g.policy_code, g.n_users, g.gamma, g.delta,
                          g.oracle, g.stickiness, g.rng, g.true0, g.phase,
                          n_requests=n_requests)


@functools.partial(jax.jit, static_argnames=("n_requests",))
def _simulate_one(prof, workload, dispatch, drift, cloud, faults,
                  g: ConfigGrid, *, n_requests: int):
    return _simulate_config(prof, workload, dispatch, drift, cloud, faults,
                            g, n_requests=n_requests)


def _over_fleet(fn, prof):
    """Apply ``fn(single_fleet_prof)``, vmapping over the leading fleet
    axis when ``prof`` is stacked. The fleet axis always batches OUTSIDE
    the config axis — axis order (fleet, config, user, time)."""
    if prof.is_stacked:
        return jax.vmap(fn)(prof)
    return fn(prof)


@functools.partial(jax.jit, static_argnames=("n_requests",))
def _simulate_vmapped(prof, workload, dispatch, drift, cloud, faults,
                      grid: ConfigGrid, *, n_requests: int):
    return _over_fleet(
        lambda pf: jax.vmap(
            lambda g: _simulate_config(pf, workload, dispatch, drift,
                                       cloud, faults, g,
                                       n_requests=n_requests))(
            grid),
        prof)


def _fused_summaries(prof, workload, dispatch, drift, cloud, faults,
                     grid: ConfigGrid, *, n_requests: int, warmup: int,
                     with_hist: bool = False):
    """The simulate + summarize composition over (fleet,) config — the ONE
    source of truth shared by the single-device jit and the shard_map'ed
    path, so the two can never drift apart and break the bit-identical
    guarantee. Returns (B,) metric vectors — (F, B) for a stacked fleet —
    without materialising (B, N) records. ``with_hist`` additionally
    emits the fixed-bin latency histogram leaf (``(B, NB)``) the
    user-block aggregation merges into exact fleet-wide percentiles."""

    def per_fleet(pf):
        def one(g):
            recs = _simulate_config(pf, workload, dispatch, drift, cloud,
                                    faults, g, n_requests=n_requests)
            return _summarize_core(recs, pf, warmup, cloud,
                                   with_hist=with_hist)

        return jax.vmap(one)(grid)

    return _over_fleet(per_fleet, prof)


@functools.partial(jax.jit,
                   static_argnames=("n_requests", "warmup", "with_hist"))
def _sweep_fused(prof, workload, dispatch, drift, cloud, faults,
                 grid: ConfigGrid, *, n_requests: int, warmup: int,
                 with_hist: bool = False):
    return _fused_summaries(prof, workload, dispatch, drift, cloud, faults,
                            grid, n_requests=n_requests, warmup=warmup,
                            with_hist=with_hist)


@functools.lru_cache(maxsize=None)
def _sweep_sharded_fn(mesh: Mesh, n_requests: int, warmup: int,
                      stacked: bool, with_hist: bool = False):
    """Build (and cache per mesh/shape signature) the shard_map'ed fused
    sweep: the config axis is split over every mesh axis, the profile
    table, workload source, dispatch engine, drift schedule and cloud
    meta are replicated, and each shard runs the plain vmapped simulate +
    summarize — no collectives, the grid is embarrassingly parallel. The
    inner jit re-specialises per workload/dispatch/drift/cloud pytree
    structure, so one cache entry serves Markov and trace runs, static
    and online engines, edge-only and edge+cloud fleets."""
    cspec = config_axis_spec(mesh)
    out_spec = PartitionSpec(None, *cspec) if stacked else cspec
    if with_hist:
        # every metric leaf is (B,) except the (B, NB) histogram: give
        # the tree a per-leaf spec so the bin axis stays unsharded
        def out_spec_of(k, base):
            return PartitionSpec(*base, None) if k == "latency_hist" \
                else base
    else:
        def out_spec_of(k, base):
            return base

    def inner(pf, wl, de, dr, cl, fl, g):
        return _fused_summaries(pf, wl, de, dr, cl, fl, g,
                                n_requests=n_requests, warmup=warmup,
                                with_hist=with_hist)

    def fn(pf, wl, de, dr, cl, fl, g):
        keys = jax.eval_shape(inner, pf, wl, de, dr, cl, fl, g).keys()
        specs = {k: out_spec_of(k, out_spec) for k in keys}
        return shard_map(
            inner, mesh=mesh,
            in_specs=(PartitionSpec(), PartitionSpec(), PartitionSpec(),
                      PartitionSpec(), PartitionSpec(), PartitionSpec(),
                      cspec),
            out_specs=specs)(pf, wl, de, dr, cl, fl, g)

    return jax.jit(fn)


def _sweep_summaries(prof, workload, dispatch, drift, cloud, faults,
                     grid: ConfigGrid, *, n_requests: int, warmup: int,
                     mesh: Mesh | None, with_hist: bool = False):
    """Dispatch a fused sweep to the single-device or sharded path; both
    return per-config summary dicts with config as the trailing axis of
    each (B,) / (F, B) leaf — (..., B, NB) for the optional histogram —
    bit-identical to each other."""
    if mesh is None:
        return _sweep_fused(prof, workload, dispatch, drift, cloud, faults,
                            grid, n_requests=n_requests, warmup=warmup,
                            with_hist=with_hist)
    n_dev = int(mesh.devices.size)
    padded, n = pad_leading(grid, n_dev)
    fn = _sweep_sharded_fn(mesh, n_requests, warmup, prof.is_stacked,
                           with_hist)
    out = fn(prof, workload, dispatch, drift, cloud, faults,
             ConfigGrid(*map(jnp.asarray, padded)))
    return {k: (v[..., :n, :] if k == "latency_hist" else v[..., :n])
            for k, v in out.items()}


def simulate(prof: ProfileTable, cfg: SimConfig,
             workload: WorkloadSource | None = None,
             dispatch: DispatchEngine | None = None,
             drift: DriftSchedule | None = None):
    """Deprecated: use ``repro.core.scenario.records(Scenario(...))``
    (one spec object instead of a config + three parallel kwargs). Same
    contract as :func:`_simulate`."""
    _warn_legacy("simulate",
                 "use repro.core.scenario.records(Scenario(...))")
    return _simulate(prof, cfg, workload, dispatch, drift)


def _simulate(prof: ProfileTable, cfg: SimConfig,
              workload: WorkloadSource | None = None,
              dispatch: DispatchEngine | None = None,
              drift: DriftSchedule | None = None,
              cloud=None, faults=None):
    """Returns a dict of per-request record arrays (length n_requests).
    Single-fleet only — stacked tables go through :func:`simulate_batch` /
    :func:`sweep_grid`, which vmap the fleet axis. ``workload`` /
    ``dispatch`` default to the config's own (``cfg.workload`` /
    ``cfg.dispatch``), else the Markov chain and static dispatch;
    ``drift`` optionally perturbs the true profile mid-run
    (:class:`repro.core.dispatch.DriftSchedule`); ``cloud`` is the
    :class:`~repro.core.cloud.CloudMeta` of an offload-extended ``prof``
    (``CloudTier.extend``), or ``None`` for an edge-only fleet;
    ``faults`` the resolved :class:`~repro.core.faults.FaultMeta` of a
    :class:`~repro.core.faults.FaultSchedule`, or ``None``."""
    if prof.is_stacked:
        raise ValueError("simulate() takes a single (P, G) ProfileTable; "
                         "pass stacked tables to simulate_batch/sweep_grid")
    workload = _resolve_workload(workload, (cfg,))
    dispatch = _resolve_dispatch(dispatch, (cfg,))
    true0, rng, phase = workload.init_draws(
        cfg.seed, cfg.stickiness, n_groups=prof.n_groups,
        n_users=cfg.n_users)
    g = ConfigGrid(
        policy_code=jnp.asarray(POLICY_CODES[cfg.policy], i32),
        n_users=jnp.asarray(cfg.n_users, i32),
        gamma=jnp.asarray(cfg.gamma, f32),
        delta=jnp.asarray(cfg.delta, f32),
        stickiness=jnp.asarray(cfg.stickiness, f32),
        oracle=jnp.asarray(cfg.oracle_estimator, bool),
        rng=jnp.asarray(rng), true0=jnp.asarray(true0, i32),
        phase=jnp.asarray(phase, i32))
    return _simulate_one(prof, workload, dispatch, drift, cloud, faults,
                         g, n_requests=cfg.n_requests)


def simulate_batch(prof: ProfileTable, grid: ConfigGrid, n_requests: int,
                   workload: WorkloadSource | None = None,
                   dispatch: DispatchEngine | None = None,
                   drift: DriftSchedule | None = None):
    """Deprecated: use ``repro.core.scenario.records(Scenario, Sweep)``
    (named axes instead of a flat grid). Same contract as
    :func:`_simulate_batch`."""
    _warn_legacy("simulate_batch",
                 "use repro.core.scenario.records(Scenario, Sweep)")
    return _simulate_batch(prof, grid, n_requests, workload, dispatch,
                           drift)


def _simulate_batch(prof: ProfileTable, grid: ConfigGrid, n_requests: int,
                    workload: WorkloadSource | None = None,
                    dispatch: DispatchEngine | None = None,
                    drift: DriftSchedule | None = None,
                    cloud=None, faults=None):
    """Run every config in ``grid`` as ONE vmapped scan in ONE jit.

    Args:
      prof: fleet profile, either a single ``(P, G)`` table or a stacked
        ``(F, P, G)`` ensemble (``repro.core.profiles.stack_profiles``);
        a stacked table runs every fleet × config combination in the same
        fused program.
      grid: struct-of-arrays batch from :func:`make_grid`, leading dim B.
      n_requests: scan length. Required (no default) and must match the
        configs the grid was built from — the grid carries only traced
        leaves, not scan shapes.
      workload: the scene-complexity source the grid was built with
        (``make_grid(..., workload=...)``); defaults to the Markov
        chain. Must match the build-time source — a grid whose ``phase``
        leaf is nonzero (a trace draw) is rejected under the Markov
        default rather than silently re-interpreted.
      dispatch: dispatch-state engine (``repro.core.dispatch``;
        :class:`StaticDispatch` by default). Its ``DispatchState`` pytree
        rides in the scan carry, so online engines vmap over configs and
        shard over meshes unchanged.
      drift: optional :class:`~repro.core.dispatch.DriftSchedule`
        perturbing the TRUE profile per dispatch step — the scenario hook
        for throttling / model-swap experiments.

    Returns:
      Dict of float32/int32 record arrays with leading dims
      ``(B, n_requests)`` — ``(F, B, n_requests)`` when ``prof`` is
      stacked. Row ``b`` (of fleet ``f``) is bit-identical to
      ``simulate(prof_f, cfg_b)`` for the matching config: padding users
      to ``n_users_max`` and batching over configs/fleets never changes
      any config's trajectory.
    """
    workload = _resolve_workload(workload)
    dispatch = _resolve_dispatch(dispatch)
    if isinstance(workload, MarkovWorkload) and bool(grid.phase.any()):
        raise ValueError(
            "grid carries nonzero workload phase offsets (built with a "
            "trace source) but simulate_batch resolved the Markov "
            "default; pass the grid's own workload= explicitly")
    return _simulate_vmapped(prof, workload, dispatch, drift, cloud,
                             faults, grid, n_requests=n_requests)


def _summarize_core(recs, prof: ProfileTable, warmup: int, cloud=None, *,
                    with_hist: bool = False):
    n = recs["latency"].shape[0]
    sl = {k: v[warmup:] for k, v in recs.items()}
    makespan = jnp.max(sl["t_arrival"] + sl["latency"]) \
        - jnp.min(sl["t_arrival"])
    n_eff = n - warmup
    floor = prof.floor_mw if prof.floor_mw is not None \
        else jnp.zeros((prof.n_pairs,))
    floor_mwh = jnp.sum(floor) * makespan / 3600.0
    out = {
        "latency_ms": 1000.0 * jnp.mean(sl["latency"]),
        "latency_p90_ms": 1000.0 * jnp.percentile(sl["latency"], 90),
        "throughput_rps": n_eff / makespan,
        "energy_mwh": jnp.mean(sl["energy"]) + floor_mwh / n_eff,
        "energy_compute_mwh": jnp.mean(sl["energy"]),
        "map": jnp.mean(sl["map"]),
        "estimator_acc": jnp.mean(sl["correct_group"]),
        "makespan_s": makespan,
    }
    if cloud is not None:
        out["offload_share"] = jnp.mean(
            cloud.is_cloud[sl["server"]].astype(f32))
    if "slo_violation" in recs:
        # fault-plane availability metrics (records carry these keys
        # only when a FaultSchedule is active)
        out["slo_violation_share"] = jnp.mean(sl["slo_violation"])
        out["failed_share"] = jnp.mean(sl["failed"])
        out["latency_p99_ms"] = 1000.0 * jnp.percentile(sl["latency"], 99)
    if with_hist:
        out["latency_hist"] = latency_histogram(sl["latency"])
    return out


def summarize(recs, prof: ProfileTable, cfg: SimConfig):
    """Aggregate a record set into the paper's Fig. 4/5 metrics.
    Single-fleet only (a stacked table's floor term would silently sum
    over fleets); use :func:`summarize_batch` for ensembles."""
    if prof.is_stacked:
        raise ValueError("summarize() takes a single (P, G) ProfileTable; "
                         "use summarize_batch for stacked tables")
    n = recs["latency"].shape[0]
    return _summarize_core(recs, prof, int(n * cfg.warmup_frac))


@functools.partial(jax.jit, static_argnames=("warmup",))
def summarize_batch(recs, prof: ProfileTable, *, warmup: int):
    """Batched :func:`summarize` over ``(B, n_requests)`` record arrays
    (``(F, B, n_requests)`` with a stacked ``prof`` — the fleet axis of
    ``recs`` must match ``prof.n_fleets``). ``warmup`` is the number of
    leading records dropped per config, usually
    ``int(n_requests * warmup_frac)``. Returns ``(B,)`` / ``(F, B)``
    float32 metric vectors; reductions are per config, so values match the
    scalar :func:`summarize` to float32 tolerance (vmap may reassociate)."""
    def per_fleet(r, pf):
        return jax.vmap(lambda r1: _summarize_core(r1, pf, warmup))(r)

    if prof.is_stacked:
        return jax.vmap(per_fleet)(recs, prof)
    return per_fleet(recs, prof)


def run_policy(prof: ProfileTable, policy: str, n_users: int,
               n_requests: int = 2000, gamma: float = 0.5,
               delta: float = 20.0, seed: int = 0, stickiness: float = 0.85,
               workload: WorkloadSource | None = None,
               dispatch: DispatchEngine | None = None,
               drift: DriftSchedule | None = None):
    """Deprecated: use ``repro.core.scenario.run(Scenario(...))`` and
    read ``Results.scalar(metric)``."""
    _warn_legacy("run_policy",
                 "use repro.core.scenario.run(Scenario(...))")
    cfg = SimConfig(n_users=n_users, n_requests=n_requests, policy=policy,
                    gamma=gamma, delta=delta, seed=seed,
                    stickiness=stickiness, workload=workload,
                    dispatch=dispatch)
    recs = _simulate(prof, cfg, drift=drift)
    out = summarize(recs, prof, cfg)
    return {k: float(v) for k, v in out.items()}


SWEEP_AXES = ("policy", "users", "gamma", "delta", "oracle", "seed")


def _sweep_grid_impl(prof, policies, user_levels, gammas, deltas, oracle,
                     seeds, n_requests, stickiness, warmup_frac, mesh,
                     workload, dispatch, drift):
    """The legacy Cartesian sweep AS a Scenario + Sweep: the kwarg axes
    map 1:1 onto Scenario fields (the SWEEP_AXES tuple is just the
    declaration order), and the scenario engine runs the identical
    config product through the identical fused program — bit-identical
    to the pre-scenario engine (golden fixtures pin it)."""
    from repro.core import scenario as SC
    sc = SC.Scenario(profile=prof, n_requests=n_requests,
                     stickiness=stickiness, warmup_frac=warmup_frac,
                     workload=workload, dispatch=dispatch, drift=drift)
    sw = SC.Sweep(policy=tuple(policies), n_users=tuple(user_levels),
                  gamma=tuple(gammas), delta=tuple(deltas),
                  oracle_estimator=tuple(oracle), seed=tuple(seeds))
    return dict(SC.run(sc, sw, mesh=mesh).metrics)


def sweep_grid(prof: ProfileTable, policies=("MO",), user_levels=(15,),
               gammas=(0.5,), deltas=(20.0,), oracle=(False,),
               seeds=(0, 1, 2), n_requests: int = 2000,
               stickiness: float = 0.85, warmup_frac: float = 0.1,
               mesh=None, workload: WorkloadSource | None = None,
               dispatch: DispatchEngine | None = None,
               drift: DriftSchedule | None = None):
    """Cartesian-product sweep as a single fused device program.

    Deprecated: this is now a thin shim over the Scenario path — use
    ``repro.core.scenario.run(Scenario(...), Sweep(...))``, which sweeps
    ANY Scenario field by name (not just these six axes) and returns
    named-axis :class:`~repro.core.scenario.Results`. Results here stay
    bit-identical to the pre-scenario engine.

    Args:
      prof: fleet profile; a stacked ``(F, P, G)`` ensemble sweeps every
        fleet over the same grid in one program.
      policies / user_levels / gammas / deltas / oracle / seeds: the grid
        axes (axis order :data:`SWEEP_AXES`); their Cartesian product is
        flattened into one :func:`make_grid` batch of
        ``B = prod(axis lengths)`` configs.
      n_requests, stickiness, warmup_frac: shared scalar parameters (scan
        shape / chain stickiness / warmup fraction) for every config.
      mesh: optional ``jax.sharding.Mesh`` (e.g.
        ``repro.launch.mesh.make_sweep_mesh()``). When given, the flat
        config axis is sharded over every mesh axis via ``shard_map``,
        padding B up to a multiple of the device count; results are
        bit-identical to the single-device path.
      workload: scene-complexity source shared by every config — the
        Markov chain by default, or a recorded trace
        (``repro.data.traces.TraceWorkload``). Orthogonal to ``mesh``
        and fleet stacking.
      dispatch: dispatch-state engine shared by every config —
        :class:`~repro.core.dispatch.StaticDispatch` by default, or
        :class:`~repro.core.dispatch.OnlineDispatch` for online-EWMA
        adaptation. Orthogonal to ``mesh``, ``workload`` and fleet
        stacking.
      drift: optional :class:`~repro.core.dispatch.DriftSchedule`
        perturbing the TRUE profile mid-run for every config (thermal
        throttling / model swap scenarios).

    Returns:
      ``{metric: float64 ndarray}`` with shape ``(len(policies),
      len(user_levels), len(gammas), len(deltas), len(oracle),
      len(seeds))``, with a leading fleet axis when ``prof`` is stacked.
      The whole grid is one ``vmap(simulate + summarize)`` under one jit;
      the trace is cached across calls with the same batch size, scan
      length, and mesh.
    """
    _warn_legacy("sweep_grid", "use repro.core.scenario.run(Scenario, "
                 "Sweep) — any Scenario field is a sweep axis")
    return _sweep_grid_impl(prof, policies, user_levels, gammas, deltas,
                            oracle, seeds, n_requests, stickiness,
                            warmup_frac, mesh, workload, dispatch, drift)


def sweep(prof: ProfileTable, policies, user_levels, n_requests: int = 2000,
          gamma: float = 0.5, delta: float = 20.0, seeds=(0, 1, 2)):
    """Full Fig. 4-style sweep; returns {policy: {metric: [per-level mean]}}.
    Each configuration runs ``len(seeds)`` times (paper: 3 repetitions).

    Deprecated: use ``repro.core.scenario.run(Scenario, Sweep(policy=...,
    n_users=..., seed=...))`` and ``Results.mean(metric, over="seed")``.
    Single-fleet only — the per-policy dict layout has no fleet axis."""
    _warn_legacy("sweep", "use repro.core.scenario.run(Scenario, Sweep) "
                 "and Results.mean(metric, over='seed')")
    if prof.is_stacked:
        raise ValueError("sweep() returns a per-policy dict with no fleet "
                         "axis; pass stacked ProfileTables to sweep_grid()")
    m = _sweep_grid_impl(prof, policies=policies, user_levels=user_levels,
                         gammas=(gamma,), deltas=(delta,), oracle=(False,),
                         seeds=seeds, n_requests=n_requests,
                         stickiness=0.85, warmup_frac=0.1, mesh=None,
                         workload=None, dispatch=None, drift=None)
    out: dict[str, dict[str, list[float]]] = {}
    for i, pol in enumerate(policies):
        out[pol] = {k: [float(np.mean(v[i, j, 0, 0, 0, :]))
                        for j in range(len(user_levels))]
                    for k, v in m.items()}
    return out
