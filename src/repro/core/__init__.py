"""The paper's contribution: multi-objective load balancing for
heterogeneous inference fleets (profiles, two-stage balancer, baselines,
estimator, fleet simulator, energy model, online adaptation, hierarchy)."""

from repro.core.dispatch import (DispatchEngine, DriftSchedule,
                                 OnlineDispatch, StaticDispatch,
                                 default_dispatch)
from repro.core.estimator import group_of_count, noisy_detected_count
from repro.core.policies import (POLICY_CODES, mo_select, mo_select_batch,
                                 policy_scores, select_pair)
from repro.core.profiles import (ProfileTable, paper_fleet, stack_profiles,
                                 synthetic_fleet)
from repro.core.scenario import (LegacyAPIWarning, Results, Scenario,
                                 Sweep, records, register_profile)
from repro.core.scenario import run as run_scenario
from repro.core.simulator import (ConfigGrid, SimConfig, grid_cache_clear,
                                  grid_cache_info, make_grid, run_policy,
                                  simulate, simulate_batch, summarize,
                                  summarize_batch, sweep, sweep_grid)

__all__ = [
    "Scenario", "Sweep", "Results", "run_scenario", "records",
    "register_profile", "LegacyAPIWarning",
    "ProfileTable", "paper_fleet", "stack_profiles", "synthetic_fleet",
    "POLICY_CODES", "mo_select", "mo_select_batch", "policy_scores",
    "select_pair", "group_of_count", "noisy_detected_count",
    "DispatchEngine", "StaticDispatch", "OnlineDispatch", "DriftSchedule",
    "default_dispatch",
    "ConfigGrid", "SimConfig", "grid_cache_clear", "grid_cache_info",
    "make_grid", "run_policy", "simulate", "simulate_batch", "summarize",
    "summarize_batch", "sweep", "sweep_grid",
]
