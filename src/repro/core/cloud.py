"""The edge-to-cloud offloading tier (ROADMAP item 2): a remote fleet
whose profile includes network cost.

The paper confines every request to an edge device-model pair; the
retrieved papers "Optimizing Edge Offloading Decisions for Object
Detection" and "Decentralized Edge-to-Cloud Load-balancing" (PAPERS.md)
both model a remote tier whose *expected* latency/energy fold in the
network: round-trip propagation, a payload transfer whose size depends on
scene complexity (a busier frame compresses worse), and the radio energy
of the transfer. :class:`CloudTier` packages exactly that as a Scenario
component:

  * :meth:`CloudTier.extend` appends the cloud pairs to a local
    :class:`~repro.core.profiles.ProfileTable` — the extended table's
    ``T[p, g]`` for a cloud pair is ``T_cloud + rtt_ms + xfer_ms(g)``
    (with ``xfer_ms(g) = payload_kb[g] * 8 / bw_mbps``) and its
    ``E[p, g]`` is ``E_cloud + payload_kb[g] * xfer_energy_mj_per_kb /
    3600`` (mJ -> mWh), so the two-stage policy's accuracy filter and
    weighted-sum scoring see offload-vs-local as ordinary pair choice —
    Algorithm 1 needs no new branches;
  * the returned :class:`CloudMeta` is the traced half: the cloud-pair
    mask, the per-group transfer times and the RTT, used by the
    simulator's uplink queue model (the shared uplink is a serial
    resource) and by the scoring-time congestion :meth:`~CloudMeta.
    penalty` — each in-flight offload delays the next transfer by one
    payload, so offloading has negative feedback exactly like local
    queue depths.

At ``rtt_ms=0, bw_mbps=inf, xfer_energy_mj_per_kb=0`` the extension is
free: the extended rows equal the raw cloud tables bit-for-bit and the
congestion penalty vanishes, so a zero-cost cloud pair scores exactly
like a local pair with the same profile (property-tested in
``tests/test_edge_cloud.py``). A scenario with ``cloud=None`` never
builds any of this — the no-cloud engine path is bit-identical to PR 7
(``tests/golden_cloud_pr7.json``)."""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.profiles import ProfileTable

f32 = jnp.float32

__all__ = ["CloudTier", "CloudMeta", "default_cloud_pairs",
           "default_payload_kb"]


def default_cloud_pairs(n_groups: int = 5) -> ProfileTable:
    """The default remote fleet: two datacenter-GPU detector services.
    Compute is fast and accurate on every group (a server-class model
    does not fall off on complex scenes the way edge ssd-class pairs
    do); per-request device energy is ~0 from the edge's perspective —
    the transfer energy (:class:`CloudTier`) is what the edge pays."""
    if n_groups != 5:
        raise ValueError("default_cloud_pairs profiles the paper's 5 "
                         f"complexity groups, got n_groups={n_groups}; "
                         "pass explicit cloud_pairs for other shapes")
    names = ("cloud/yolov8m", "cloud/yolov8x")
    T = jnp.array([
        [14.0, 15.0, 16.0, 17.0, 18.0],
        [26.0, 27.0, 29.0, 31.0, 33.0],
    ])
    E = jnp.zeros((2, 5), f32)
    mAP = jnp.array([
        [77.0, 80.0, 80.5, 81.0, 81.5],
        [78.0, 81.0, 82.0, 83.0, 84.0],
    ])
    return ProfileTable(T, E, mAP, names, jnp.zeros((2,), f32))


def default_payload_kb(n_groups: int) -> np.ndarray:
    """Scene-complexity-dependent payload sizes (KB): a busier frame
    compresses worse, so the uplink cost grows with the group."""
    return np.linspace(40.0, 100.0, n_groups).astype(np.float32)


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class CloudMeta:
    """The traced half of a cloud extension — what jitted code needs.

    Leaves: ``is_cloud`` (P_ext,) bool mask over the extended pair axis,
    ``xfer_ms`` (G,) per-group uplink transfer times, ``rtt_ms`` scalar
    round-trip time. A registered pytree replicated across the config
    axis like the profile table, so cloud grids vmap / shard /
    drift-vmap unchanged."""

    is_cloud: jax.Array      # (P_ext,) bool
    xfer_ms: jax.Array       # (G,) f32
    rtt_ms: jax.Array        # () f32

    def tree_flatten(self):
        return (self.is_cloud, self.xfer_ms, self.rtt_ms), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)

    def penalty(self, g, q):
        """Scoring-time uplink congestion penalty (P_ext,) in ms for a
        request of estimated group ``g`` against live queue depths ``q``:
        each in-flight offload occupies the shared uplink for one
        payload, so a cloud pair's expected latency grows by
        ``xfer_ms[g]`` per queued cloud request. Local pairs pay zero;
        at ``bw = inf`` the penalty vanishes identically (the zero-cost
        cloud bit-equality property depends on this)."""
        isc = self.is_cloud.astype(f32)
        uplink_q = jnp.sum(isc * jnp.asarray(q, f32))
        return isc * self.xfer_ms[jnp.asarray(g)] * uplink_q


@dataclass(frozen=True, eq=False)
class CloudTier:
    """A remote offloading tier as a declarative Scenario component.

    ``rtt_ms`` is the round-trip propagation time, ``bw_mbps`` the
    uplink bandwidth (``inf`` = free transfer), ``xfer_energy_mj_per_kb``
    the radio energy per payload KB (mJ; LTE-class ~3.6),
    ``cloud_pairs`` the remote compute profile (a ``(Pc, G)``
    :class:`~repro.core.profiles.ProfileTable`; None = the
    :func:`default_cloud_pairs` datacenter GPUs) and ``payload_kb`` the
    per-group payload sizes (None = :func:`default_payload_kb`).

    Value-equal like a Scenario (two tiers are ``==`` iff their JSON
    specs match), so ``Results.sel(cloud=tier)`` and scenario hashing
    work; ``Sweep(cloud=[replace(tier, rtt_ms=r) for r in rtts])``
    sweeps the RTT axis."""

    rtt_ms: float = 40.0
    bw_mbps: float = 20.0
    xfer_energy_mj_per_kb: float = 3.6
    cloud_pairs: ProfileTable | None = None
    payload_kb: np.ndarray | None = field(default=None)

    def __post_init__(self):
        if not (self.rtt_ms >= 0.0):
            raise ValueError(f"rtt_ms must be >= 0, got {self.rtt_ms!r}")
        if not (self.bw_mbps > 0.0):
            raise ValueError(f"bw_mbps must be > 0 (inf allowed), got "
                             f"{self.bw_mbps!r}")
        if not (self.xfer_energy_mj_per_kb >= 0.0):
            raise ValueError("xfer_energy_mj_per_kb must be >= 0, got "
                             f"{self.xfer_energy_mj_per_kb!r}")
        if self.cloud_pairs is not None:
            if not isinstance(self.cloud_pairs, ProfileTable):
                raise TypeError("cloud_pairs must be a ProfileTable or "
                                f"None, got {type(self.cloud_pairs)}")
            if self.cloud_pairs.is_stacked:
                raise ValueError("cloud_pairs must be a single (Pc, G) "
                                 "table, not a stacked ensemble")
        if self.payload_kb is not None:
            pl = np.asarray(self.payload_kb, np.float32)
            if pl.ndim != 1 or (pl <= 0).any():
                raise ValueError("payload_kb must be a 1-D positive "
                                 f"array, got {self.payload_kb!r}")
            object.__setattr__(self, "payload_kb", pl)

    # -- resolution -----------------------------------------------------

    def resolve_pairs(self, n_groups: int) -> ProfileTable:
        if self.cloud_pairs is not None:
            if self.cloud_pairs.n_groups != n_groups:
                raise ValueError(
                    f"cloud_pairs profiles {self.cloud_pairs.n_groups} "
                    f"groups, fleet has {n_groups}")
            return self.cloud_pairs
        return default_cloud_pairs(n_groups)

    def resolve_payload(self, n_groups: int) -> np.ndarray:
        if self.payload_kb is not None:
            if self.payload_kb.shape[0] != n_groups:
                raise ValueError(
                    f"payload_kb has {self.payload_kb.shape[0]} groups, "
                    f"fleet has {n_groups}")
            return self.payload_kb
        return default_payload_kb(n_groups)

    def xfer_ms(self, n_groups: int) -> np.ndarray:
        """Per-group uplink transfer time: KB -> kbit over Mbps = ms
        (zeros at ``bw_mbps = inf``)."""
        payload = self.resolve_payload(n_groups)
        return (payload * 8.0 / self.bw_mbps).astype(np.float32)

    def extend(self, prof: ProfileTable) -> tuple[ProfileTable, CloudMeta]:
        """Append the cloud pairs to a local fleet: the extended table's
        cloud rows carry the network-inclusive expected latency/energy
        (module docstring), cloud floors are zero (the datacenter's idle
        power is not the edge operator's bill), and the returned
        :class:`CloudMeta` decomposes the totals back for the
        simulator's uplink model."""
        if prof.is_stacked:
            raise ValueError("CloudTier.extend takes a single (P, G) "
                             "fleet; stacked ensembles are not supported "
                             "with a cloud tier")
        G = prof.n_groups
        pairs = self.resolve_pairs(G)
        payload = jnp.asarray(self.resolve_payload(G), f32)
        xfer = jnp.asarray(self.xfer_ms(G), f32)
        Tc = pairs.T + f32(self.rtt_ms) + xfer[None, :]
        Ec = pairs.E + payload[None, :] \
            * f32(self.xfer_energy_mj_per_kb) / 3600.0
        P, Pc = prof.n_pairs, pairs.n_pairs
        floor_local = prof.floor_mw if prof.floor_mw is not None \
            else jnp.zeros((P,), f32)
        ext = ProfileTable(
            T=jnp.concatenate([prof.T, Tc]),
            E=jnp.concatenate([prof.E, Ec]),
            mAP=jnp.concatenate([prof.mAP, pairs.mAP]),
            names=tuple(prof.names) + tuple(pairs.names),
            floor_mw=jnp.concatenate([floor_local, jnp.zeros((Pc,), f32)]),
        )
        meta = CloudMeta(
            is_cloud=jnp.concatenate([jnp.zeros((P,), bool),
                                      jnp.ones((Pc,), bool)]),
            xfer_ms=xfer,
            rtt_ms=jnp.asarray(self.rtt_ms, f32),
        )
        return ext, meta

    # -- serialization (the Scenario component contract) ---------------

    def to_json(self) -> dict:
        spec = {
            "rtt_ms": float(self.rtt_ms),
            "bw_mbps": float(self.bw_mbps),
            "xfer_energy_mj_per_kb": float(self.xfer_energy_mj_per_kb),
        }
        # defaults serialize as absent keys, so default-equivalent tiers
        # share one spec/hash (the workload/dispatch canonicalization
        # rule); json handles inf (bw) natively via allow_nan
        if self.cloud_pairs is not None:
            p = self.cloud_pairs
            spec["cloud_pairs"] = {
                "T": np.asarray(p.T).tolist(),
                "E": np.asarray(p.E).tolist(),
                "mAP": np.asarray(p.mAP).tolist(),
                "names": list(p.names),
            }
        if self.payload_kb is not None:
            spec["payload_kb"] = np.asarray(self.payload_kb,
                                            np.float64).tolist()
        return spec

    @classmethod
    def from_json(cls, spec: dict | None) -> "CloudTier | None":
        if spec is None:
            return None
        pairs = None
        if spec.get("cloud_pairs") is not None:
            o = spec["cloud_pairs"]
            pairs = ProfileTable(
                jnp.asarray(o["T"], f32), jnp.asarray(o["E"], f32),
                jnp.asarray(o["mAP"], f32), tuple(o.get("names", ())))
        payload = None if spec.get("payload_kb") is None \
            else np.asarray(spec["payload_kb"], np.float32)
        return cls(rtt_ms=float(spec.get("rtt_ms", 40.0)),
                   bw_mbps=float(spec.get("bw_mbps", 20.0)),
                   xfer_energy_mj_per_kb=float(
                       spec.get("xfer_energy_mj_per_kb", 3.6)),
                   cloud_pairs=pairs, payload_kb=payload)

    def __eq__(self, other):
        if not isinstance(other, CloudTier):
            return NotImplemented
        return self.to_json() == other.to_json()

    def __hash__(self):
        spec = self.to_json()
        return hash((spec["rtt_ms"], spec["bw_mbps"],
                     spec["xfer_energy_mj_per_kb"],
                     "cloud_pairs" in spec, "payload_kb" in spec))

    def __repr__(self):
        bw = "inf" if math.isinf(self.bw_mbps) else f"{self.bw_mbps:g}"
        return (f"CloudTier(rtt_ms={self.rtt_ms:g}, bw_mbps={bw}, "
                f"xfer_energy_mj_per_kb={self.xfer_energy_mj_per_kb:g})")
