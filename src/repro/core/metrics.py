"""Detection metrics: mAP computation for the end-to-end example
(pseudo-ground-truth protocol mirroring the paper: a high-capacity model's
detections serve as reference labels)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

f32 = jnp.float32


def cell_matches(pred_obj, ref_obj, threshold: float = 0.0):
    """Grid-cell detection matching: a predicted-positive cell matches a
    reference-positive cell at the same location (coarse IoU proxy for the
    SSD-style per-cell heads in repro.models.detection)."""
    p = pred_obj > threshold
    r = ref_obj > 0.5
    tp = jnp.sum(p & r, axis=(-2, -1))
    fp = jnp.sum(p & ~r, axis=(-2, -1))
    fn = jnp.sum(~p & r, axis=(-2, -1))
    return tp, fp, fn


def average_precision(scores, is_tp, n_ref):
    """AP = area under the precision-recall curve (all-point interpolation).
    scores: (N,) detection confidences; is_tp: (N,) bool; n_ref: #references."""
    order = jnp.argsort(-scores)
    tp = jnp.cumsum(is_tp[order].astype(f32))
    fp = jnp.cumsum((~is_tp[order]).astype(f32))
    recall = tp / jnp.maximum(n_ref, 1)
    precision = tp / jnp.maximum(tp + fp, 1e-9)
    # integrate with right-max interpolation
    prec_interp = jax.lax.associative_scan(jnp.maximum, precision[::-1])[::-1]
    dr = jnp.diff(recall, prepend=0.0)
    return jnp.sum(prec_interp * dr)


def map_from_grids(pred_grids, pred_scores, ref_grids) -> float:
    """mAP (x100) over a set of images given per-cell predictions and
    reference grids; single-class variant used by the e2e example."""
    scores = pred_scores.reshape(-1)
    is_tp = (pred_grids.reshape(-1) > 0) & (ref_grids.reshape(-1) > 0)
    n_ref = jnp.sum(ref_grids > 0)
    return float(average_precision(scores, is_tp, n_ref) * 100.0)
