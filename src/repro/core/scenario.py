"""Declarative scenarios: ONE spec object through sim, serving and
benchmarks.

The paper's evaluation is a grid of *scenarios* — device fleets ×
workload dynamics × dispatch policies × drift — but the engine used to
express a scenario as four parallel kwargs (``workload=``, ``dispatch=``,
``drift=``, ``mesh=``) threaded through six signatures, and sweep axes
were the hardcoded ``SWEEP_AXES`` 6-tuple. This module replaces that
with three objects:

  * :class:`Scenario` — a frozen, JSON-serializable bundle of everything
    one simulated (or served) configuration needs: the fleet profile, the
    scene-complexity :class:`~repro.core.workload.WorkloadSource`, the
    :class:`~repro.core.dispatch.DispatchEngine`, an optional
    :class:`~repro.core.dispatch.DriftSchedule`, a mesh spec, and the
    per-config knobs (policy, concurrency, γ, Δ, stickiness, seed, ...).
    ``to_json``/``from_json`` round-trip it exactly and
    :attr:`Scenario.hash` fingerprints it — benchmark artifacts embed the
    spec so regression gates compare like-for-like.
  * :class:`Sweep` — sweep axes declared **by field name**:
    ``Sweep(policy=("MO", "LT"), stickiness=(0.5, 0.85))`` sweeps any
    ``Scenario`` field, not just the six the legacy tuple hardcoded.
    Config-leaf axes (:data:`CONFIG_AXES`) fuse into ONE batched device
    program exactly like the legacy engine; a ``drift`` axis over
    same-shape schedules fuses as an extra vmapped batch axis; component
    axes (``workload``, ``dispatch``, ...) run one fused program per
    value.
  * :class:`Results` — named-axis summaries: every metric is an ndarray
    whose axes carry the sweep's field names and coordinate values
    (``res.sel("latency_ms", policy="MO", n_users=15)``), so callers
    never reshape flattened config rows again.

The single entry point is :func:`run`; :func:`records` returns the
per-request record arrays for a scenario (the old ``simulate``). The
legacy kwarg entry points of ``repro.core.simulator`` are deprecation-
warned shims over this path and stay bit-identical (the golden fixtures
of ``tests/`` pin that), and ``repro.serving.gateway.Gateway`` accepts a
``Scenario`` directly, so simulation and serving share one config
object. See ``docs/sweep_engine.md`` for the architecture guide and the
legacy-kwarg migration table.
"""

from __future__ import annotations

import functools
import hashlib
import itertools
import json
from dataclasses import dataclass, replace
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import simulator as SIM
from repro.core.cloud import CloudTier
from repro.core.faults import FaultSchedule
from repro.core.dispatch import (DispatchEngine, DriftSchedule,
                                 OnlineDispatch, StaticDispatch)
from repro.core.policies import POLICY_CODES
from repro.core.profiles import ProfileTable, paper_fleet
from repro.core.workload import MarkovWorkload, WorkloadSource

__all__ = ["Scenario", "Sweep", "Results", "run", "records",
           "LegacyAPIWarning", "register_profile", "PROFILE_REGISTRY",
           "CONFIG_AXES", "STATIC_AXES", "COMPONENT_AXES"]

SCHEMA = "repro-scenario/v1"


class LegacyAPIWarning(DeprecationWarning):
    """Issued by the deprecated kwarg entry points of
    ``repro.core.simulator`` (``simulate`` / ``simulate_batch`` /
    ``make_grid`` / ``sweep_grid`` / ``run_policy`` / ``sweep``). The
    tier-1 suite runs with this category escalated to an error
    (``pytest.ini``), proving in-repo callers are migrated; tests that
    pin the legacy contracts opt back in per test with
    ``@pytest.mark.filterwarnings``."""


# Named profiles a Scenario can reference symbolically (and therefore
# serialize by name instead of inlining the tables).
PROFILE_REGISTRY: dict[str, Callable[[], ProfileTable]] = {
    "paper": paper_fleet,
}


def register_profile(name: str, builder: Callable[[], ProfileTable]):
    """Register a named fleet profile so scenarios can reference it
    symbolically (``Scenario(profile=name)``) and serialize by name."""
    PROFILE_REGISTRY[str(name)] = builder


#: Scenario fields that are traced ``ConfigGrid`` leaves: axes over them
#: fuse into ONE batched device program (the flat config axis).
CONFIG_AXES = ("policy", "n_users", "gamma", "delta", "stickiness",
               "oracle_estimator", "seed")
#: Scenario fields that fix the compiled program's *shape*: axes over
#: them run one fused program per value. ``user_block`` is the user-axis
#: block size (``repro.core.useraxis``) — it changes how many block rows
#: each config expands into, a grid shape.
STATIC_AXES = ("n_requests", "warmup_frac", "user_block")
#: Scenario component fields: ``drift`` axes over same-shape schedules
#: fuse as an extra vmapped batch axis; same-shape ``profile`` axes fuse
#: as a stacked fleet axis; the rest (including ``cloud`` — each tier
#: value extends the fleet differently — and ``faults``, whose source
#: flags change the traced graph) loop one fused program per value.
COMPONENT_AXES = ("profile", "workload", "dispatch", "drift", "cloud",
                  "faults")

_SWEEPABLE = CONFIG_AXES + STATIC_AXES + COMPONENT_AXES


# ------------------------------------------------------------ Scenario --

@dataclass(frozen=True, eq=False)
class Scenario:
    """One evaluation scenario, declaratively.

    ``profile`` is either a registry name (:data:`PROFILE_REGISTRY`,
    default ``"paper"`` — the Table I/II testbed) or an explicit
    :class:`~repro.core.profiles.ProfileTable` (a stacked ensemble adds a
    leading ``fleet`` axis to every result). ``workload`` / ``dispatch``
    default to the Markov chain and static offline tables when ``None``;
    ``drift`` optionally perturbs the TRUE profile mid-run. ``mesh`` is a
    *spec*, not a device object: ``None`` (single device), ``"local"``
    (shard the config axis over every local device) or a device count.

    Scenarios are frozen and value-equal (two scenarios are ``==`` iff
    their canonical JSON specs match); :attr:`hash` is a stable
    fingerprint of that spec, embedded in benchmark artifacts so
    ``scripts/check_bench.py`` refuses to diff runs of different
    scenarios.
    """

    profile: ProfileTable | str = "paper"
    policy: str = "MO"
    n_users: int = 15
    n_requests: int = 2000
    gamma: float = 0.5
    delta: float = 20.0
    stickiness: float = 0.85
    seed: int = 0
    warmup_frac: float = 0.1
    oracle_estimator: bool = False
    workload: WorkloadSource | None = None
    dispatch: DispatchEngine | None = None
    drift: DriftSchedule | None = None
    user_block: int | None = None
    # user-axis block size (repro.core.useraxis): n_users > user_block
    # decomposes into ceil(n_users/user_block) independent balancer
    # replicas of <= user_block users, run as extra config rows and
    # segment-reduced back — the scaling path to 10^5..10^6-user fleets.
    # None (default) = one balancer, the paper's single-queue model.
    # Part of the scientific identity (it changes the physical system
    # when n_users > user_block), so it enters the spec/hash — but only
    # when set, keeping every existing scenario's hash unchanged.
    cloud: CloudTier | None = None
    # edge-to-cloud offloading tier (repro.core.cloud.CloudTier): when
    # set, the fleet is extended with remote model pairs whose profiled
    # latency/energy fold in RTT + scene-dependent transfer cost, the
    # simulator serialises uplink transfers, and latency-aware policies
    # see an uplink congestion penalty. None (default) = edge-only, the
    # paper's testbed — bit-identical to the pre-cloud engine
    # (tests/golden_cloud_pr7.json pins it). Scientific identity, so it
    # enters the spec/hash — but only when set.
    faults: FaultSchedule | None = None
    # the fault plane (repro.core.faults.FaultSchedule): device outages,
    # throttling bursts and stochastic WAN jitter, drawn per-step from
    # fold_in-keyed RNG (partition/block/shard-invariant). None
    # (default) = the always-up fleet — bit-identical to the pre-fault
    # engine (tests/golden_faults_pr9.json pins it). Scientific
    # identity, so it enters the spec/hash — but only when set.
    mesh: int | str | None = None

    def __post_init__(self):
        if isinstance(self.profile, str):
            if self.profile not in PROFILE_REGISTRY:
                raise ValueError(
                    f"unknown profile {self.profile!r}; registered: "
                    f"{sorted(PROFILE_REGISTRY)} (register_profile adds "
                    f"more)")
        elif not isinstance(self.profile, ProfileTable):
            raise TypeError("profile must be a registry name or a "
                            f"ProfileTable, got {type(self.profile)}")
        if self.policy not in POLICY_CODES:
            raise ValueError(f"unknown policy {self.policy!r}; one of "
                             f"{sorted(POLICY_CODES)}")
        if self.user_block is not None and (
                not isinstance(self.user_block, int)
                or isinstance(self.user_block, bool)
                or self.user_block <= 0):
            raise ValueError("user_block must be None or a positive int, "
                             f"got {self.user_block!r}")
        if self.cloud is not None and not isinstance(self.cloud,
                                                     CloudTier):
            raise TypeError("cloud must be None or a CloudTier, got "
                            f"{type(self.cloud)}")
        if self.faults is not None and not isinstance(self.faults,
                                                      FaultSchedule):
            raise TypeError("faults must be None or a FaultSchedule, got "
                            f"{type(self.faults)}")
        if not (self.mesh is None or self.mesh == "local"
                or (isinstance(self.mesh, int)
                    and not isinstance(self.mesh, bool)
                    and self.mesh > 0)):
            raise ValueError("mesh must be None, 'local', or a positive "
                             f"device count, got {self.mesh!r}")

    # -- resolution -----------------------------------------------------

    def resolve_profile(self) -> ProfileTable:
        if isinstance(self.profile, str):
            return PROFILE_REGISTRY[self.profile]()
        return self.profile

    def resolve_fleet(self):
        """``(prof, cloud_meta)`` — the fleet the engine actually runs:
        the resolved profile extended with the cloud tier's remote pairs
        (``CloudTier.extend``) when one is set, else ``(profile, None)``.
        """
        prof = self.resolve_profile()
        if self.cloud is None:
            return prof, None
        return self.cloud.extend(prof)

    def resolve_faults(self, n_pairs: int):
        """The :class:`~repro.core.faults.FaultMeta` bound to the
        (cloud-extended) fleet's ``n_pairs``, or ``None``."""
        if self.faults is None:
            return None
        return self.faults.resolve(n_pairs)

    def resolve_workload(self) -> WorkloadSource:
        return SIM._resolve_workload(self.workload)

    def resolve_dispatch(self) -> DispatchEngine:
        return SIM._resolve_dispatch(self.dispatch)

    def resolve_mesh(self):
        """The jax Mesh this scenario's sweeps shard over (or None)."""
        return _resolve_mesh(self.mesh)

    def to_config(self) -> "SIM.SimConfig":
        """The per-config slice of the scenario (a legacy SimConfig)."""
        return SIM.SimConfig(
            n_users=self.n_users, n_requests=self.n_requests,
            policy=self.policy, gamma=self.gamma, delta=self.delta,
            stickiness=self.stickiness, seed=self.seed,
            warmup_frac=self.warmup_frac,
            oracle_estimator=self.oracle_estimator)

    # -- serialization --------------------------------------------------

    def to_json(self) -> dict:
        """A JSON-compatible spec that :meth:`from_json` restores
        exactly. Components serialize by value (profiles by registry name
        when symbolic, inline tables otherwise; traces inline their
        counts), so a spec is self-contained."""
        spec = {
            "schema": SCHEMA,
            "profile": _profile_to_json(self.profile),
            "policy": self.policy,
            "n_users": self.n_users,
            "n_requests": self.n_requests,
            "gamma": self.gamma,
            "delta": self.delta,
            "stickiness": self.stickiness,
            "seed": self.seed,
            "warmup_frac": self.warmup_frac,
            "oracle_estimator": bool(self.oracle_estimator),
            "workload": _workload_to_json(self.workload),
            "dispatch": _dispatch_to_json(self.dispatch),
            "drift": _drift_to_json(self.drift),
            "mesh": self.mesh,
        }
        # only when set: the key's absence keeps every pre-user-axis
        # (and pre-cloud) scenario's canonical spec (and hash)
        # byte-identical
        if self.user_block is not None:
            spec["user_block"] = int(self.user_block)
        if self.cloud is not None:
            spec["cloud"] = self.cloud.to_json()
        if self.faults is not None:
            spec["faults"] = self.faults.to_json()
        return spec

    @classmethod
    def from_json(cls, spec: dict | str) -> "Scenario":
        """Inverse of :meth:`to_json` (accepts the dict or its JSON
        string); ``Scenario.from_json(s.to_json()) == s`` for every
        serializable scenario."""
        if isinstance(spec, str):
            spec = json.loads(spec)
        if spec.get("schema", SCHEMA) != SCHEMA:
            raise ValueError(f"not a {SCHEMA} spec: "
                             f"schema={spec.get('schema')!r}")
        return cls(
            profile=_profile_from_json(spec.get("profile", "paper")),
            policy=spec.get("policy", "MO"),
            n_users=int(spec.get("n_users", 15)),
            n_requests=int(spec.get("n_requests", 2000)),
            gamma=float(spec.get("gamma", 0.5)),
            delta=float(spec.get("delta", 20.0)),
            stickiness=float(spec.get("stickiness", 0.85)),
            seed=int(spec.get("seed", 0)),
            warmup_frac=float(spec.get("warmup_frac", 0.1)),
            oracle_estimator=bool(spec.get("oracle_estimator", False)),
            workload=_workload_from_json(spec.get("workload")),
            dispatch=_dispatch_from_json(spec.get("dispatch")),
            drift=_drift_from_json(spec.get("drift")),
            user_block=(None if spec.get("user_block") is None
                        else int(spec["user_block"])),
            cloud=CloudTier.from_json(spec.get("cloud")),
            faults=FaultSchedule.from_json(spec.get("faults")),
            mesh=spec.get("mesh"),
        )

    def canonical_json(self) -> str:
        return json.dumps(self.to_json(), sort_keys=True,
                          separators=(",", ":"))

    @property
    def hash(self) -> str:
        """Stable 16-hex-digit fingerprint of the canonical spec, MINUS
        the mesh: the mesh is execution topology, not scientific
        identity — sharded results are bit-identical to single-device,
        so a ``--sharded`` benchmark artifact must still be gateable
        against the single-device baseline."""
        spec = self.to_json()
        spec.pop("mesh", None)
        return hashlib.sha256(
            json.dumps(spec, sort_keys=True,
                       separators=(",", ":")).encode()).hexdigest()[:16]

    def __eq__(self, other):
        if not isinstance(other, Scenario):
            return NotImplemented
        try:
            return self.to_json() == other.to_json()
        except TypeError:        # unserializable custom component
            return self is other

    def __hash__(self):
        try:
            return hash(self.canonical_json())
        except TypeError:
            return id(self)


# ------------------------------------------- component (de)serializers --

def _tolist(a) -> list:
    return np.asarray(a).tolist()


def _profile_to_json(p):
    if isinstance(p, str):
        return p
    d = {"kind": "inline", "T": _tolist(p.T), "E": _tolist(p.E),
         "mAP": _tolist(p.mAP), "names": list(p.names)}
    d["floor_mw"] = None if p.floor_mw is None else _tolist(p.floor_mw)
    return d


def _profile_from_json(o):
    if isinstance(o, str):
        return o
    return ProfileTable(
        jnp.asarray(o["T"], jnp.float32), jnp.asarray(o["E"], jnp.float32),
        jnp.asarray(o["mAP"], jnp.float32), tuple(o.get("names", ())),
        None if o.get("floor_mw") is None
        else jnp.asarray(o["floor_mw"], jnp.float32))


def _workload_to_json(w):
    # an explicit MarkovWorkload() IS the default: canonicalize to None
    # so default-equivalent scenarios share one spec, hash and equality
    # (the benchmark gate must not refuse {"kind": "markov"} vs null)
    if w is None or isinstance(w, MarkovWorkload):
        return None
    # late import: repro.data.traces imports repro.core.workload
    from repro.data.traces import TraceWorkload
    if isinstance(w, TraceWorkload):
        return {"kind": "trace", "name": w.name,
                "counts": _tolist(w.counts)}
    raise TypeError(f"cannot serialize workload source {type(w).__name__}"
                    " (only the Markov default and TraceWorkload have a "
                    "spec form)")


def _workload_from_json(o):
    if o is None:
        return None
    if o["kind"] == "markov":
        return MarkovWorkload()
    if o["kind"] == "trace":
        from repro.data.traces import TraceWorkload
        return TraceWorkload(np.asarray(o["counts"], np.int32),
                             name=o.get("name", "trace"))
    raise ValueError(f"unknown workload kind {o['kind']!r}")


def _dispatch_to_json(d):
    # an explicit StaticDispatch() IS the default: canonicalize to None
    # (same reasoning as _workload_to_json; from_json still accepts the
    # {"kind": "static"} form in hand-written specs)
    if d is None or isinstance(d, StaticDispatch):
        return None
    if isinstance(d, OnlineDispatch):
        return {"kind": "online", "alpha": d.alpha,
                "prior_weight": d.prior_weight, "window": d.window}
    raise TypeError(f"cannot serialize dispatch engine {type(d).__name__}")


def _dispatch_from_json(o):
    if o is None:
        return None
    if o["kind"] == "static":
        return StaticDispatch()
    if o["kind"] == "online":
        w = o.get("window")
        return OnlineDispatch(alpha=float(o.get("alpha", 0.1)),
                              prior_weight=float(o.get("prior_weight",
                                                       10.0)),
                              window=None if w is None else int(w))
    raise ValueError(f"unknown dispatch kind {o['kind']!r}")


def _drift_to_json(d):
    if d is None:
        return None
    return {"start_step": _tolist(d.start_step),
            "t_scale": _tolist(d.t_scale), "e_scale": _tolist(d.e_scale)}


def _drift_from_json(o):
    if o is None:
        return None
    return DriftSchedule(np.asarray(o["start_step"], np.int32),
                         np.asarray(o["t_scale"], np.float32),
                         np.asarray(o["e_scale"], np.float32))


def _resolve_mesh(spec):
    if spec is None:
        return None
    from jax.sharding import Mesh
    if isinstance(spec, Mesh):
        return spec
    from repro.launch.mesh import make_sweep_mesh
    if spec == "local":
        return make_sweep_mesh()
    return make_sweep_mesh(int(spec))


# --------------------------------------------------------------- Sweep --

class Sweep:
    """Sweep axes by Scenario field name, e.g. ``Sweep(policy=("MO",
    "LT"), stickiness=(0.5, 0.85), seed=range(3))``.

    Any field in :data:`CONFIG_AXES`, :data:`STATIC_AXES` or
    :data:`COMPONENT_AXES` is sweepable; declaration order is the axis
    order of the :class:`Results`. A scalar value counts as a length-1
    axis. The Cartesian product over config-leaf axes runs as ONE fused
    device program (the legacy ``SWEEP_AXES`` grid is the special case
    ``Sweep(policy=..., n_users=..., gamma=..., delta=...,
    oracle_estimator=..., seed=...)``).
    """

    __slots__ = ("axes",)

    def __init__(self, **axes):
        packed = []
        for name, vals in axes.items():
            if name not in _SWEEPABLE:
                raise ValueError(
                    f"unknown sweep axis {name!r}; sweepable Scenario "
                    f"fields: {', '.join(_SWEEPABLE)}")
            if isinstance(vals, (str, bytes)) \
                    or not hasattr(vals, "__iter__"):
                vals = (vals,)
            vals = tuple(vals)
            if not vals:
                raise ValueError(f"sweep axis {name!r} has no values")
            packed.append((name, vals))
        object.__setattr__(self, "axes", tuple(packed))

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(n for n, _ in self.axes)

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(len(v) for _, v in self.axes)

    def values(self, name: str) -> tuple:
        for n, v in self.axes:
            if n == name:
                return v
        raise KeyError(name)

    def __repr__(self):
        inner = ", ".join(f"{n}={len(v)} values" for n, v in self.axes)
        return f"Sweep({inner})"

    def __eq__(self, other):
        return isinstance(other, Sweep) and self.axes == other.axes

    def __hash__(self):
        return hash(("Sweep", tuple((n, len(v)) for n, v in self.axes)))


def _coord_eq(a, b) -> bool:
    """Coordinate equality for Results.sel: identity, then plain ``==``,
    then structural pytree comparison — so a component rebuilt with the
    same values (a round-tripped DriftSchedule, an equal TraceWorkload)
    still selects its axis entry even when its own ``__eq__`` compares
    arrays and cannot produce a bool."""
    if a is b:
        return True
    if isinstance(a, (np.ndarray, jax.Array)) \
            or isinstance(b, (np.ndarray, jax.Array)):
        return bool(np.array_equal(np.asarray(a), np.asarray(b)))
    try:
        return bool(a == b)
    except Exception:              # array-valued component __eq__
        pass
    if type(a) is not type(b):
        return False
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    return ta == tb and len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# ------------------------------------------------------------- Results --

@dataclass(frozen=True, eq=False)
class Results:
    """Named-axis sweep summaries.

    ``metrics[name]`` is a float64 ndarray whose dimensions follow
    :attr:`axes` (the sweep's declared order, with a leading ``fleet``
    axis when the scenario's profile is a stacked ensemble);
    ``coords[axis]`` holds the coordinate values along each axis.
    :meth:`sel` indexes by coordinate value, so callers never translate
    positions by hand.
    """

    axes: tuple[str, ...]
    coords: dict[str, tuple]
    metrics: dict[str, np.ndarray]
    scenario: Scenario
    sweep: Sweep | None = None

    @property
    def metric_names(self) -> tuple[str, ...]:
        return tuple(self.metrics)

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(len(self.coords[a]) for a in self.axes)

    def __getitem__(self, metric: str) -> np.ndarray:
        return self.metrics[metric]

    def _index_of(self, axis: str, value) -> int:
        for i, v in enumerate(self.coords[axis]):
            if _coord_eq(v, value):
                return i
        raise KeyError(f"{value!r} not on axis {axis!r}; coords: "
                       f"{self.coords[axis]!r}")

    def sel(self, metric: str, **fixed) -> np.ndarray:
        """Select by coordinate value: ``res.sel("latency_ms",
        policy="MO", n_users=15)`` fixes those axes and returns the
        remaining array (a scalar ndarray when everything is fixed)."""
        arr = self.metrics[metric]
        idx: list = [slice(None)] * arr.ndim
        for name, value in fixed.items():
            if name not in self.axes:
                raise KeyError(f"no axis {name!r}; axes: {self.axes}")
            idx[self.axes.index(name)] = self._index_of(name, value)
        return arr[tuple(idx)]

    def mean(self, metric: str, over: str | Sequence[str] = "seed"):
        """Average a metric over one or more named axes (default: the
        ``seed`` axis — the paper's repetition mean)."""
        names = (over,) if isinstance(over, str) else tuple(over)
        dims = tuple(self.axes.index(n) for n in names)
        return self.metrics[metric].mean(axis=dims)

    def scalar(self, metric: str) -> float:
        """The metric as a python float (0-d results only)."""
        arr = self.metrics[metric]
        if arr.ndim:
            raise ValueError(f"{metric} has axes {self.axes}; use sel()")
        return float(arr)

    def __repr__(self):
        ax = ", ".join(f"{a}={len(self.coords[a])}" for a in self.axes)
        return (f"Results([{ax}], metrics={list(self.metrics)}, "
                f"scenario={self.scenario.hash})")


# ------------------------------------------------------------ engine ----

def _stack_drifts(values) -> DriftSchedule | None:
    """Stack same-shape DriftSchedules into one pytree with a leading
    axis (the fused drift-axis form), or None when they don't stack
    (mixed None / differing segment counts -> outer loop instead)."""
    if not all(isinstance(v, DriftSchedule) for v in values):
        return None
    shapes = {tuple(leaf.shape for leaf in jax.tree_util.tree_leaves(v))
              for v in values}
    if len(shapes) > 1:
        return None
    return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *values)


@functools.partial(jax.jit, static_argnames=("n_requests", "warmup"))
def _drift_axis_fused(prof, workload, dispatch, drifts, cloud, faults,
                      grid, *, n_requests: int, warmup: int):
    """The fused drift axis: vmap the simulate+summarize composition over
    a stacked DriftSchedule — the whole drift × config grid (× fleet) is
    ONE device program, leaves shaped (D, [F,] B)."""

    def one(dr):
        return SIM._fused_summaries(prof, workload, dispatch, dr, cloud,
                                    faults, grid, n_requests=n_requests,
                                    warmup=warmup)

    return jax.vmap(one)(drifts)


def _resolve_axis_profile(value) -> ProfileTable:
    if isinstance(value, str):
        if value not in PROFILE_REGISTRY:
            raise ValueError(f"unknown profile {value!r} on sweep axis")
        return PROFILE_REGISTRY[value]()
    if isinstance(value, ProfileTable):
        return value
    raise TypeError(f"profile axis values must be ProfileTables or "
                    f"registry names, got {type(value)}")


def run(scenario: Scenario, sweep: Sweep | None = None, *,
        mesh=None) -> Results:
    """Evaluate a scenario (optionally swept) and return named-axis
    summaries.

    Axis fusion: config-leaf axes (:data:`CONFIG_AXES`) flatten into the
    batched engine's config axis — one ``vmap(simulate + summarize)``
    under one jit, sharded over the scenario's mesh when set. A ``drift``
    axis over same-shape schedules becomes an extra vmapped batch axis in
    the same program (single-device path); a ``profile`` axis over
    same-shape fleets becomes a stacked fleet axis. Axes over
    ``workload`` / ``dispatch`` / ``n_requests`` / ``warmup_frac`` (and
    non-stackable drift/profile values) run one fused program per value.

    ``mesh`` overrides the scenario's mesh spec and may be an actual
    ``jax.sharding.Mesh`` (the legacy ``sweep_grid(mesh=...)`` shim uses
    this).

    Returns a :class:`Results`; with no sweep the metric arrays are 0-d
    (``Results.scalar``). A stacked-profile scenario adds a leading
    ``fleet`` axis.
    """
    sweep = sweep if sweep is not None else Sweep()
    mesh_obj = _resolve_mesh(scenario.mesh if mesh is None else mesh)

    config_axes = [(n, v) for n, v in sweep.axes if n in CONFIG_AXES]
    config_names = [n for n, _ in config_axes]
    config_dims = [len(v) for _, v in config_axes]

    profile_axis = None       # ("profile", values) fused via stacking
    drift_axis = None         # ("drift", values, stacked) fused via vmap
    outer_axes: list[tuple[str, tuple]] = []
    for n, v in sweep.axes:
        if n in CONFIG_AXES:
            continue
        if n == "profile":
            tables = [_resolve_axis_profile(x) for x in v]
            if any(t.is_stacked for t in tables):
                raise ValueError("profile axis values must be single "
                                 "(P, G) tables — the axis itself is "
                                 "the ensemble dimension")
            if len({t.T.shape for t in tables}) == 1:
                from repro.core.profiles import stack_profiles
                profile_axis = (n, v, stack_profiles(tables))
                continue
            outer_axes.append((n, tuple(tables)))
        elif n == "drift" and mesh_obj is None \
                and (stacked := _stack_drifts(v)) is not None:
            drift_axis = (n, v, stacked)
        else:
            outer_axes.append((n, v))

    base_prof = profile_axis[2] if profile_axis \
        else scenario.resolve_profile()
    # ANY profile axis (fused or ragged/outer) replaces the scenario's
    # own profile, so the implicit fleet axis only exists when the
    # scenario's stacked profile is actually the one running
    profile_is_outer = any(n == "profile" for n, _ in outer_axes)
    implicit_fleet = profile_axis is None and not profile_is_outer \
        and base_prof.is_stacked

    outer_names = [n for n, _ in outer_axes]
    outer_dims = [len(v) for _, v in outer_axes]

    # a cloud axis mixing None (edge-only) and tiers must still produce
    # one consistent metric set: edge-only combos report offload_share 0
    cloud_vals = next((v for n, v in outer_axes if n == "cloud"),
                      (scenario.cloud,))
    any_cloud = any(v is not None for v in cloud_vals)
    # same rule for a faults axis mixing None and schedules: fault-free
    # combos report zero failed/SLO shares (p99 backfilled below)
    fault_vals = next((v for n, v in outer_axes if n == "faults"),
                      (scenario.faults,))
    any_faults = any(v is not None for v in fault_vals)

    metrics: dict[str, np.ndarray] | None = None
    block_shape: tuple[int, ...] = ()
    for oi, combo in enumerate(itertools.product(
            *(v for _, v in outer_axes))):
        override = dict(zip(outer_names, combo))
        prof = override.pop("profile", base_prof)
        sc = replace(scenario, **{k: v for k, v in override.items()
                                  if k != "drift"}) \
            if any(k != "drift" for k in override) else scenario
        drift = override["drift"] if "drift" in override else sc.drift
        workload = sc.resolve_workload()
        dispatch = sc.resolve_dispatch()
        if sc.cloud is not None:
            if prof.is_stacked:
                raise ValueError("cloud tier does not compose with "
                                 "stacked fleet profiles (each fleet "
                                 "would need its own extension); sweep "
                                 "single-fleet profiles instead")
            prof, cloud_meta = sc.cloud.extend(prof)
        else:
            cloud_meta = None
        fault_meta = sc.resolve_faults(prof.n_pairs)
        n_requests = sc.n_requests
        warmup = int(n_requests * sc.warmup_frac)

        base = dict(n_users=sc.n_users, n_requests=n_requests,
                    policy=sc.policy, gamma=sc.gamma, delta=sc.delta,
                    stickiness=sc.stickiness, seed=sc.seed,
                    warmup_frac=sc.warmup_frac,
                    oracle_estimator=sc.oracle_estimator)
        cfgs = [SIM.SimConfig(**{**base, **dict(zip(config_names, vals))})
                for vals in itertools.product(
                    *(v for _, v in config_axes))]
        if sc.user_block is None:
            grid, segments = SIM._make_grid(prof, cfgs,
                                            workload=workload), None
        else:
            # user-blocked grid: each config's balancer-replica blocks
            # are extra rows on the config axis (vmapped/sharded as
            # usual), segment-reduced back to per-config metrics below
            grid, segments = SIM._make_user_grid(prof, cfgs,
                                                 sc.user_block,
                                                 workload=workload)

        if drift_axis is not None:
            out = _drift_axis_fused(prof, workload, dispatch,
                                    drift_axis[2], cloud_meta, fault_meta,
                                    grid, n_requests=n_requests,
                                    warmup=warmup)
        else:
            with_hist = segments is not None \
                and int(np.asarray(segments).shape[0]) > len(cfgs)
            out = SIM._sweep_summaries(prof, workload, dispatch, drift,
                                       cloud_meta, fault_meta, grid,
                                       n_requests=n_requests,
                                       warmup=warmup, mesh=mesh_obj,
                                       with_hist=with_hist)
        if segments is not None:
            out = SIM.aggregate_block_summaries(out, segments, len(cfgs),
                                                block_axis=-1)
        if any_cloud and "offload_share" not in out:
            out = dict(out)
            out["offload_share"] = jnp.zeros_like(out["latency_ms"])
        if any_faults and "slo_violation_share" not in out:
            out = dict(out)
            for m in ("slo_violation_share", "failed_share",
                      "latency_p99_ms"):
                out[m] = jnp.zeros_like(out["latency_ms"])

        block_shape = ((len(drift_axis[1]),) if drift_axis else ()) \
            + ((prof.n_fleets,) if prof.is_stacked else ()) \
            + tuple(config_dims)
        if metrics is None:
            metrics = {k: np.empty(tuple(outer_dims) + block_shape,
                                   np.float64) for k in out}
        oidx = np.unravel_index(oi, tuple(outer_dims)) if outer_axes \
            else ()
        for k, v in out.items():
            metrics[k][oidx] = np.asarray(
                v, np.float64).reshape(block_shape)

    # internal layout -> declared axis order
    fleet_name = ("profile" if profile_axis
                  else ("fleet" if implicit_fleet else None))
    internal = list(outer_names) \
        + (["drift"] if drift_axis else []) \
        + ([fleet_name] if fleet_name else []) \
        + config_names
    final = (["fleet"] if implicit_fleet else []) + list(sweep.names)
    perm = [internal.index(n) for n in final]
    assert metrics is not None
    # (np.ascontiguousarray would promote 0-d results to 1-d; copy() keeps
    # the transposed layout materialized without changing rank)
    metrics = {k: np.transpose(v, perm).copy() for k, v in metrics.items()}

    coords: dict[str, tuple] = {}
    if implicit_fleet:
        coords["fleet"] = tuple(range(base_prof.n_fleets))
    for n, v in sweep.axes:
        coords[n] = v
    return Results(axes=tuple(final), coords=coords, metrics=metrics,
                   scenario=scenario, sweep=sweep)


def records(scenario: Scenario, sweep: Sweep | None = None):
    """Per-request record arrays for a scenario (the scenario-path
    ``simulate``).

    Without a sweep: a dict of ``(n_requests,)`` arrays for the single
    config (single-fleet profiles only — stacked ensembles need the
    batched form). With a sweep over config-leaf axes only
    (:data:`CONFIG_AXES`): one fused batched run whose record arrays
    carry the named axes as leading dims, shape ``(*axis_lens,
    n_requests)`` (``(F, *axis_lens, n_requests)`` stacked). Rows are
    bit-identical to each config's own single run — the engine's padding
    /batching guarantee.
    """
    prof, cloud_meta = scenario.resolve_fleet()
    workload = scenario.resolve_workload()
    dispatch = scenario.resolve_dispatch()
    if scenario.user_block is not None:
        # single-block configs run the identical program, so records are
        # well-defined (and bit-identical to user_block=None); multi-
        # block configs have no single per-request stream to return
        max_users = max([scenario.n_users]
                        + [max(v) for n, v in (sweep.axes if sweep else ())
                           if n == "n_users"])
        if max_users > scenario.user_block:
            raise ValueError(
                "records() needs n_users <= user_block (a multi-block "
                "config is K independent balancer replicas with no "
                "single record stream); use run() for aggregate metrics")
    fault_meta = scenario.resolve_faults(prof.n_pairs)
    if sweep is None or not sweep.axes:
        return SIM._simulate(prof, scenario.to_config(),
                             workload=workload, dispatch=dispatch,
                             drift=scenario.drift, cloud=cloud_meta,
                             faults=fault_meta)
    bad = [n for n in sweep.names if n not in CONFIG_AXES]
    if bad:
        raise ValueError(
            f"records() sweeps config-leaf axes only {CONFIG_AXES}; "
            f"got {bad} (use run() for component/static axes)")
    base = dict(n_users=scenario.n_users, n_requests=scenario.n_requests,
                policy=scenario.policy, gamma=scenario.gamma,
                delta=scenario.delta, stickiness=scenario.stickiness,
                seed=scenario.seed, warmup_frac=scenario.warmup_frac,
                oracle_estimator=scenario.oracle_estimator)
    names = list(sweep.names)
    cfgs = [SIM.SimConfig(**{**base, **dict(zip(names, vals))})
            for vals in itertools.product(*(v for _, v in sweep.axes))]
    grid = SIM._make_grid(prof, cfgs, workload=workload)
    recs = SIM._simulate_batch(prof, grid,
                               n_requests=scenario.n_requests,
                               workload=workload, dispatch=dispatch,
                               drift=scenario.drift, cloud=cloud_meta,
                               faults=fault_meta)
    dims = sweep.shape
    pre = (prof.n_fleets,) if prof.is_stacked else ()
    return {k: v.reshape(pre + dims + v.shape[len(pre) + 1:])
            for k, v in recs.items()}
