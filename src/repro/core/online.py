"""Online profile adaptation (the paper's §VII future work, implemented).

The static offline tables drift when hardware throttles, models are updated
or input distributions shift. ``OnlineProfiles`` keeps an EWMA of observed
latency/energy per (pair, group) on top of the offline prior, with a
pseudo-count ramp so cold cells trust the prior and hot cells trust
measurements. Pure-functional: state in, state out — usable inside the
jitted gateway and the simulator."""

from __future__ import annotations


import jax.numpy as jnp

from repro.core.profiles import ProfileTable

f32 = jnp.float32


def init_state(prof: ProfileTable):
    return {
        "T": prof.T.astype(f32),
        "E": prof.E.astype(f32),
        "count": jnp.zeros_like(prof.T),
    }


def observe(state, p, g, obs_t_ms, obs_e_mwh=None, alpha: float = 0.1,
            prior_weight: float = 10.0):
    """Fold one observation into the EWMA. The effective step size anneals
    from ~0 (trust prior) to ``alpha`` as observations accumulate."""
    c = state["count"][p, g]
    eff = alpha * c / (c + prior_weight)
    new_T = state["T"].at[p, g].mul(1.0 - eff)
    new_T = new_T.at[p, g].add(eff * obs_t_ms)
    out = dict(state)
    out["T"] = new_T
    out["count"] = state["count"].at[p, g].add(1.0)
    if obs_e_mwh is not None:
        new_E = state["E"].at[p, g].mul(1.0 - eff)
        out["E"] = new_E.at[p, g].add(eff * obs_e_mwh)
    return out


def as_profile(state, prof: ProfileTable) -> ProfileTable:
    """Materialise the adapted tables (mAP stays offline-profiled: accuracy
    cannot be observed online without labels)."""
    return ProfileTable(state["T"], state["E"], prof.mAP, prof.names,
                        prof.floor_mw)


def drift_robustness_gap(prof: ProfileTable, drifted: ProfileTable,
                         state) -> dict:
    """Diagnostics for the drift experiment (EXPERIMENTS.md §Online): RMS
    error of static vs adapted tables against the drifted ground truth."""
    rms = lambda a, b: float(jnp.sqrt(jnp.mean(jnp.square(a - b))))
    return {
        "static_T_rms": rms(prof.T, drifted.T),
        "adapted_T_rms": rms(state["T"], drifted.T),
    }
