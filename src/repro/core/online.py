"""Online profile adaptation (the paper's §VII future work, implemented).

The static offline tables drift when hardware throttles, models are updated
or input distributions shift. ``OnlineProfiles`` keeps an EWMA of observed
latency/energy per (pair, group) on top of the offline prior, with a
pseudo-count ramp so cold cells trust the prior and hot cells trust
measurements. Pure-functional: state in, state out — usable inside the
jitted gateway and the simulator (``repro.core.dispatch.OnlineDispatch``
threads this state through the batched scan).

State is a dict pytree with ``T``/``E`` belief tables and a per-cell
``count``; extra keys (e.g. the dispatch engines' round-robin counter)
pass through every helper untouched.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.profiles import ProfileTable

f32 = jnp.float32


def init_state(prof: ProfileTable):
    return {
        "T": prof.T.astype(f32),
        "E": prof.E.astype(f32),
        "count": jnp.zeros_like(prof.T),
    }


def _ewma_cell(val, obs, eff):
    """One annealed-EWMA cell update: move ``val`` toward ``obs`` by the
    effective step ``eff`` (shared by the T and E tables, scalar and
    windowed paths — the single place the fold is written)."""
    return val * (1.0 - eff) + eff * obs


def observe(state, p, g, obs_t_ms, obs_e_mwh=None, alpha: float = 0.1,
            prior_weight: float = 10.0):
    """Fold one observation into the EWMA. The effective step size anneals
    from ~0 (trust prior) to ``alpha`` as observations accumulate."""
    c = state["count"][p, g]
    eff = alpha * c / (c + prior_weight)
    out = dict(state)
    out["T"] = state["T"].at[p, g].set(
        _ewma_cell(state["T"][p, g], obs_t_ms, eff))
    out["count"] = state["count"].at[p, g].add(1.0)
    if obs_e_mwh is not None:
        out["E"] = state["E"].at[p, g].set(
            _ewma_cell(state["E"][p, g], obs_e_mwh, eff))
    return out


def observe_window(state, pairs, groups, obs_t_ms, obs_e_mwh=None,
                   alpha: float = 0.1, prior_weight: float = 10.0):
    """Fold a whole routing window of observations in one call — the
    batched :func:`observe` behind the gateway's windowed ``moscore``
    path.

    ``pairs``/``groups``/``obs_t_ms`` (and optionally ``obs_e_mwh``) are
    (W,) arrays, one entry per completed request, in completion order.
    Equivalent to W sequential :func:`observe` calls: updates to distinct
    cells commute, and within a cell the fold preserves window order. The
    fold runs per cell and is vmapped over the (P, G) table, so the whole
    window is one device program instead of W scatter round-trips.
    """
    pairs = jnp.asarray(pairs, jnp.int32)
    groups = jnp.asarray(groups, jnp.int32)
    obs_t = jnp.asarray(obs_t_ms, f32)
    has_e = obs_e_mwh is not None
    obs_e = jnp.asarray(obs_e_mwh, f32) if has_e else jnp.zeros_like(obs_t)

    def one_cell(p, g, T0, E0, c0):
        def fold(carry, w):
            T, E, c = carry
            hit = (pairs[w] == p) & (groups[w] == g)
            eff = jnp.where(hit, alpha * c / (c + prior_weight), 0.0)
            T = _ewma_cell(T, obs_t[w], eff)
            E = _ewma_cell(E, obs_e[w], eff) if has_e else E
            return (T, E, c + hit.astype(f32)), None

        (T, E, c), _ = jax.lax.scan(fold, (T0, E0, c0),
                                    jnp.arange(pairs.shape[0]))
        return T, E, c

    P, G = state["T"].shape
    pp, gg = jnp.meshgrid(jnp.arange(P), jnp.arange(G), indexing="ij")
    T, E, c = jax.vmap(jax.vmap(one_cell))(pp, gg, state["T"], state["E"],
                                           state["count"])
    out = dict(state)
    out["T"], out["count"] = T, c
    if has_e:
        out["E"] = E
    return out


# ----------------------------------------------- sliding-window variant --
#
# The annealed EWMA above never discounts stale evidence: after a large
# drift its belief closes the gap by only ``alpha`` per observation, no
# matter how much pre-drift history a cell carries. The windowed estimator
# keeps the last ``window`` observations per cell in a ring buffer and
# scores against their mean (blended with the offline prior while the
# prior's pseudo-count outweighs the evidence), so ``window`` observations
# after a drift the belief is *fully* post-drift — the "sliding-window
# EWMA" forgetting scheme of the ROADMAP's drift-detection item.
# ``repro.core.dispatch.OnlineDispatch(window=...)`` selects it.


def init_window_state(prof: ProfileTable, window: int):
    """Ring-buffer state for the sliding-window estimator: per-cell sums
    and ``(P, G, window)`` buffers for T and E, plus per-cell observation
    counts (E has its own — energy is not always observed). Counts are
    int32, not float32: a float32 counter saturates at 2^24 (c + 1 == c),
    which would freeze the ring index of a long-lived serving gateway and
    pin stale slots forever — the exact staleness this estimator exists
    to discard."""
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    z = jnp.zeros_like(prof.T)
    c = jnp.zeros(prof.T.shape, jnp.int32)
    buf = jnp.zeros(prof.T.shape + (window,), f32)
    return {"tsum": z, "esum": z, "tbuf": buf, "ebuf": buf,
            "count": c, "ecount": c}


def observe_windowed(state, p, g, obs_t_ms, obs_e_mwh=None, *,
                     window: int):
    """Fold one observation into the ring buffer: overwrite the cell's
    oldest slot and maintain the running window sum (unconditionally —
    unfilled slots hold zero, so the subtraction is a no-op while the
    window fills). Traced; scan/vmap-safe like :func:`observe`."""
    out = dict(state)
    i = jnp.mod(state["count"][p, g], window)
    out["tsum"] = state["tsum"].at[p, g].add(
        obs_t_ms - state["tbuf"][p, g, i])
    out["tbuf"] = state["tbuf"].at[p, g, i].set(obs_t_ms)
    out["count"] = state["count"].at[p, g].add(1)
    if obs_e_mwh is not None:
        j = jnp.mod(state["ecount"][p, g], window)
        out["esum"] = state["esum"].at[p, g].add(
            obs_e_mwh - state["ebuf"][p, g, j])
        out["ebuf"] = state["ebuf"].at[p, g, j].set(obs_e_mwh)
        out["ecount"] = state["ecount"].at[p, g].add(1)
    return out


def observe_windowed_batch(state, pairs, groups, obs_t_ms,
                           obs_e_mwh=None, *, window: int):
    """Fold a whole routing window into the ring buffers as ONE device
    program — the batched :func:`observe_windowed`.

    Ring updates are order-dependent *within* a cell (each observation
    overwrites the oldest slot), so unlike the annealed
    :func:`observe_window` this fold cannot be vmapped per cell; instead
    a ``lax.scan`` applies the W cell updates sequentially, preserving
    completion order exactly — bit-identical to W :func:`observe_windowed`
    calls, but one fused program instead of W scatter round-trips (the
    serving gateway's windowed observation path under
    ``OnlineDispatch(window=...)``)."""
    pairs = jnp.asarray(pairs, jnp.int32)
    groups = jnp.asarray(groups, jnp.int32)
    obs_t = jnp.asarray(obs_t_ms, f32)
    has_e = obs_e_mwh is not None
    obs_e = jnp.asarray(obs_e_mwh, f32) if has_e else None

    def fold(st, w):
        return observe_windowed(st, pairs[w], groups[w], obs_t[w],
                                obs_e[w] if has_e else None,
                                window=window), None

    state, _ = jax.lax.scan(fold, state, jnp.arange(pairs.shape[0]))
    return state


def window_tables(state, prof: ProfileTable, *, window: int,
                  prior_weight: float = 10.0) -> ProfileTable:
    """Belief tables from the ring buffers: each cell is the mean of its
    last ``min(count, window)`` observations blended with the offline
    prior at pseudo-count ``max(prior_weight - count, 0)`` — cold cells
    trust the prior, and once a cell has seen ``prior_weight`` real
    observations the prior has washed out entirely (unlike the annealed
    EWMA, whose prior never fully leaves the estimate)."""

    def blend(prior, s, c):
        c = c.astype(f32)
        n = jnp.minimum(c, float(window))
        pw = jnp.maximum(prior_weight - c, 0.0)
        # untouched cells return the prior BIT-exactly (the blend would
        # round through (pw * prior) / pw); c > 0 implies n >= 1, so the
        # division in the taken branch is always well-defined
        return jnp.where(c > 0.0,
                         (pw * prior + s) / jnp.maximum(pw + n, 1e-9),
                         prior)

    return ProfileTable(blend(prof.T, state["tsum"], state["count"]),
                        blend(prof.E, state["esum"], state["ecount"]),
                        prof.mAP, prof.names, prof.floor_mw)


def as_profile(state, prof: ProfileTable) -> ProfileTable:
    """Materialise the adapted tables (mAP stays offline-profiled: accuracy
    cannot be observed online without labels)."""
    return ProfileTable(state["T"], state["E"], prof.mAP, prof.names,
                        prof.floor_mw)


def drift_robustness_gap(prof: ProfileTable, drifted: ProfileTable,
                         state) -> dict:
    """Diagnostics for the drift experiment (EXPERIMENTS.md §Online): RMS
    error of static vs adapted tables against the drifted ground truth."""
    rms = lambda a, b: float(jnp.sqrt(jnp.mean(jnp.square(a - b))))
    return {
        "static_T_rms": rms(prof.T, drifted.T),
        "adapted_T_rms": rms(state["T"], drifted.T),
    }
