"""int8-quantized routing tables for the moscore hot path.

The router's working set is tiny — (P, G) fp32 tables — but on the
serving hot path the latency/energy half is re-materialised every
admission window (the :class:`~repro.core.dispatch.OnlineDispatch`
belief blend) and handed to the fused kernel. :class:`QuantProfileTable`
stores ``T`` and ``E`` as int8 with one fp32 scale per *group column*:
all P pairs of a group share a scale, so dequantisation is a (P, G)
multiply and the error per cell is bounded by half a quantisation step
of its column's absmax (|x - deq(q(x))| <= absmax_g / 254).

``mAP`` is deliberately NOT quantized. It exists only to build the
accuracy-feasibility mask — a queue-independent bool table the hoisted
kernel precomputes once — and quantising it flips feasibility at the Δ
boundary, which lets the router pick accuracy-infeasible pairs (score
regret up to the full normalised range, measured). Keeping mAP fp32
matches the belief-table contract too: ``OnlineDispatch`` adapts T/E
from observations and keeps mAP offline-profiled, so T/E are exactly
the tables that churn per window.

The quantisation machinery is ``repro.training.compression.quantize_int8``
— the same per-chunk absmax scheme the cross-pod gradient reduction uses
(and ``tests/test_kv_quant.py``'s int8 KV cache before it) — applied with
``chunk = P`` to the transposed (G, P) table, so each chunk IS a group
column.

Routing against dequantised tables is NOT bit-identical to fp32 routing:
the contract is *bounded decision mismatch* instead — every choice stays
accuracy-feasible by construction, mismatches happen only between
near-tied candidates (fp32-score regret bounded; hypothesis-tested in
``tests/test_quant_route.py`` with end-metric deltas bounded on the
paper-fleet sweep). The fp32 ``hoisted`` backend keeps the bit-identical
contract; ``int8`` trades near-tie exactness for a 4x smaller hot-table
footprint. See ``docs/kernels.md``.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.profiles import ProfileTable
from repro.training.compression import dequantize_int8, quantize_int8

f32 = jnp.float32


def _quantize_columns(x):
    """(P, G) fp32 -> ((P, G) int8, (G,) fp32 per-group-column scales),
    via :func:`quantize_int8` on the transposed table with ``chunk = P``
    (each chunk is exactly one group column)."""
    P = x.shape[-2]
    q, scales, _shape = quantize_int8(jnp.asarray(x, f32).T, chunk=P)
    return q.T, scales


def _dequantize_columns(q, scales):
    """Inverse of :func:`_quantize_columns` (shapes (P, G) + (G,))."""
    return dequantize_int8(q.T, scales, q.T.shape).T


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class QuantProfileTable:
    """A :class:`~repro.core.profiles.ProfileTable` with its latency and
    energy tables quantized to int8 under per-group-column fp32 scales —
    the wire/VMEM format the int8 moscore backend scores against.

    ``qT``/``qE`` are (P, G) int8 with (G,) scales; ``mAP`` rides along
    fp32 (see the module docstring for why). A registered pytree, so it
    crosses ``jit`` boundaries and :meth:`from_profile` /
    :meth:`dequantize` are traced (the gateway can quantize the
    OnlineDispatch belief blend per window inside its jitted route)."""

    qT: jax.Array               # (P, G) int8
    qE: jax.Array               # (P, G) int8
    t_scale: jax.Array          # (G,) fp32 per-group-column scales
    e_scale: jax.Array          # (G,)
    mAP: jax.Array              # (P, G) fp32 — feasibility stays exact
    names: tuple[str, ...] = ()

    def tree_flatten(self):
        return ((self.qT, self.qE, self.t_scale, self.e_scale, self.mAP),
                self.names)

    @classmethod
    def tree_unflatten(cls, names, leaves):
        return cls(*leaves, names)

    @property
    def n_pairs(self) -> int:
        return self.qT.shape[-2]

    @property
    def n_groups(self) -> int:
        return self.qT.shape[-1]

    @property
    def nbytes_hot(self) -> int:
        """Payload bytes of the per-window (belief) half: int8 T/E cells
        plus their fp32 column scales — vs ``8 * P * G`` unquantized."""
        return 2 * self.n_pairs * self.n_groups + 2 * 4 * self.n_groups

    @classmethod
    def from_profile(cls, prof: ProfileTable) -> "QuantProfileTable":
        if prof.is_stacked:
            raise ValueError("QuantProfileTable quantizes one fleet; "
                             "stacked (F, P, G) tables are not supported")
        qT, ts = _quantize_columns(prof.T)
        qE, es = _quantize_columns(prof.E)
        return cls(qT, qE, ts, es, jnp.asarray(prof.mAP, f32), prof.names)

    def dequantize(self) -> ProfileTable:
        """Materialise fp32 belief tables from the int8 payload (what the
        int8 backend actually scores against — so CPU/TPU agree on the
        quantisation error by construction). ``floor_mw`` is not part of
        the routing hot path and is dropped."""
        return ProfileTable(_dequantize_columns(self.qT, self.t_scale),
                            _dequantize_columns(self.qE, self.e_scale),
                            self.mAP, self.names)


def quantize_roundtrip(prof: ProfileTable) -> ProfileTable:
    """fp32 -> int8 -> fp32: the tables the int8 backend scores against."""
    return QuantProfileTable.from_profile(prof).dequantize()
