"""Task-allocation policies (paper §III-B Algorithm 1 + §IV-B baselines),
as pure JAX functions over ProfileTable arrays.

All policies share one interface so the simulator, the serving gateway and
the Pallas ``moscore`` kernel agree bit-for-bit:

    scores = policy_scores(code, prof, g, q, rnd, rr_counter, gamma, delta)
    p*     = argmin(scores)

The two-stage MO policy is also exposed directly (:func:`mo_select`, exact
Algorithm 1) and in a queue-feedback batched form (:func:`mo_select_batch`,
``lax.scan`` over a routing window — the reference for the kernel)."""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.profiles import ProfileTable

f32 = jnp.float32
BIG = jnp.asarray(1e30, f32)

POLICY_CODES = {"MO": 0, "RR": 1, "RND": 2, "LC": 3, "LE": 4, "LT": 5,
                "HA": 6}
POLICY_NAMES = {v: k for k, v in POLICY_CODES.items()}


# ------------------------------------------------------------ Algorithm 1 --

def mo_scores(T_g, E_g, mAP_g, q, *, delta: float, gamma: float,
              penalty=None, health=None):
    """Vectorised Algorithm 1 scores over the P pairs for one request.

    T_g/E_g/mAP_g: (P,) profiled columns for the request's group;
    q: (P,) live queue depths. Returns (J, feasible): infeasible pairs get
    +inf so argmin(J) == argmin over the accuracy-feasible candidate set.

    ``penalty`` (optional, (P,) ms) is an additive expected-latency term —
    the cloud tier's uplink congestion feedback
    (:meth:`repro.core.cloud.CloudMeta.penalty`). ``health`` (optional,
    (P,) bool) is the fault plane's per-step mask
    (:meth:`repro.core.faults.FaultMeta.health_at`): down pairs are
    removed from the candidate set at this accuracy-feasibility stage.
    Graceful degradation is defined here: when NO healthy pair clears
    the accuracy bar (the bar itself stays the unmasked fleet-wide
    ``map_max``), the candidate set relaxes to ALL healthy pairs and the
    energy term is zeroed, so ``argmin J`` becomes the healthy
    argmin-expected-latency pair — the caller counts that step as an
    SLO violation. ``None`` (every no-fault caller) leaves the traced
    graph exactly as before."""
    map_max = jnp.max(mAP_g)
    feasible = mAP_g >= map_max - delta
    if health is not None:
        cand = feasible & health
        degraded = ~jnp.any(cand)
        feasible = jnp.where(degraded, health, cand)
    L_exp = T_g * (1.0 + q)
    if penalty is not None:
        L_exp = L_exp + penalty
    l_min = jnp.min(jnp.where(feasible, L_exp, BIG))
    l_max = jnp.max(jnp.where(feasible, L_exp, -BIG))
    e_min = jnp.min(jnp.where(feasible, E_g, BIG))
    e_max = jnp.max(jnp.where(feasible, E_g, -BIG))
    L_n = (L_exp - l_min) / jnp.maximum(l_max - l_min, 1e-9)
    E_n = (E_g - e_min) / jnp.maximum(e_max - e_min, 1e-9)
    if health is not None:
        E_n = jnp.where(degraded, 0.0, E_n)
    J = gamma * L_n + (1.0 - gamma) * E_n
    return jnp.where(feasible, J, BIG), feasible


def mo_select(prof: ProfileTable, g, q, *, delta: float = 5.0,
              gamma: float = 0.5, health=None):
    """p* = argmin J over the accuracy-feasible set (one request)."""
    J, feasible = mo_scores(prof.T[:, g], prof.E[:, g], prof.mAP[:, g], q,
                            delta=delta, gamma=gamma, health=health)
    return jnp.argmin(J), J, feasible


# ------------------------------------------- hoisted (queue-independent) --
#
# Algorithm 1 splits cleanly into queue-independent and queue-dependent
# halves: the accuracy-feasibility mask (mAP, Δ), the feasible-set energy
# extrema e_min/e_max and the normalised energy term E_n depend only on
# the request's GROUP, never on the live queue — yet :func:`mo_scores`
# recomputes all of them for every request of a routing window. The
# hoisted form precomputes the whole (P, G) queue-independent half ONCE
# per table (:func:`mo_precompute`) and leaves only the expected-latency
# normalisation + argmin in the per-request step (:func:`mo_scores_hoisted`).
#
# Bit-identity: min/max reductions are exactly associative and the
# surviving per-step expression is written identically, so the hoisted
# scores — and therefore the routing decisions — are bit-identical to the
# unhoisted path (asserted across backends in tests/test_kernels.py and
# pinned against the golden_static_pr3 decisions).


def mo_precompute(T, E, mAP, *, delta: float, health=None):
    """The queue-independent half of Algorithm 1, for a whole (P, G) table.

    Returns ``(feasible, E_n)``, both (P, G): the accuracy-feasibility
    mask and the feasible-set-normalised energy term. Column g of each
    equals what :func:`mo_scores` computes per request for group ``g`` —
    bitwise (the reductions are min/max, which commute exactly).

    ``health`` (optional, (P,) bool) folds the fault plane's mask into
    the precomputed half with :func:`mo_scores`'s exact degraded-mode
    expressions (unmasked accuracy bar, candidate set ``feasible &
    health`` relaxed per-group to all-healthy + zeroed energy term when
    empty) — the mask is queue-independent, so it hoists with the rest."""
    map_max = jnp.max(mAP, axis=-2, keepdims=True)
    feasible = mAP >= map_max - delta
    if health is not None:
        h = jnp.asarray(health)[..., None]
        cand = feasible & h
        degraded = ~jnp.any(cand, axis=-2, keepdims=True)
        feasible = jnp.where(degraded, h, cand)
    e_min = jnp.min(jnp.where(feasible, E, BIG), axis=-2, keepdims=True)
    e_max = jnp.max(jnp.where(feasible, E, -BIG), axis=-2, keepdims=True)
    E_n = (E - e_min) / jnp.maximum(e_max - e_min, 1e-9)
    if health is not None:
        E_n = jnp.where(degraded, 0.0, E_n)
    return feasible, E_n


def mo_scores_hoisted(T_g, En_g, feas_g, q, *, gamma: float, penalty=None):
    """Per-request Algorithm 1 scores from precomputed group constants.

    ``T_g``/``En_g``/``feas_g``: (P,) group-g columns of the profile and
    of :func:`mo_precompute`'s outputs; ``q``: (P,) live queue depths.
    Only the expected-latency normalisation survives in the step — J is
    bit-identical to :func:`mo_scores` on the same inputs."""
    L_exp = T_g * (1.0 + q)
    if penalty is not None:
        L_exp = L_exp + penalty
    l_min = jnp.min(jnp.where(feas_g, L_exp, BIG))
    l_max = jnp.max(jnp.where(feas_g, L_exp, -BIG))
    L_n = (L_exp - l_min) / jnp.maximum(l_max - l_min, 1e-9)
    J = gamma * L_n + (1.0 - gamma) * En_g
    return jnp.where(feas_g, J, BIG)


def mo_select_batch_hoisted(prof: ProfileTable, gs, q0, *,
                            delta: float = 5.0, gamma: float = 0.5,
                            health=None):
    """:func:`mo_select_batch` with the queue-independent work hoisted out
    of the scan — the XLA form of the ``hoisted`` moscore backend. Same
    contract, bit-identical assignments and final queue. ``health`` is
    one (P,) mask for the whole window (the gateway routes each window
    at one health snapshot)."""
    feasible, E_n = mo_precompute(prof.T, prof.E, prof.mAP, delta=delta,
                                  health=health)
    # transpose once so the scan gathers contiguous (P,) group rows
    Tt, Ent, Ft = prof.T.T, E_n.T, feasible.T

    def step(q, g):
        J = mo_scores_hoisted(Tt[g], Ent[g], Ft[g], q, gamma=gamma)
        p = jnp.argmin(J)
        return q.at[p].add(1.0), p

    q, ps = jax.lax.scan(step, q0.astype(f32), gs)
    return ps, q


def mo_select_batch(prof: ProfileTable, gs, q0, *, delta: float = 5.0,
                    gamma: float = 0.5, health=None):
    """Sequential assignment of a routing window with queue feedback:
    each selection bumps q[p*] before the next request is scored (the
    semantics HAProxy dispatch gives the paper implicitly). gs: (W,) groups.
    Returns (assignments (W,), final q). Reference for kernels/moscore.
    ``health`` is one (P,) mask applied to the whole window."""

    def step(q, g):
        p, _, _ = mo_select(prof, g, q, delta=delta, gamma=gamma,
                            health=health)
        return q.at[p].add(1.0), p

    q, ps = jax.lax.scan(step, q0.astype(f32), gs)
    return ps, q


# ---------------------------------------------------------------- baselines

def policy_scores(code, prof: ProfileTable, g, q, rnd, rr_counter,
                  gamma, delta, penalty=None, health=None):
    """Scores (P,) for every policy; dispatch via lax.switch so one jitted
    simulator serves all seven policies. ``penalty`` (optional, (P,) ms)
    adds to the expected-latency term of the latency-aware policies (MO,
    LT) — the offload tier's uplink congestion feedback; the
    latency-blind baselines ignore it by construction. ``health``
    (optional, (P,) bool) masks down pairs for EVERY policy: MO applies
    it at the feasibility stage (:func:`mo_scores`, with the degraded
    fallback); the baselines get their scores forced to +inf on down
    pairs — RR skips them in rotation, RND draws uniformly over healthy
    pairs, LC/LE/LT/HA argmin over the healthy set."""
    P = prof.n_pairs

    def mo(_):
        J, _f = mo_scores(prof.T[:, g], prof.E[:, g], prof.mAP[:, g], q,
                          delta=delta, gamma=gamma, penalty=penalty,
                          health=health)
        return J

    def rr(_):
        return jnp.mod(jnp.arange(P) - rr_counter, P).astype(f32)

    def rnd_(_):
        return jax.random.uniform(rnd, (P,))

    def lc(_):
        return q.astype(f32)

    def le(_):
        return jnp.mean(prof.E, axis=1)          # fixed global-cheapest pair

    def lt(_):
        L = prof.T[:, g] * (1.0 + q)
        return L if penalty is None else L + penalty

    def ha(_):
        return -jnp.mean(prof.mAP, axis=1)       # fixed global-best-mAP pair

    scores = jax.lax.switch(code, [mo, rr, rnd_, lc, le, lt, ha], None)
    if health is not None:
        # idempotent for MO (its unhealthy scores are already BIG); this
        # is what masks the six baselines
        scores = jnp.where(health, scores, BIG)
    return scores


def select_pair(code, prof: ProfileTable, g, q, rnd, rr_counter, gamma,
                delta, penalty=None, health=None):
    """``(p*, scores)`` — the one selection rule every dispatch path (the
    simulator's scan, the gateway, ``repro.core.dispatch`` engines)
    shares: score with :func:`policy_scores`, pick the argmin.
    ``penalty`` and ``health`` flow through to :func:`policy_scores`."""
    scores = policy_scores(code, prof, g, q, rnd, rr_counter, gamma, delta,
                           penalty, health)
    return jnp.argmin(scores).astype(jnp.int32), scores
