"""Dispatch engines: where per-decision routing state lives.

The paper's §VII names online profile adaptation as the key open problem:
static offline tables drift when devices throttle, models are swapped or
inputs shift. This module turns the balancer's per-decision state — the
round-robin counter the baselines need, and the online-EWMA belief tables
the adaptive balancer needs — into one pluggable interface,
:class:`DispatchEngine`, mirroring the ``WorkloadSource`` pattern of
``repro.core.workload``:

  * :meth:`DispatchEngine.init` builds the engine's :data:`DispatchState`
    pytree once per config, outside the scan;
  * :meth:`DispatchEngine.select` scores the fleet for one request and
    returns the chosen pair plus the advanced state;
  * :meth:`DispatchEngine.observe` folds one measured (latency, energy)
    observation back into the state after the request completes.

The batched simulator (``repro.core.simulator``, the ``dispatch=``
argument throughout) threads the state through its ``lax.scan`` carry, and
the serving gateway (``repro.serving.gateway.Gateway``) drives the *same*
hooks per live request — simulation and serving run one stateful code
path.

Implementations are registered jax pytrees (hyper-parameters as static
aux data, no leaves), so they pass through ``jit`` / ``vmap`` /
``shard_map`` like a ``ProfileTable`` and a grid of online configs still
vmaps over the config axis, shards over a mesh and fuses over fleet
ensembles unchanged.

:class:`StaticDispatch` is the default — bit-identical to the engine
before the interface existed (pinned by ``tests/golden_static_pr3.json``).
:class:`OnlineDispatch` wraps the annealed-EWMA estimator of
``repro.core.online``. :class:`DriftSchedule` is the matching scenario
hook: a piecewise-constant perturbation of the *true* profile mid-run
(thermal throttling, a model swap), against which static dispatch routes
on stale numbers while online dispatch re-converges.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import online as ONL
from repro.core.policies import select_pair
from repro.core.profiles import ProfileTable

f32 = jnp.float32
i32 = jnp.int32

# A DispatchState is a flat dict pytree of per-config jax arrays — the
# scan-carried (gateway-held) mutable half of a dispatch engine. Every
# engine's state carries the round-robin counter "rr"; adaptive engines
# add their belief tables on top. Extra keys flow through
# ``repro.core.online`` untouched, so the EWMA helpers work on either.
DispatchState = dict


class DispatchEngine:
    """Interface between the routing loop and its per-decision state.

    Engines are stateless objects (hyper-parameters only); all mutable
    state lives in the :data:`DispatchState` pytree returned by
    :meth:`init` and threaded through :meth:`select` / :meth:`observe` by
    the caller (the simulator's scan carry, or the gateway between
    requests). Every hook is traced — safe inside ``jit`` / ``vmap`` /
    ``lax.scan`` — and subclasses must be registered pytrees so the
    engine itself can cross ``jit`` / ``shard_map`` boundaries.
    """

    #: False when :meth:`observe` is a no-op — lets hot serving paths
    #: skip the observation plumbing entirely (the traced simulator
    #: needs no flag: XLA dead-code-eliminates a no-op observe).
    adaptive: bool = True

    def init(self, prof: ProfileTable) -> DispatchState:
        """Fresh per-config state for a fleet of ``prof``'s shape."""
        raise NotImplementedError

    def tables(self, state: DispatchState, prof: ProfileTable):
        """The belief :class:`ProfileTable` decisions are scored against
        (the offline table itself, or an adapted copy)."""
        raise NotImplementedError

    def select(self, state, prof, code, g_est, q, key, gamma, delta,
               penalty=None, tables=None, health=None):
        """Score one request -> ``(pair, new_state)``. ``code`` is the
        policy index (``POLICY_CODES``), ``g_est`` the estimated group,
        ``q`` the (P,) live queue depths, ``key`` a fresh threefry key
        (consumed only by the RND baseline). ``penalty`` (optional, (P,)
        ms) is the cloud tier's uplink congestion term, added to the
        latency-aware policies' expected latency
        (``repro.core.policies.policy_scores``); ``None`` keeps the
        traced graph exactly as before. ``health`` (optional, (P,) bool)
        is the fault plane's per-step mask — down pairs leave the
        candidate set at the feasibility stage, with MO's degraded
        fallback (``repro.core.policies.mo_scores``). ``tables``
        (optional) is a pre-materialised belief :class:`ProfileTable`
        for ``state`` — :meth:`select_window` hoists the :meth:`tables`
        call out of its scan and passes it here; ``None`` (every
        per-request caller) materialises it on the spot."""
        tbl = self.tables(state, prof) if tables is None else tables
        p, _scores = select_pair(code, tbl, g_est, q, key,
                                 state["rr"] % prof.n_pairs, gamma,
                                 delta, penalty, health)
        return p, {**state, "rr": state["rr"] + 1}

    def select_window(self, state, prof, code, gs, q0, keys, gamma,
                      delta, penalty_fn=None, healths=None):
        """Route a whole admission window with queue feedback — the
        batched :meth:`select`. ``gs``/``keys`` are (W,) groups and
        per-request threefry keys, ``q0`` the (P,) queue depths at
        admission. A ``lax.scan`` threads ``(state, q)`` through the W
        selections (decision w+1 sees decision w's queue bump), so the
        result is bit-identical to W sequential :meth:`select` calls;
        returns ``(pairs (W,), q_after (P,), new_state)``. The serving
        gateway jits this once per window shape — one device program per
        admission window instead of W dispatches.

        The belief tables are materialised ONCE, outside the scan:
        :meth:`select` never touches the belief half of the state (only
        ``rr`` advances; observations arrive separately via
        :meth:`observe_window`), so :meth:`tables` is loop-invariant
        across the window — hoisting it saves the per-request table
        blend (for :class:`OnlineDispatch` in window mode, a whole
        (P, G) prior-blend per request), bit-identically.

        ``penalty_fn`` (optional) maps ``(g, q) -> (P,)`` per-decision
        latency penalties — the cloud tier's congestion feedback,
        re-evaluated against each decision's live ``q`` inside the scan
        (:meth:`repro.core.cloud.CloudMeta.penalty`).

        ``healths`` (optional, (W, P) bool) gives each request its own
        fault-plane health mask (row w masks decision w) — per-request
        rather than per-window so the realization keys on ABSOLUTE step
        indices and window partitioning cannot change it; ``None`` keeps
        the scan's xs exactly as before."""
        tbl = self.tables(state, prof)

        def step(carry, inp):
            st, q = carry
            g, key = inp[:2]
            h = inp[2] if healths is not None else None
            pen = None if penalty_fn is None else penalty_fn(g, q)
            p, st = self.select(st, prof, code, g, q, key, gamma, delta,
                                penalty=pen, tables=tbl, health=h)
            return (st, q.at[p].add(1.0)), p

        xs = (gs, keys) if healths is None else (gs, keys, healths)
        (state, q), pairs = jax.lax.scan(
            step, (state, q0.astype(f32)), xs)
        return pairs, q, state

    def observe(self, state, p, g, obs_t_ms, obs_e_mwh=None):
        """Fold one completed request's measurements — latency (ms) and
        optionally energy (mWh) at cell ``(p, g)`` — into the state."""
        raise NotImplementedError

    def observe_window(self, state, pairs, groups, obs_t_ms,
                       obs_e_mwh=None):
        """Fold a whole routing window of observations ((W,) arrays, in
        completion order) — the batched :meth:`observe`, used by the
        gateway's windowed path. The default loops :meth:`observe`;
        engines with a fused fold override it."""
        for w in range(len(pairs)):
            state = self.observe(state, pairs[w], groups[w], obs_t_ms[w],
                                 None if obs_e_mwh is None
                                 else obs_e_mwh[w])
        return state


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class StaticDispatch(DispatchEngine):
    """The default: decisions use the offline profile unchanged and
    observations are discarded; state is just the round-robin counter.
    Bit-identical to the engine before the interface existed
    (``tests/golden_static_pr3.json`` pins it, single-device and on a
    forced 4-device mesh)."""

    adaptive = False

    def tree_flatten(self):
        return (), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls()

    def init(self, prof):
        return {"rr": jnp.zeros((), i32)}

    def tables(self, state, prof):
        return prof

    def observe(self, state, p, g, obs_t_ms, obs_e_mwh=None):
        return state

    def observe_window(self, state, pairs, groups, obs_t_ms,
                       obs_e_mwh=None):
        return state


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class OnlineDispatch(DispatchEngine):
    """Online-adaptive dispatch: decisions are scored against the
    annealed-EWMA belief tables of ``repro.core.online`` and every
    completed request's measured latency/energy is folded back in. Cold
    cells trust the offline prior, hot cells converge to observations
    (step size ramps from ~0 to ``alpha`` over ``prior_weight``
    pseudo-counts). mAP stays offline-profiled — accuracy is not
    observable online without labels.

    With ``window=W`` the estimator switches from the annealed EWMA to a
    sliding-window mean over the last W observations per cell
    (``repro.core.online.observe_windowed`` / ``window_tables``): stale
    evidence is *discarded* rather than annealed away, so after a large
    drift the belief is fully post-drift within W observations — the
    forgetting variant the annealed engine lacks (``alpha`` is unused in
    this mode). Both modes are scan-safe and vmap/shard/fleet-stack
    unchanged."""

    alpha: float = 0.1
    prior_weight: float = 10.0
    window: int | None = None

    def tree_flatten(self):
        return (), (self.alpha, self.prior_weight, self.window)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*aux)

    def init(self, prof):
        state = ONL.init_state(prof) if self.window is None \
            else ONL.init_window_state(prof, self.window)
        state["rr"] = jnp.zeros((), i32)
        return state

    def tables(self, state, prof):
        if self.window is None:
            return ONL.as_profile(state, prof)
        return ONL.window_tables(state, prof, window=self.window,
                                 prior_weight=self.prior_weight)

    def observe(self, state, p, g, obs_t_ms, obs_e_mwh=None):
        if self.window is None:
            return ONL.observe(state, p, g, obs_t_ms, obs_e_mwh,
                               alpha=self.alpha,
                               prior_weight=self.prior_weight)
        return ONL.observe_windowed(state, p, g, obs_t_ms, obs_e_mwh,
                                    window=self.window)

    def observe_window(self, state, pairs, groups, obs_t_ms,
                       obs_e_mwh=None):
        if self.window is None:
            return ONL.observe_window(state, pairs, groups, obs_t_ms,
                                      obs_e_mwh, alpha=self.alpha,
                                      prior_weight=self.prior_weight)
        # ring-buffer updates are order-dependent within a cell, so the
        # windowed mode folds the batch with a sequential lax.scan — one
        # fused program, bit-identical to per-request observes
        return ONL.observe_windowed_batch(state, pairs, groups, obs_t_ms,
                                          obs_e_mwh, window=self.window)


_DEFAULT_DISPATCH = StaticDispatch()


def default_dispatch() -> StaticDispatch:
    """The engine's default dispatch state handler (static tables)."""
    return _DEFAULT_DISPATCH


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class DriftSchedule:
    """Piecewise-constant perturbation of the TRUE profile mid-run.

    The scenario hook for non-stationary hardware: at dispatch step
    ``start_step[k]`` the fleet's true service times and energies become
    ``prof.T * t_scale[k]`` / ``prof.E * e_scale[k]`` (thermal
    throttling, a model swap, a migrated container). Policies never see
    the schedule — :class:`StaticDispatch` keeps routing on the stale
    offline table, :class:`OnlineDispatch` re-converges from
    observations. mAP is not drifted (the belief tables keep it offline
    for the same reason). Composition with the fault plane's throttling
    bursts (``repro.core.faults``) is DEFINED: drift scales apply first,
    fault throttles multiply on top — ``truth = (prof x drift) x fault``
    — in the simulator and in ``AsyncExecutorPool``'s factored
    multipliers alike (tested in ``tests/test_faults.py``).

    Leaves: ``start_step`` (K,) int32 ascending with ``start_step[0] ==
    0`` (the baseline segment), ``t_scale``/``e_scale`` (K, P, G) float32
    multipliers. A registered pytree, replicated across the config axis
    like the profile table, so drifted grids vmap / shard / fleet-stack
    unchanged.
    """

    start_step: jax.Array
    t_scale: jax.Array
    e_scale: jax.Array

    def __post_init__(self):
        if not isinstance(self.start_step, jax.core.Tracer):
            steps = np.asarray(self.start_step)
            if steps.ndim != 1 or steps.size == 0 or steps[0] != 0:
                raise ValueError("DriftSchedule: start_step must be a 1-D "
                                 "array beginning at 0 (the baseline "
                                 "segment)")
            if (np.diff(steps) <= 0).any():
                raise ValueError("DriftSchedule: start_step must be "
                                 "strictly ascending")
        object.__setattr__(self, "start_step",
                           jnp.asarray(self.start_step, i32))
        object.__setattr__(self, "t_scale", jnp.asarray(self.t_scale, f32))
        object.__setattr__(self, "e_scale", jnp.asarray(self.e_scale, f32))

    def tree_flatten(self):
        return (self.start_step, self.t_scale, self.e_scale), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        obj = cls.__new__(cls)
        for name, leaf in zip(("start_step", "t_scale", "e_scale"), leaves):
            object.__setattr__(obj, name, leaf)
        return obj

    @property
    def n_segments(self) -> int:
        return int(self.start_step.shape[0])

    def at_step(self, prof: ProfileTable, step) -> ProfileTable:
        """The true profile at dispatch step ``step`` (traced; used inside
        the simulator's scan). Broadcasts over a stacked (F, P, G) table."""
        seg = jnp.sum(jnp.asarray(step, i32) >= self.start_step) - 1
        return ProfileTable(prof.T * self.t_scale[seg],
                            prof.E * self.e_scale[seg],
                            prof.mAP, prof.names, prof.floor_mw)

    @classmethod
    def throttle(cls, prof: ProfileTable, pair: int, *, at_step: int,
                 t_mult: float = 3.0, e_mult: float = 1.5,
                 recover_step: int | None = None) -> "DriftSchedule":
        """The canonical thermal-throttling event: from dispatch step
        ``at_step`` on, pair ``pair``'s true service time is ``t_mult``×
        and its energy ``e_mult``× the profiled value (optionally
        recovering at ``recover_step``)."""
        P, G = prof.n_pairs, prof.n_groups
        ident = np.ones((P, G), np.float32)
        t_seg, e_seg = ident.copy(), ident.copy()
        t_seg[pair] *= t_mult
        e_seg[pair] *= e_mult
        steps = [0, at_step]
        t_scales = [ident, t_seg]
        e_scales = [ident, e_seg]
        if recover_step is not None:
            steps.append(recover_step)
            t_scales.append(ident)
            e_scales.append(ident)
        return cls(np.asarray(steps, np.int32), np.stack(t_scales),
                   np.stack(e_scales))
