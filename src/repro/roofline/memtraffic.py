"""Analytic HBM model (traffic + capacity) per cell.

Why analytic: the dry-run compiles for the CPU backend, whose
``bytes accessed`` reflects *unfused* execution (every elementwise op
round-trips full buffers) and whose buffer assignment upcasts bf16 — both
wildly pessimistic versus TPU's fused pipelines. The memory roofline term
therefore comes from this standard fusion-aware model (the same accounting
MFU calculators use); the XLA numbers are still recorded as an upper bound.

All byte counts are GLOBAL; divide by chips for the per-device term.
Formulas are deliberately simple and disclosed in EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

from repro.common.configs import (DiTConfig, LMConfig, MMDiTConfig, ShapeSpec,
                                  TrainingConfig, VisionConfig)

BF16 = 2
F32 = 4

# params_io bytes/param for a full train step (read fwd + read bwd + grad
# write/read + optimizer state read/write + param write)
_OPT_IO = {"adamw": 26, "adafactor": 12, "sgdm": 18}
# optimizer state bytes/param (capacity)
_OPT_CAP = {"adamw": 8, "adafactor": 0.1, "sgdm": 4}


def _attn_scores_io(batch, heads, sq, skv, causal: bool, train: bool,
                    flash: bool = False) -> float:
    """HBM bytes for exact-attention score/softmax buffers. ~12 B/element
    (f32 scores write+read, bf16 probs write+read) per pass; x3 with
    backward. A flash/fused kernel keeps them in VMEM -> 0."""
    if flash:
        return 0.0
    elems = batch * heads * float(sq) * float(skv) * (0.5 if causal else 1.0)
    return elems * 12.0 * (3.0 if train else 1.0)


def _lm_act_bytes_per_token_layer(cfg: LMConfig) -> float:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    if cfg.moe:
        f_eff = cfg.top_k * cfg.d_exp * cfg.capacity_factor \
            + cfg.n_shared_experts * cfg.d_exp \
            + (cfg.d_ff if cfg.moe_dense_residual else 0)
    else:
        f_eff = cfg.d_ff
    per_tok = (H * hd) + 2 * (KV * hd) + (H * hd) + 3 * D + 2 * f_eff + D
    return BF16 * per_tok


def lm_traffic(cfg: LMConfig, shape: ShapeSpec, tcfg: TrainingConfig,
               flash: bool = False) -> dict:
    B, S = shape.global_batch, shape.seq_len
    p = cfg.n_params()
    L, H = cfg.n_layers, cfg.n_heads
    act = _lm_act_bytes_per_token_layer(cfg)
    kv_elem = (1 + F32 / cfg.hd) if cfg.kv_cache_dtype == "int8" else BF16
    cache_bytes = 2 * L * B * S * cfg.n_kv_heads * cfg.hd * kv_elem

    if shape.kind == "train":
        tokens = B * S
        out = {
            "params_io": p * _OPT_IO[tcfg.optimizer],
            "act_io": 3.0 * tokens * L * act,
            "scores_io": L * _attn_scores_io(B, H, S, S, True, True, flash),
            "xent_io": tokens * cfg.vocab_size * 12.0,
        }
    elif shape.kind == "prefill":
        tokens = B * S
        out = {
            "params_io": p * BF16,
            "act_io": 1.0 * tokens * L * act,
            "scores_io": L * _attn_scores_io(B, H, S, S, True, False, flash),
            "cache_io": cache_bytes,
        }
    else:  # decode: read weights once + stream the cache
        if cfg.moe:
            # only experts hit by the B*top_k routed tokens are read
            hit = min(B * cfg.top_k, cfg.n_experts) / cfg.n_experts
            expert_p = cfg.n_layers * cfg.n_experts * 3 * cfg.d_model \
                * cfg.d_exp
            p_read = (p - expert_p) + hit * expert_p
        else:
            p_read = p
        out = {
            "params_io": p_read * BF16,
            "cache_io": cache_bytes,
            "act_io": 3 * B * L * act,
        }
    out["total"] = sum(out.values())
    return out


def lm_capacity(cfg: LMConfig, shape: ShapeSpec, tcfg: TrainingConfig,
                chips: int, param_shards: int) -> dict:
    B, S = shape.global_batch, shape.seq_len
    p = cfg.n_params()
    L, D = cfg.n_layers, cfg.d_model
    out = {"params": p * BF16 / param_shards}
    if shape.kind == "train":
        out["opt"] = p * _OPT_CAP[tcfg.optimizer] / param_shards
        out["grads"] = p * (F32 if tcfg.microbatch else BF16) / param_shards
        tokens_local = B * S / min(chips, B * S)
        saved_mult = 1.0 if tcfg.remat == "full" else 3.0
        out["activations"] = saved_mult * L * tokens_local * D * BF16
        out["transient"] = tokens_local * min(cfg.vocab_size, 8192) * F32
    else:
        kv_elem = (1 + F32 / cfg.hd) if cfg.kv_cache_dtype == "int8" else BF16
        cache = 2 * L * B * S * cfg.n_kv_heads * cfg.hd * kv_elem
        out["kv_cache"] = cache / chips
        out["transient"] = B * S * D * BF16 / min(chips, max(B, 1) * 16)
    out["total"] = sum(out.values())
    return out


def _dit_tokens_and_width(cfg, shape):
    if isinstance(cfg, MMDiTConfig):
        return cfg.n_img_tokens(shape.img_res) + cfg.txt_len, cfg.d_model, \
            cfg.n_double_blocks + cfg.n_single_blocks, cfg.n_heads
    return cfg.n_tokens(shape.img_res), cfg.d_model, cfg.n_layers, \
        cfg.n_heads


def dit_traffic(cfg, shape: ShapeSpec, tcfg: TrainingConfig,
                flash: bool = False) -> dict:
    n_tok, D, L, H = _dit_tokens_and_width(cfg, shape)
    B = shape.global_batch
    p = cfg.n_params()
    train = shape.kind == "train"
    act = BF16 * 12 * D
    out = {
        "params_io": p * (_OPT_IO[tcfg.optimizer] if train else BF16),
        "act_io": (3.0 if train else 1.0) * B * n_tok * L * act,
        "scores_io": L * _attn_scores_io(B, H, n_tok, n_tok, False, train,
                                         flash),
    }
    out["total"] = sum(out.values())
    return out


def dit_capacity(cfg, shape: ShapeSpec, tcfg: TrainingConfig, chips: int,
                 param_shards: int) -> dict:
    n_tok, D, L, H = _dit_tokens_and_width(cfg, shape)
    B = shape.global_batch
    p = cfg.n_params()
    train = shape.kind == "train"
    out = {"params": p * BF16 / param_shards}
    if train:
        out["opt"] = p * _OPT_CAP[tcfg.optimizer] / param_shards
        out["grads"] = p * BF16 / param_shards
        tokens_local = B * n_tok / min(chips, B * 16)
        out["activations"] = 3.0 * L * tokens_local * D * BF16
    bl = max(B // min(B, max(chips // 16, 1)), 1)
    out["transient"] = bl * (n_tok ** 2) * F32 / 16  # per-dev score chunk
    out["total"] = sum(out.values())
    return out


def vision_feature_bytes(cfg: VisionConfig, img_res: int) -> float:
    """Sum of feature-map bytes for one forward pass (per image)."""
    import math
    from repro.models.convnets import plan

    cur = img_res
    total = 0.0
    for b in plan(cfg):
        t = b["t"]
        if t == "conv_bn":
            cur = math.ceil(cur / b["s"])
            total += cur * cur * b["cout"]
        elif t == "maxpool":
            cur = math.ceil(cur / b["s"])
        elif t == "resnet_block":
            mid_res = cur
            cur = math.ceil(cur / b["s"])
            total += mid_res * mid_res * b["mid"] + cur * cur * (b["mid"] + b["cout"])
        elif t == "convnext_stem":
            cur = cur // 4
            total += cur * cur * b["cout"]
        elif t == "convnext_down":
            cur = cur // 2
            total += cur * cur * b["cout"]
        elif t == "convnext_block":
            total += cur * cur * b["dim"] * 6
        elif t == "mbconv":
            mid = b["cin"] * b["e"]
            total += cur * cur * mid
            cur = math.ceil(cur / b["s"])
            total += cur * cur * (mid + b["cout"])
    return total * BF16


def vision_traffic(cfg: VisionConfig, shape: ShapeSpec,
                   tcfg: TrainingConfig) -> dict:
    p = cfg.n_params()
    train = shape.kind == "train"
    feats = vision_feature_bytes(cfg, shape.img_res) * shape.global_batch
    out = {
        "params_io": p * (_OPT_IO[tcfg.optimizer] if train else BF16),
        "act_io": (3.0 if train else 1.0) * feats * 2,   # write + read
    }
    out["total"] = sum(out.values())
    return out


def vision_capacity(cfg: VisionConfig, shape: ShapeSpec,
                    tcfg: TrainingConfig, chips: int,
                    param_shards: int) -> dict:
    p = cfg.n_params()
    train = shape.kind == "train"
    out = {"params": p * BF16 / param_shards}
    feats = vision_feature_bytes(cfg, shape.img_res)
    local_imgs = max(shape.global_batch / chips, 1.0 / 16)
    if train:
        out["opt"] = p * _OPT_CAP[tcfg.optimizer] / param_shards
        out["grads"] = p * F32 / param_shards
        out["activations"] = feats * local_imgs
    else:
        out["activations"] = feats * local_imgs * 0.25   # live window
    out["total"] = sum(out.values())
    return out


def cell_memory(cfg, shape: ShapeSpec, tcfg: TrainingConfig, chips: int,
                param_shards: int, flash: bool = False) -> dict:
    if isinstance(cfg, LMConfig):
        t = lm_traffic(cfg, shape, tcfg, flash)
        c = lm_capacity(cfg, shape, tcfg, chips, param_shards)
    elif isinstance(cfg, (DiTConfig, MMDiTConfig)):
        t = dit_traffic(cfg, shape, tcfg, flash)
        c = dit_capacity(cfg, shape, tcfg, chips, param_shards)
    elif isinstance(cfg, VisionConfig):
        t = vision_traffic(cfg, shape, tcfg)
        c = vision_capacity(cfg, shape, tcfg, chips, param_shards)
    else:
        raise TypeError(type(cfg))
    return {"traffic": t, "capacity": c}
