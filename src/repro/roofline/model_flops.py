"""Analytic MODEL_FLOPS per cell (the "useful work" numerator).

LM: 6·N_active·tokens for train, 2·N_active·tokens for inference matmuls,
plus exact attention-score/value FLOPs (which 6ND omits). Diffusion/vision:
2·MACs per forward (x3 for training). The roofline report uses
MODEL_FLOPS / HLO_FLOPs to expose remat and dispatch waste."""

from __future__ import annotations

import math

from repro.common.configs import (DiTConfig, LMConfig, MMDiTConfig, ShapeSpec,
                                  VisionConfig)


def _lm_attention_flops(cfg: LMConfig, batch: int, sq: int, skv: int) -> float:
    # QK^T + PV: 2 matmuls, 2*sq*skv*hd MACs each per head -> FLOPs = 4*...
    return 4.0 * batch * cfg.n_heads * cfg.hd * float(sq) * float(skv)


def lm_flops(cfg: LMConfig, shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    n_act = cfg.n_active_params()
    if shape.kind == "train":
        tokens = B * S
        dense = 6.0 * n_act * tokens
        attn = 3.0 * cfg.n_layers * _lm_attention_flops(cfg, B, S, S) / 2.0
        # causal: half the S^2 work; x3 for fwd+bwd
        return {"model_flops": dense + attn, "flops_6nd": dense}
    if shape.kind == "prefill":
        tokens = B * S
        dense = 2.0 * n_act * tokens
        attn = cfg.n_layers * _lm_attention_flops(cfg, B, S, S) / 2.0
        return {"model_flops": dense + attn, "flops_6nd": dense}
    # decode: one token per sequence against an S-token cache
    dense = 2.0 * n_act * B
    attn = cfg.n_layers * _lm_attention_flops(cfg, B, 1, S)
    return {"model_flops": dense + attn, "flops_6nd": dense}


def dit_flops(cfg, shape: ShapeSpec) -> dict:
    if isinstance(cfg, MMDiTConfig):
        n_tok = cfg.n_img_tokens(shape.img_res) + cfg.txt_len
        d = cfg.d_model
        # double blocks: two streams share joint attention
        per_tok_params = (cfg.n_double_blocks * 2 + cfg.n_single_blocks) \
            * 12 * d * d
        attn_layers = cfg.n_double_blocks + cfg.n_single_blocks
    else:
        n_tok = cfg.n_tokens(shape.img_res)
        d = cfg.d_model
        per_tok_params = cfg.n_layers * 18 * d * d
        attn_layers = cfg.n_layers
    B = shape.global_batch
    dense = 2.0 * per_tok_params * n_tok * B
    attn = attn_layers * 4.0 * B * n_tok * n_tok * d
    fwd = dense + attn
    mult = 3.0 if shape.kind == "train" else 1.0
    return {"model_flops": mult * fwd, "flops_6nd": mult * dense,
            "steps": shape.steps}


def vision_flops(cfg: VisionConfig, shape: ShapeSpec) -> dict:
    from repro.models.convnets import plan

    res = shape.img_res
    macs = 0.0
    cur = res

    def conv_macs(h, k, cin, cout, stride, groups=1):
        oh = math.ceil(h / stride)
        return oh * oh * k * k * cin * cout / groups, oh

    for b in plan(cfg):
        t = b["t"]
        if t == "conv_bn":
            m, cur = conv_macs(cur, b["k"], b["cin"], b["cout"], b["s"])
            macs += m
        elif t == "maxpool":
            cur = math.ceil(cur / b["s"])
        elif t == "resnet_block":
            m1, _ = conv_macs(cur, 1, b["cin"], b["mid"], 1)
            m2, nxt = conv_macs(cur, 3, b["mid"], b["mid"], b["s"])
            m3, _ = conv_macs(nxt, 1, b["mid"], b["cout"], 1)
            macs += m1 + m2 + m3
            if b["cin"] != b["cout"] or b["s"] > 1:
                mp, _ = conv_macs(cur, 1, b["cin"], b["cout"], b["s"])
                macs += mp
            cur = nxt
        elif t == "convnext_stem":
            m, cur = conv_macs(cur, 4, 3, b["cout"], 4)
            macs += m
        elif t == "convnext_down":
            m, cur = conv_macs(cur, 2, b["cin"], b["cout"], 2)
            macs += m
        elif t == "convnext_block":
            d = b["dim"]
            mdw, _ = conv_macs(cur, 7, d, d, 1, groups=d)
            macs += mdw + cur * cur * d * 4 * d * 2
        elif t == "mbconv":
            cin, cout, e, k = b["cin"], b["cout"], b["e"], b["k"]
            mid = cin * e
            if e != 1:
                m, _ = conv_macs(cur, 1, cin, mid, 1)
                macs += m
            mdw, nxt = conv_macs(cur, k, mid, mid, b["s"], groups=mid)
            macs += mdw
            se = max(1, cin // 4)
            macs += mid * se * 2
            mp, _ = conv_macs(nxt, 1, mid, cout, 1)
            macs += mp
            cur = nxt
        elif t == "head":
            macs += b["cin"] * b["classes"]
    fwd = 2.0 * macs * shape.global_batch
    mult = 3.0 if shape.kind == "train" else 1.0
    return {"model_flops": mult * fwd, "flops_6nd": mult * fwd}


def cell_model_flops(cfg, shape: ShapeSpec) -> dict:
    if isinstance(cfg, LMConfig):
        return lm_flops(cfg, shape)
    if isinstance(cfg, (DiTConfig, MMDiTConfig)):
        return dit_flops(cfg, shape)
    if isinstance(cfg, VisionConfig):
        return vision_flops(cfg, shape)
    raise TypeError(type(cfg))
