from repro.roofline.hw import V5E
