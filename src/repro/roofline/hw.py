"""Hardware constants for the roofline model (TPU v5e, public numbers)."""

from dataclasses import dataclass


@dataclass(frozen=True)
class Chip:
    name: str
    peak_bf16_flops: float        # FLOP/s
    hbm_bw: float                 # B/s
    ici_link_bw: float            # B/s per link (one direction)
    ici_links: int                # links per chip (2D torus: 4)
    hbm_bytes: int
    vmem_bytes: int
    idle_w: float
    peak_w: float


V5E = Chip(
    name="tpu-v5e",
    peak_bf16_flops=197e12,
    hbm_bw=819e9,
    ici_link_bw=50e9,
    ici_links=4,
    hbm_bytes=16 * (1 << 30),
    vmem_bytes=128 * (1 << 20),
    idle_w=70.0,
    peak_w=170.0,
)
