"""Roofline-term extraction from compiled dry-run artifacts.

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = per-device link bytes / link_bw

``cost_analysis()`` is per-device (the SPMD-partitioned module), matching
the assignment's global/chips formulation. Collective bytes are NOT in
cost_analysis, so we parse the optimised HLO and apply standard ring-cost
factors per collective kind using each op's replica-group size.
"""

from __future__ import annotations

import re
from dataclasses import dataclass


from repro.roofline.hw import V5E, Chip

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SOURCE_TARGET_RE = re.compile(r"source_target_pairs=\{")


def shape_bytes(s: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(s):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _ring_factor(kind: str, n: int) -> float:
    """Per-device link bytes as a multiple of the op's (per-device) output/
    input bytes, ring algorithm."""
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n
    if kind == "all-gather":
        return (n - 1) / n          # x output bytes (already gathered size)
    if kind == "reduce-scatter":
        return float(n - 1)         # x output bytes (the shard)
    if kind == "all-to-all":
        return (n - 1) / n
    if kind == "collective-permute":
        return 1.0
    return 1.0


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device link bytes over every collective in the optimised HLO.
    Returns {kind: bytes} plus 'total'."""
    out: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_s, kind = m.group(1), m.group(2)
        nbytes = shape_bytes(shape_s)
        # find replica_groups on the same statement (up to end of line)
        line_end = hlo_text.find("\n", m.end())
        stmt = hlo_text[m.end(): line_end if line_end > 0 else None]
        g = _GROUPS_RE.search(stmt)
        gi = _GROUPS_IOTA_RE.search(stmt)
        if g:
            n = len([x for x in g.group(1).split(",") if x.strip() != ""])
        elif gi:  # iota format: [n_groups, group_size]<=[total]
            n = int(gi.group(2))
        elif kind == "collective-permute":
            n = 2
        else:
            n = 1
        out[kind] = out.get(kind, 0.0) + nbytes * _ring_factor(kind, n)
        out[f"{kind}_count"] = out.get(f"{kind}_count", 0.0) + 1
    out["total"] = sum(v for k, v in out.items() if not k.endswith("_count"))
    return out


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    chips: int
    chip: Chip = V5E

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / self.chip.peak_bf16_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / self.chip.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / self.chip.ici_link_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Perfect-overlap bound: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "step_time_s": self.step_time,
        }


def analyze_compiled(compiled, chips: int) -> dict:
    ca = compiled.cost_analysis()
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    rl = Roofline(flops, byts, coll["total"], chips)
    ma = compiled.memory_analysis()
    out = rl.as_dict()
    out["collectives"] = {k: v for k, v in coll.items()}
    out["memory"] = {
        "argument_bytes": ma.argument_size_in_bytes,
        "output_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "alias_bytes": ma.alias_size_in_bytes,
        "code_bytes": ma.generated_code_size_in_bytes,
    }
    live = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
            + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    out["memory"]["live_bytes"] = live
    out["memory"]["fits_hbm"] = bool(live <= V5E.hbm_bytes)
    out["memory"]["hbm_frac"] = live / V5E.hbm_bytes
    return out
