"""stablelm-12b [hf:stabilityai/stablelm-2-1_6b; hf]: 40L d_model=5120 32H
(GQA kv=8) d_ff=13824 vocab=100352, dense, LayerNorm."""

from repro.common.configs import LMConfig, TrainingConfig
from repro.configs.base import Arch

CONFIG = LMConfig(
    name="stablelm-12b",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=13_824, vocab_size=100_352, norm="layernorm",
)

REDUCED = LMConfig(
    name="stablelm-12b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=160, vocab_size=512, norm="layernorm", dtype="float32",
)

ARCH = Arch(
    id="stablelm-12b", family="lm", config=CONFIG,
    train=TrainingConfig(optimizer="adamw", lr=3e-4, remat="dots"),
    reduced=REDUCED, source="hf:stabilityai/stablelm-2-1_6b; hf",
)
