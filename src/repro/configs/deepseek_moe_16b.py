"""deepseek-moe-16b [arXiv:2401.06066; hf]: 28L d_model=2048 16H (GQA kv=16)
d_ff=1408 vocab=102400, MoE 64e top-6 — 2 shared + 64 routed, fine-grained."""

from repro.common.configs import LMConfig, TrainingConfig
from repro.configs.base import Arch

CONFIG = LMConfig(
    name="deepseek-moe-16b",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=102_400,
    moe=True, n_experts=64, top_k=6, n_shared_experts=2, d_expert=1408,
)

REDUCED = LMConfig(
    name="deepseek-moe-16b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab_size=512,
    moe=True, n_experts=8, top_k=2, n_shared_experts=2, d_expert=96,
    dtype="float32",
)

ARCH = Arch(
    id="deepseek-moe-16b", family="lm", config=CONFIG,
    train=TrainingConfig(optimizer="adamw", lr=4.2e-4, remat="dots"),
    reduced=REDUCED, source="arXiv:2401.06066; hf",
    notes="fine-grained MoE: 2 shared + 64 routed top-6",
)
