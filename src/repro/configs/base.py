"""Arch descriptor + the per-family shape sets from the assignment."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from repro.common.configs import ShapeSpec, TrainingConfig


# --- assigned shape cells (verbatim from the assignment) -------------------

LM_SHAPES = (
    ShapeSpec("train_4k", "train", global_batch=256, seq_len=4096),
    ShapeSpec("prefill_32k", "prefill", global_batch=32, seq_len=32_768),
    ShapeSpec("decode_32k", "decode", global_batch=128, seq_len=32_768),
    ShapeSpec("long_500k", "decode", global_batch=1, seq_len=524_288),
)

DIFFUSION_SHAPES = (
    ShapeSpec("train_256", "train", global_batch=256, img_res=256, steps=1000),
    ShapeSpec("gen_1024", "serve", global_batch=4, img_res=1024, steps=50),
    ShapeSpec("gen_fast", "serve", global_batch=16, img_res=512, steps=4),
    ShapeSpec("train_1024", "train", global_batch=32, img_res=1024, steps=1000),
)

VISION_SHAPES = (
    ShapeSpec("cls_224", "train", global_batch=256, img_res=224),
    ShapeSpec("cls_384", "train", global_batch=64, img_res=384),
    ShapeSpec("serve_b1", "serve", global_batch=1, img_res=224),
    ShapeSpec("serve_b128", "serve", global_batch=128, img_res=224),
)

FAMILY_SHAPES = {
    "lm": LM_SHAPES,
    "diffusion": DIFFUSION_SHAPES,
    "vision": VISION_SHAPES,
}


@dataclass(frozen=True)
class Arch:
    id: str
    family: str                       # lm | diffusion | vision
    config: Any                       # LMConfig | DiTConfig | MMDiTConfig
                                      # | VisionConfig
    train: TrainingConfig
    reduced: Any                      # smoke-test-sized config, same family
    source: str = ""                  # citation tag from the assignment
    notes: str = ""

    @property
    def shapes(self) -> tuple[ShapeSpec, ...]:
        return FAMILY_SHAPES[self.family]

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.id}: unknown shape {name!r}")
