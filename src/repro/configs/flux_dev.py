"""flux-dev [BFL tech report; unverified]: MMDiT rectified-flow,
img_res=1024 latent_res=128, 19 double + 38 single blocks, d_model=3072,
24 heads, ~12B params."""

from repro.common.configs import MMDiTConfig, TrainingConfig
from repro.configs.base import Arch

CONFIG = MMDiTConfig(
    name="flux-dev",
    img_res=1024, n_double_blocks=19, n_single_blocks=38,
    d_model=3072, n_heads=24, patch=2, in_channels=16,
    d_txt=4096, d_pooled=768, txt_len=512,
)

REDUCED = MMDiTConfig(
    name="flux-dev-smoke",
    img_res=64, n_double_blocks=2, n_single_blocks=2,
    d_model=64, n_heads=4, patch=2, in_channels=4,
    d_txt=32, d_pooled=16, txt_len=8, dtype="float32",
)

ARCH = Arch(
    id="flux-dev", family="diffusion", config=CONFIG,
    train=TrainingConfig(optimizer="adamw", lr=1e-4, remat="dots"),
    reduced=REDUCED, source="BFL tech report; unverified",
    notes="text/VAE frontends stubbed: input_specs provides latents + "
          "T5/CLIP features (assignment rule for modality frontends)",
)
