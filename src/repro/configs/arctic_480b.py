"""arctic-480b [hf:Snowflake/snowflake-arctic-base; hf]: 35L d_model=7168
56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2 + dense residual.

Training uses Adafactor: Adam states for 480B params (~6.7 TB) exceed one
v5e pod's 4 TB HBM; factored second moments fit (DESIGN.md §7)."""

from repro.common.configs import LMConfig, TrainingConfig
from repro.configs.base import Arch

CONFIG = LMConfig(
    name="arctic-480b",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab_size=32_000,
    moe=True, n_experts=128, top_k=2, n_shared_experts=0, d_expert=4864,
    moe_dense_residual=True,
)

REDUCED = LMConfig(
    name="arctic-480b-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=96, vocab_size=512,
    moe=True, n_experts=8, top_k=2, d_expert=96, moe_dense_residual=True,
    dtype="float32",
)

ARCH = Arch(
    id="arctic-480b", family="lm", config=CONFIG,
    train=TrainingConfig(optimizer="adafactor", lr=1e-4, remat="full",
                         microbatch=4),
    reduced=REDUCED, source="hf:Snowflake/snowflake-arctic-base; hf",
    notes="dense-MoE hybrid: dense FFN residual in parallel with 128e top-2",
)
