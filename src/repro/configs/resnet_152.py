"""resnet-152 [arXiv:1512.03385; paper]: depths 3-8-36-3, width 64,
bottleneck 4x, img_res=224."""

from repro.common.configs import TrainingConfig, VisionConfig
from repro.configs.base import Arch

CONFIG = VisionConfig(
    name="resnet-152", family="resnet", img_res=224,
    depths=(3, 8, 36, 3), width=64, bottleneck=4,
)

REDUCED = VisionConfig(
    name="resnet-152-smoke", family="resnet", img_res=64,
    depths=(1, 2, 2, 1), width=8, n_classes=10, dtype="float32",
)

ARCH = Arch(
    id="resnet-152", family="vision", config=CONFIG,
    train=TrainingConfig(optimizer="sgdm", lr=0.1, weight_decay=1e-4),
    reduced=REDUCED, source="arXiv:1512.03385; paper",
)
