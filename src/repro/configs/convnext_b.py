"""convnext-b [arXiv:2201.03545; paper]: depths 3-3-27-3, dims
128-256-512-1024, img_res=224."""

from repro.common.configs import TrainingConfig, VisionConfig
from repro.configs.base import Arch

CONFIG = VisionConfig(
    name="convnext-b", family="convnext", img_res=224,
    depths=(3, 3, 27, 3), dims=(128, 256, 512, 1024), norm="layernorm",
)

REDUCED = VisionConfig(
    name="convnext-b-smoke", family="convnext", img_res=64,
    depths=(1, 1, 2, 1), dims=(16, 32, 64, 128), n_classes=10,
    norm="layernorm", dtype="float32",
)

ARCH = Arch(
    id="convnext-b", family="vision", config=CONFIG,
    train=TrainingConfig(optimizer="adamw", lr=4e-3, weight_decay=0.05),
    reduced=REDUCED, source="arXiv:2201.03545; paper",
)
