"""efficientnet-b7 [arXiv:1905.11946; paper]: compound scaling width 2.0 /
depth 3.1 over the B0 base, img_res=600."""

from repro.common.configs import TrainingConfig, VisionConfig
from repro.configs.base import Arch

CONFIG = VisionConfig(
    name="efficientnet-b7", family="efficientnet", img_res=600,
    width_mult=2.0, depth_mult=3.1,
)

REDUCED = VisionConfig(
    name="efficientnet-b7-smoke", family="efficientnet", img_res=64,
    width_mult=0.25, depth_mult=0.25, n_classes=10, dtype="float32",
)

ARCH = Arch(
    id="efficientnet-b7", family="vision", config=CONFIG,
    train=TrainingConfig(optimizer="sgdm", lr=0.1, weight_decay=1e-5),
    reduced=REDUCED, source="arXiv:1905.11946; paper",
)
