"""Architecture registry: ``get(arch_id)`` -> Arch for every assigned
architecture (plus the paper's own edge-detection fleet)."""

from __future__ import annotations

import importlib

from repro.configs.base import (DIFFUSION_SHAPES, LM_SHAPES,
                                VISION_SHAPES, Arch)

_MODULES = {
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "arctic-480b": "repro.configs.arctic_480b",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "stablelm-3b": "repro.configs.stablelm_3b",
    "flux-dev": "repro.configs.flux_dev",
    "dit-l2": "repro.configs.dit_l2",
    "convnext-b": "repro.configs.convnext_b",
    "resnet-152": "repro.configs.resnet_152",
    "efficientnet-b7": "repro.configs.efficientnet_b7",
    "resnet-50": "repro.configs.resnet_50",
}

ARCH_IDS = tuple(_MODULES)


def get(arch_id: str) -> Arch:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).ARCH


def all_archs() -> list[Arch]:
    return [get(a) for a in ARCH_IDS]
