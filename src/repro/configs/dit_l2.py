"""dit-l2 [arXiv:2212.09748; paper]: DiT-L/2 — img_res=256 patch=2
n_layers=24 d_model=1024 n_heads=16, class-conditional on VAE latents."""

from repro.common.configs import DiTConfig, TrainingConfig
from repro.configs.base import Arch

CONFIG = DiTConfig(
    name="dit-l2",
    img_res=256, patch=2, n_layers=24, d_model=1024, n_heads=16,
    in_channels=4, n_classes=1000,
)

REDUCED = DiTConfig(
    name="dit-l2-smoke",
    img_res=64, patch=2, n_layers=2, d_model=64, n_heads=4,
    in_channels=4, n_classes=10, dtype="float32",
)

ARCH = Arch(
    id="dit-l2", family="diffusion", config=CONFIG,
    train=TrainingConfig(optimizer="adamw", lr=1e-4, remat="dots"),
    reduced=REDUCED, source="arXiv:2212.09748; paper",
)
