"""stablelm-3b [hf:stabilityai/stablelm-2-1_6b; unverified]: 32L d_model=2560
32H (GQA kv=32 = MHA) d_ff=6912 vocab=50304, dense, LayerNorm."""

from repro.common.configs import LMConfig, TrainingConfig
from repro.configs.base import Arch

CONFIG = LMConfig(
    name="stablelm-3b",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab_size=50_304, norm="layernorm",
)

REDUCED = LMConfig(
    name="stablelm-3b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=160, vocab_size=512, norm="layernorm", dtype="float32",
)

ARCH = Arch(
    id="stablelm-3b", family="lm", config=CONFIG,
    train=TrainingConfig(optimizer="adamw", lr=3e-4, remat="dots"),
    reduced=REDUCED, source="hf:stabilityai/stablelm-2-1_6b; unverified",
)
