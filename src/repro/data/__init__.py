from repro.data.workload import VideoStreamWorkload
from repro.data import tokens, images, pipeline

__all__ = ["VideoStreamWorkload", "tokens", "images", "pipeline"]
