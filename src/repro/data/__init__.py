from repro.data import images, pipeline, tokens, traces
from repro.data.workload import VideoStreamWorkload

__all__ = ["VideoStreamWorkload", "tokens", "images", "pipeline", "traces"]
