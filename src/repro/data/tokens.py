"""Synthetic LM token pipeline: deterministic, shard-aware, infinite."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def synthetic_lm_batch(rng, batch: int, seq: int, vocab: int,
                       p_det: float = 0.8):
    """Markov synthetic token stream: with prob ``p_det`` the next token is
    a fixed function of the current one, else uniform — so a single-token
    context suffices to learn most of the stream and a few dozen training
    steps show a real loss decrease (optimal xent ~= (1-p)ln V)."""
    k1, k2, k3 = jax.random.split(rng, 3)
    t0 = jax.random.randint(k1, (batch,), 0, vocab)
    noise = jax.random.randint(k2, (batch, seq), 0, vocab)
    use_det = jax.random.bernoulli(k3, p_det, (batch, seq))

    def step(tok, xs):
        nz, det = xs
        nxt = jnp.where(det, (tok * 31 + 7) % vocab, nz)
        return nxt, nxt

    _, toks = jax.lax.scan(step, t0, (noise.T, use_det.T))
    toks = jnp.concatenate([t0[:, None], toks.T], axis=1)
    return {"tokens": toks[:, :-1].astype(jnp.int32),
            "labels": toks[:, 1:].astype(jnp.int32)}


class TokenLoader:
    """Infinite iterator of sharded batches."""

    def __init__(self, batch: int, seq: int, vocab: int, sharding=None,
                 seed: int = 0):
        self.batch, self.seq, self.vocab = batch, seq, vocab
        self.sharding = sharding
        self.rng = jax.random.PRNGKey(seed)
        self.step = 0

    def __iter__(self):
        return self

    def __next__(self):
        rng = jax.random.fold_in(self.rng, self.step)
        self.step += 1
        b = synthetic_lm_batch(rng, self.batch, self.seq, self.vocab)
        if self.sharding is not None:
            b = jax.tree.map(
                lambda x, s: jax.device_put(x, s), b,
                {"tokens": self.sharding, "labels": self.sharding})
        return b
