"""Synthetic vision workload: per-stream Markov scene complexity producing
frames with a known number of objects (bright squares on noise), plus the
pseudo-ground-truth grids used for real mAP evaluation (mirrors the paper's
YOLOv8x-as-reference protocol with an exactly-known reference)."""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import estimator as EST


@dataclass
class VideoStreamWorkload:
    n_streams: int = 8
    img_res: int = 64
    n_groups: int = 5
    grid: int = 8
    stickiness: float = 0.85
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._P = np.asarray(EST.markov_transition(self.n_groups,
                                                   self.stickiness))
        pi = np.asarray(EST.stationary(self._P))
        self._state = self._rng.choice(self.n_groups, self.n_streams, p=pi)
        self._last_frame: dict[int, np.ndarray] = {}

    def next_frame(self, stream: int):
        """Advance the stream one frame; returns (image (R,R,3) f32, g_true).
        The frame contains exactly ``count`` objects (count == group, the
        paper's 4+ bucket rendered as 4..7 objects)."""
        s = int(self._state[stream])
        s = int(self._rng.choice(self.n_groups, p=self._P[s]))
        self._state[stream] = s
        count = s if s < self.n_groups - 1 else int(self._rng.integers(4, 8))
        img = self._rng.normal(0.0, 0.1, (self.img_res, self.img_res, 3))
        cell = self.img_res // self.grid
        cells = self._rng.choice(self.grid * self.grid, count, replace=False)
        for c in cells:
            cy, cx = divmod(int(c), self.grid)
            img[cy * cell:(cy + 1) * cell, cx * cell:(cx + 1) * cell] += 2.0
        img = img.astype(np.float32)
        self._last_frame[stream] = img
        return img, s

    def _threshold_grid(self, img: np.ndarray) -> np.ndarray:
        """(G, G) int32 objectness grid by mean-pooling each cell and
        thresholding: lit cells sit ~2.0 above the noise floor, so 0.5
        separates them exactly."""
        cell = self.img_res // self.grid
        pooled = img.reshape(self.grid, cell, self.grid, cell, 3)
        return (pooled.mean(axis=(1, 3, 4)) > 0.5).astype(np.int32)

    def reference_grid(self, stream: int):
        """Ground-truth objectness grid (G, G) of the LAST generated frame
        of ``stream`` (exact — objects are drawn a full cell at a time, so
        the thresholding path ``labelled_frame`` uses recovers precisely
        the drawn cells). Raises if the stream has no frame yet."""
        if stream not in self._last_frame:
            raise ValueError(f"stream {stream} has no generated frame yet; "
                             "call next_frame/labelled_frame first")
        return self._threshold_grid(self._last_frame[stream])

    def labelled_frame(self, stream: int):
        """(image, obj_grid (G,G), cls_grid, g_true) for detector training."""
        img, g = self.next_frame(stream)
        obj = self.reference_grid(stream)
        cls = np.zeros_like(obj)
        return img, obj, cls, g

    def noisy_count(self, stream: int, map_pg: float) -> int:
        """Modelled detection count (executor 'modelled' mode)."""
        s = int(self._state[stream])
        true_count = s if s < self.n_groups - 1 else 5
        p = min(1.0, 0.80 + 0.20 * map_pg / 100.0)
        det = int(self._rng.binomial(true_count, p))
        if self._rng.random() < 0.05 * (1 - map_pg / 100.0):
            det += 1
        return det


def closed_loop_arrivals(n_users: int, n_requests: int):
    """Initial arrival offsets for Locust-style closed-loop load."""
    return [i * 1e-4 for i in range(n_users)]
