"""Synthetic image/label and latent batches (vision + diffusion cells)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def synthetic_image_batch(rng, batch: int, res: int, n_classes: int):
    """Class-dependent blob images: each class lights a different grid cell,
    so a few hundred training steps produce above-chance accuracy."""
    k1, k2 = jax.random.split(rng)
    labels = jax.random.randint(k1, (batch,), 0, n_classes)
    imgs = jax.random.normal(k2, (batch, res, res, 3)) * 0.1
    g = max(res // 8, 1)
    cy = (labels % 8) * g
    ys = jnp.arange(res)[None, :]
    mask = ((ys >= cy[:, None]) & (ys < cy[:, None] + g)).astype(jnp.float32)
    imgs = imgs + mask[:, :, None, None] * 2.0
    return {"images": imgs.astype(jnp.float32), "labels": labels.astype(jnp.int32)}


def synthetic_diffusion_batch(rng, batch: int, latent_res: int, channels: int,
                              n_classes: int = 1000, mmdit_cfg=None):
    ks = jax.random.split(rng, 6)
    lat = jax.random.normal(ks[0], (batch, latent_res, latent_res, channels))
    noise = jax.random.normal(ks[1], lat.shape)
    if mmdit_cfg is not None:
        return {
            "latents": lat, "noise": noise,
            "txt": jax.random.normal(ks[2], (batch, mmdit_cfg.txt_len,
                                             mmdit_cfg.d_txt)),
            "pooled": jax.random.normal(ks[3], (batch, mmdit_cfg.d_pooled)),
            "t": jax.random.uniform(ks[4], (batch,)),
            "guidance": jnp.full((batch,), 3.5),
        }
    return {
        "latents": lat, "noise": noise,
        "labels": jax.random.randint(ks[2], (batch,), 0, n_classes),
        "t": jax.random.randint(ks[3], (batch,), 0, 1000),
    }
