"""Sharded host -> device input pipeline.

Single-process here, but written against the multi-host contract: each host
materialises only its addressable shard of the global batch and assembles a
global array (``jax.make_array_from_single_device_arrays``)."""

from __future__ import annotations

import jax


def device_put_sharded_batch(batch, sharding):
    """Place a host batch onto devices under ``sharding``. On multi-host,
    slice to the per-host addressable portion first."""
    def put(x):
        if hasattr(sharding, "addressable_devices") and \
                len(sharding.addressable_devices) < len(sharding.device_set):
            # multi-host: build from addressable shards
            idx = sharding.addressable_devices_indices_map(x.shape)
            arrs = [jax.device_put(x[i], d) for d, i in idx.items()]
            return jax.make_array_from_single_device_arrays(
                x.shape, sharding, arrs)
        return jax.device_put(x, sharding)
    return jax.tree.map(put, batch)


def prefetch(iterator, size: int = 2):
    """Simple software pipeline: keep ``size`` batches in flight."""
    import threading
    import queue as q

    out: q.Queue = q.Queue(maxsize=size)
    SENTINEL = object()

    def worker():
        try:
            for item in iterator:
                out.put(item)
        finally:
            out.put(SENTINEL)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = out.get()
        if item is SENTINEL:
            return
        yield item
