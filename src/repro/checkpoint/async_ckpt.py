"""Asynchronous checkpointing: snapshot on-device state to host (cheap),
write to disk on a background thread, never blocking the train loop for
longer than the device->host copy."""

from __future__ import annotations

from concurrent.futures import Future, ThreadPoolExecutor

import jax

from repro.checkpoint.checkpointer import save_checkpoint


class AsyncCheckpointer:
    def __init__(self, ckpt_dir: str, keep_n: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_n = keep_n
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._inflight: Future | None = None

    def save(self, step: int, state) -> None:
        """Blocking part: device_get snapshot. Disk write happens async."""
        self.wait()                       # one in flight at a time
        snapshot = jax.tree.map(lambda x: jax.device_get(x), state)
        self._inflight = self._pool.submit(
            save_checkpoint, self.ckpt_dir, step, snapshot, self.keep_n)

    def wait(self) -> None:
        if self._inflight is not None:
            self._inflight.result()
            self._inflight = None

    def close(self) -> None:
        self.wait()
        self._pool.shutdown()
