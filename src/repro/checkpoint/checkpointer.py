"""Sharded checkpointing (no external deps: npz shards + msgpack manifest).

Layout per step:
    <dir>/step_<N>/manifest.msgpack   tree structure, shapes, dtypes, mesh
    <dir>/step_<N>/host<H>.npz        this host's addressable shard data
    <dir>/step_<N>/COMMIT             written last -> atomic completeness

Fault-tolerance contract:
  * a crash mid-write leaves no COMMIT file; ``latest_step`` skips it;
  * restore validates every expected shard file before loading;
  * ``keep_n`` old steps are garbage-collected only after COMMIT of the new.
"""

from __future__ import annotations

import os
import shutil

import jax
import msgpack
import numpy as np

from repro.common.treeutil import flatten_with_names


def _leaf_names(tree):
    return [n for n, _ in flatten_with_names(tree)]


def save_checkpoint(ckpt_dir: str, step: int, state, keep_n: int = 3) -> str:
    """Write a complete checkpoint; returns the step directory."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = d + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    leaves = flatten_with_names(state)
    host = jax.process_index()
    arrays = {}
    meta = []
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        arrays[name] = arr
        meta.append({"name": name, "shape": list(arr.shape),
                     "dtype": str(arr.dtype)})
    np.savez(os.path.join(tmp, f"host{host}.npz"),
             **{k.replace("/", "__"): v for k, v in arrays.items()})
    manifest = {"step": step, "n_hosts": jax.process_count(), "leaves": meta}
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    open(os.path.join(tmp, "COMMIT"), "w").close()
    if os.path.exists(d):              # idempotent re-save of same step
        shutil.rmtree(tmp)
    else:
        os.replace(tmp, d)

    # GC old steps (only after the new one is committed)
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_n]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
    return d


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for e in os.listdir(ckpt_dir):
        if e.startswith("step_") and not e.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, e, "COMMIT")):
                out.append(int(e.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    s = all_steps(ckpt_dir)
    return s[-1] if s else None


def restore_checkpoint(ckpt_dir: str, step: int, abstract_state,
                       shardings=None):
    """Restore into the structure of ``abstract_state``; device_put with
    ``shardings`` (same tree) when given."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.exists(os.path.join(d, "COMMIT")):
        raise FileNotFoundError(f"incomplete checkpoint at {d}")
    host = jax.process_index()
    z = np.load(os.path.join(d, f"host{host}.npz"))

    names = _leaf_names(abstract_state)
    leaves_out = []
    for name in names:
        key = name.replace("/", "__")
        if key not in z:
            raise KeyError(f"checkpoint missing leaf {name}")
        leaves_out.append(z[key])
    treedef = jax.tree.structure(abstract_state)
    state = jax.tree.unflatten(treedef, leaves_out)
    if shardings is not None:
        state = jax.tree.map(jax.device_put, state, shardings)
    return state
