"""Elastic restore: load a checkpoint written under one mesh onto a
DIFFERENT mesh/topology (node failures, slice resize, pod loss).

The npz shards hold full (host-gathered) arrays, so resharding reduces to
device_put with the new shardings; on true multi-host filesystems each host
slices its addressable window out of the loaded array first — implemented
here against the sharding's index map so the logic is multi-host correct."""

from __future__ import annotations

import jax
import numpy as np

from repro.checkpoint.checkpointer import restore_checkpoint


def reshard_restore(ckpt_dir: str, step: int, abstract_state, new_shardings):
    """Restore + reshard onto a new mesh in one pass."""
    host_state = restore_checkpoint(ckpt_dir, step, abstract_state,
                                    shardings=None)

    def put(x, sh):
        x = np.asarray(x)
        try:
            idx_map = sh.addressable_devices_indices_map(x.shape)
        except Exception:
            return jax.device_put(x, sh)
        arrs = [jax.device_put(x[idx], d) for d, idx in idx_map.items()]
        if len(arrs) == len(sh.device_set):
            return jax.make_array_from_single_device_arrays(x.shape, sh, arrs)
        return jax.make_array_from_single_device_arrays(x.shape, sh, arrs)

    return jax.tree.map(put, host_state, new_shardings)


def survivable(abstract_state, lost_fraction: float) -> bool:
    """Policy hook: with full (non-sharded-redundant) npz shards per host a
    single surviving host can restore everything; with partitioned shards
    survival requires every data-parallel replica group to keep >= 1 copy.
    Returns whether restore is possible under the simple model."""
    return lost_fraction < 1.0
