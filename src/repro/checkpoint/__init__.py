from repro.checkpoint.checkpointer import save_checkpoint, restore_checkpoint, latest_step
from repro.checkpoint.async_ckpt import AsyncCheckpointer
from repro.checkpoint.elastic import reshard_restore

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer", "reshard_restore"]
