from repro.checkpoint.async_ckpt import AsyncCheckpointer
from repro.checkpoint.checkpointer import (latest_step, restore_checkpoint,
                                           save_checkpoint)
from repro.checkpoint.elastic import reshard_restore

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer", "reshard_restore"]
