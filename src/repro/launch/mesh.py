"""Production mesh builders.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — smoke tests see 1 device; only dryrun.py
sets ``xla_force_host_platform_device_count``.
"""

from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh

try:                                   # jax >= 0.5: explicit-axis-type API
    from jax.sharding import AxisType
except ImportError:                    # older jax: only Auto axes exist
    AxisType = None


def compat_mesh(shape, axes) -> Mesh:
    """make_mesh across jax versions: pass axis_types where supported,
    fall back to positional construction on older jax."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    devices = np.asarray(jax.devices()[:math.prod(shape)]).reshape(shape)
    return Mesh(devices, axis_names=axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model); the pod
    axis composes with data for batch/FSDP sharding, and is the boundary
    where gradient compression / hierarchical gateways attach."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_mesh(shape, axes)


def make_sweep_mesh(n_devices: int | None = None) -> Mesh:
    """1-D ``('config',)`` mesh over local devices for sharded grid sweeps
    (``Scenario(mesh=...)`` / the legacy ``sweep_grid(mesh=...)``). The
    sweep shards the flat config axis of a ``ConfigGrid`` across every
    mesh device; the grid is embarrassingly parallel, so any device count
    works (the config axis is padded up to a multiple of it). With a
    user-blocked scenario (``Scenario(user_block=...)``) the rows are
    balancer-replica blocks, so the same mesh also shards the user axis:
    a 10^6-user config becomes ~10^3 block rows spread over the devices,
    per-user state and all."""
    n = len(jax.devices()) if n_devices is None else n_devices
    return compat_mesh((n,), ("config",))


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Mesh over however many local devices exist (tests / examples)."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return compat_mesh((data, model), ("data", "model"))
