"""Production mesh builders.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — smoke tests see 1 device; only dryrun.py
sets ``xla_force_host_platform_device_count``.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model); the pod
    axis composes with data for batch/FSDP sharding, and is the boundary
    where gradient compression / hierarchical gateways attach."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Mesh over however many local devices exist (tests / examples)."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
