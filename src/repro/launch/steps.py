"""Cell factory: (architecture x input-shape) -> step function + abstract
inputs + logical shardings.

Every one of the 40 assigned cells (and every reduced smoke variant) is
built through :func:`build_cell`; the dry-run, the smoke tests, the roofline
report and the serving executors all consume the same Cell object, so there
is exactly one definition of what each cell computes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.common.configs import (DiTConfig, LMConfig, MMDiTConfig, ShapeSpec,
                                  TrainingConfig, VisionConfig)
from repro.configs.base import Arch
from repro.distributed import sharding as SH
from repro.models import convnets, dit, mmdit
from repro.models import transformer as T
from repro.models.layers import sds
from repro.training import train_loop as TL

i32 = jnp.int32
f32 = jnp.float32
bf16 = jnp.bfloat16

GiB = 1 << 30


@dataclass
class Cell:
    arch: Arch
    shape: ShapeSpec
    config: Any                      # possibly reduced
    step_fn: Callable                # positional args
    abstract_args: tuple             # ShapeDtypeStruct pytrees, call order
    arg_logical: tuple               # same structure, tuples of logical axes
    donate: tuple[int, ...]          # donated arg indices
    rules: SH.AxisRules
    out_logical: Any = None
    description: str = ""
    # while-loop structure for the dry-run trip-count solve: a list of
    # chains; each chain is [(tag, trip_count), ...] ordered outer->inner.
    loops: tuple = ()

    def in_shardings(self, mesh):
        return tuple(
            SH.shard_tree(mesh, self.rules, lg, ab)
            for lg, ab in zip(self.arg_logical, self.abstract_args))


# ------------------------------------------------------------- rules -------

def select_rules(arch: Arch, shape: ShapeSpec, cfg) -> SH.AxisRules:
    if shape.kind == "train":
        # TP + FSDP is the production default for train cells. Two measured
        # alternatives were REFUTED (EXPERIMENTS §Perf): pure FSDP without
        # TP duplicates compute 16x on the idle model axis (it.2), and
        # sequence-DP (context parallelism) trades TP activation
        # all-reduces for K/V all-gathers, a loss for MHA archs (it.3).
        return SH.DEFAULT_RULES
    if arch.family == "lm":
        # Serving: replicate params over data unless they don't fit a
        # model-axis shard (e.g. arctic-480b -> keep FSDP sharding).
        pbytes = cfg.n_params() * 2
        base = SH.DEFAULT_RULES if pbytes / 16 > 8 * GiB else SH.SERVE_RULES
        # Perf it.4: when kv_heads divide the model axis, shard the cache on
        # HEADS (attention stays fully local, zero per-layer collectives);
        # otherwise fall back to sequence sharding (distributed split-K).
        kv_shardable = cfg.n_kv_heads % 16 == 0
        if shape.kind == "decode" and shape.global_batch == 1:
            # long-context decode: cache sharded across the whole mesh
            if kv_shardable:
                return base.override(batch=None, seq_kv=("data",),
                                     kv_heads=("model",))
            return base.override(seq_kv=("data", "model"), batch=None)
        if kv_shardable:
            return base.override(seq_kv=None, kv_heads=("model",))
        return base.override(seq_kv=("model",))
    if arch.family == "vision" and shape.global_batch == 1:
        # latency cell: spatial partitioning over the data axis
        return SH.SERVE_RULES.override(batch=None, spatial_h=("data",))
    return SH.SERVE_RULES


def _num_groups(mesh, batch: int) -> int:
    """MoE dispatch groups = batch shards (so in-group sorts stay local)."""
    if mesh is None:
        return 1
    sizes = SH.mesh_axis_sizes(mesh)
    g = sizes.get("pod", 1) * sizes.get("data", 1)
    while g > 1 and batch % g != 0:
        g //= 2
    return max(g, 1)


# --------------------------------------------------------- optimizer axes --

def _opt_logical(tcfg: TrainingConfig, p_logical, p_abstract):
    if tcfg.optimizer == "adamw":
        return {"m": p_logical, "v": p_logical}
    if tcfg.optimizer == "sgdm":
        return {"mom": p_logical}
    if tcfg.optimizer == "adafactor":
        def leaf(p, lg):
            if p.ndim >= 2 and p.shape[-1] >= 128 and p.shape[-2] >= 128:
                return {"vr": tuple(lg[:-1]), "vc": tuple(lg[:-2]) + (lg[-1],)}
            return {"v": tuple(lg)}
        return jax.tree.map(leaf, p_abstract, p_logical)
    raise ValueError(tcfg.optimizer)


def _state_logical(tcfg, p_logical, p_abstract, extra_logical=None):
    st = {"params": p_logical,
          "opt": _opt_logical(tcfg, p_logical, p_abstract),
          "step": ()}
    if extra_logical is not None:
        st["extra"] = extra_logical
    return st


# ------------------------------------------------------------- LM cells ----

def _lm_cell(arch: Arch, shape: ShapeSpec, cfg: LMConfig, mesh) -> Cell:
    B, S = shape.global_batch, shape.seq_len
    tcfg = arch.train
    rules = select_rules(arch, shape, cfg)
    groups = _num_groups(mesh, B)
    p_abs, p_log = T.param_specs(cfg)

    if shape.kind == "train":
        def loss_fn(params, batch):
            with SH.use_rules(rules):
                return T.loss_and_metrics(
                    cfg, params, batch, num_groups=groups, remat=tcfg.remat,
                    label_smoothing=tcfg.label_smoothing)

        step = TL.make_train_step(loss_fn, tcfg)
        state_abs = TL.abstract_state(p_abs, tcfg)
        batch_abs = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        state_log = _state_logical(tcfg, p_log, p_abs)
        batch_log = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
        chain = []
        if tcfg.microbatch:
            chain.append(("micro", tcfg.microbatch))
        chain.append(("layers", cfg.n_layers))
        return Cell(arch, shape, cfg, step, (state_abs, batch_abs),
                    (state_log, batch_log), donate=(0,), rules=rules,
                    loops=(tuple(chain),),
                    description=f"train_step {B}x{S}")

    cache_abs, cache_log = T.cache_specs(cfg, B, S)
    if shape.kind == "prefill":
        def step(params, tokens, caches):
            with SH.use_rules(rules):
                return T.prefill(cfg, params, tokens, caches,
                                 num_groups=groups)

        tok_abs = sds((B, S), i32)
        chain = [("layers", cfg.n_layers)]
        if S >= 4096:
            chain.append(("attn", S // 512))
        return Cell(arch, shape, cfg, step, (p_abs, tok_abs, cache_abs),
                    (p_log, ("batch", "seq"), cache_log), donate=(2,),
                    rules=rules, loops=(tuple(chain),),
                    description=f"prefill {B}x{S}")

    # decode: one token against a cache filled to S-1
    def step(params, token, caches, pos):
        with SH.use_rules(rules):
            return T.decode_step(cfg, params, token, caches, pos,
                                 num_groups=groups)

    tok_abs = sds((B, 1), i32)
    pos_abs = sds((), i32)
    return Cell(arch, shape, cfg, step, (p_abs, tok_abs, cache_abs, pos_abs),
                (p_log, ("batch", "seq"), cache_log, ()), donate=(2,),
                rules=rules, loops=((("layers", cfg.n_layers),),),
                description=f"decode_step B={B} kv={S}")


# ------------------------------------------------------- diffusion cells ---

def _dit_cell(arch: Arch, shape: ShapeSpec, cfg: DiTConfig, mesh) -> Cell:
    B = shape.global_batch
    lr = cfg.latent_res(shape.img_res)
    C = cfg.in_channels
    tcfg = arch.train
    rules = select_rules(arch, shape, cfg)
    p_abs, p_log = dit.param_specs(cfg)
    lat = sds((B, lr, lr, C), bf16 if cfg.dtype == "bfloat16" else f32)

    if shape.kind == "train":
        def loss_fn(params, batch):
            with SH.use_rules(rules):
                return dit.diffusion_loss(cfg, params, batch)

        step = TL.make_train_step(loss_fn, tcfg)
        batch_abs = {"latents": lat, "labels": sds((B,), i32),
                     "t": sds((B,), i32), "noise": lat}
        b_log = {"latents": ("batch", None, None, None), "labels": ("batch",),
                 "t": ("batch",), "noise": ("batch", None, None, None)}
        return Cell(arch, shape, cfg, step,
                    (TL.abstract_state(p_abs, tcfg), batch_abs),
                    (_state_logical(tcfg, p_log, p_abs), b_log), donate=(0,),
                    rules=rules, loops=((("layers", cfg.n_layers),),),
                    description=f"dit train {B}@{shape.img_res}")

    def step(params, xt, t, t_prev, y):
        with SH.use_rules(rules):
            return dit.sample_step(cfg, params, xt, t, t_prev, y)

    return Cell(arch, shape, cfg, step,
                (p_abs, lat, sds((B,), i32), sds((B,), i32), sds((B,), i32)),
                (p_log, ("batch", None, None, None), ("batch",), ("batch",),
                 ("batch",)),
                donate=(1,), rules=rules,
                loops=((("layers", cfg.n_layers),),),
                description=f"dit sample_step {B}@{shape.img_res} "
                            f"(x{shape.steps} steps)")


def _mmdit_cell(arch: Arch, shape: ShapeSpec, cfg: MMDiTConfig, mesh) -> Cell:
    B = shape.global_batch
    lr = cfg.latent_res(shape.img_res)
    C = cfg.in_channels
    tcfg = arch.train
    rules = select_rules(arch, shape, cfg)
    p_abs, p_log = mmdit.param_specs(cfg)
    dt = bf16 if cfg.dtype == "bfloat16" else f32
    lat = sds((B, lr, lr, C), dt)
    txt = sds((B, cfg.txt_len, cfg.d_txt), dt)
    pooled = sds((B, cfg.d_pooled), dt)
    lat_log = ("batch", None, None, None)
    txt_log = ("batch", "seq", None)

    if shape.kind == "train":
        def loss_fn(params, batch):
            with SH.use_rules(rules):
                return mmdit.rectified_flow_loss(cfg, params, batch)

        step = TL.make_train_step(loss_fn, tcfg)
        batch_abs = {"latents": lat, "txt": txt, "pooled": pooled,
                     "t": sds((B,), f32), "noise": lat,
                     "guidance": sds((B,), f32)}
        b_log = {"latents": lat_log, "txt": txt_log, "pooled": ("batch", None),
                 "t": ("batch",), "noise": lat_log, "guidance": ("batch",)}
        return Cell(arch, shape, cfg, step,
                    (TL.abstract_state(p_abs, tcfg), batch_abs),
                    (_state_logical(tcfg, p_log, p_abs), b_log), donate=(0,),
                    rules=rules,
                    loops=((("double", cfg.n_double_blocks),),
                           (("single", cfg.n_single_blocks),)),
                    description=f"mmdit train {B}@{shape.img_res}")

    def step(params, xt, txt_, pooled_, t, t_prev, guidance):
        with SH.use_rules(rules):
            return mmdit.sample_step(cfg, params, xt, txt_, pooled_, t,
                                     t_prev, guidance)

    return Cell(arch, shape, cfg, step,
                (p_abs, lat, txt, pooled, sds((B,), f32), sds((B,), f32),
                 sds((B,), f32)),
                (p_log, lat_log, txt_log, ("batch", None), ("batch",),
                 ("batch",), ("batch",)),
                donate=(1,), rules=rules,
                loops=((("double", cfg.n_double_blocks),),
                       (("single", cfg.n_single_blocks),)),
                description=f"mmdit sample_step {B}@{shape.img_res} "
                            f"(x{shape.steps} steps)")


# ---------------------------------------------------------- vision cells ---

def _vision_cell(arch: Arch, shape: ShapeSpec, cfg: VisionConfig, mesh) -> Cell:
    B, R = shape.global_batch, shape.img_res
    tcfg = arch.train
    rules = select_rules(arch, shape, cfg)
    p_abs, p_log, st_abs = convnets.param_specs(cfg)
    st_log = jax.tree.map(lambda _: ("norm",), st_abs)
    img = sds((B, R, R, 3), f32)
    img_log = ("batch", "spatial_h", "spatial_w", None)

    if shape.kind == "train":
        def loss_fn(params, batch, bn_state):
            with SH.use_rules(rules):
                loss, (metrics, new_state) = convnets.xent_loss(
                    cfg, params, bn_state, batch, train=True)
            return loss, (metrics, new_state)

        step = TL.make_train_step(loss_fn, tcfg, has_extra_state=True)
        state_abs = TL.abstract_state(p_abs, tcfg, extra=st_abs)
        batch_abs = {"images": img, "labels": sds((B,), i32)}
        state_log = _state_logical(tcfg, p_log, p_abs, extra_logical=st_log)
        b_log = {"images": img_log, "labels": ("batch",)}
        return Cell(arch, shape, cfg, step, (state_abs, batch_abs),
                    (state_log, b_log), donate=(0,), rules=rules,
                    description=f"{cfg.family} train {B}@{R}")

    def step(params, state, images):
        with SH.use_rules(rules):
            logits, _ = convnets.forward(cfg, params, state, images,
                                         train=False)
        return logits

    return Cell(arch, shape, cfg, step, (p_abs, st_abs, img),
                (p_log, st_log, img_log), donate=(), rules=rules,
                description=f"{cfg.family} serve {B}@{R}")


# ------------------------------------------------------------- factory -----

REDUCED_SHAPES = {
    "lm": {
        "train": ShapeSpec("train_smoke", "train", global_batch=4, seq_len=32),
        "prefill": ShapeSpec("prefill_smoke", "prefill", global_batch=2,
                             seq_len=32),
        "decode": ShapeSpec("decode_smoke", "decode", global_batch=2,
                            seq_len=64),
    },
    "diffusion": {
        "train": ShapeSpec("train_smoke", "train", global_batch=2, img_res=64,
                           steps=10),
        "serve": ShapeSpec("serve_smoke", "serve", global_batch=2, img_res=64,
                           steps=2),
    },
    "vision": {
        "train": ShapeSpec("train_smoke", "train", global_batch=2, img_res=64),
        "serve": ShapeSpec("serve_smoke", "serve", global_batch=2, img_res=64),
    },
}


def build_cell(arch: Arch, shape: ShapeSpec | str, mesh=None,
               reduced: bool = False) -> Cell:
    if isinstance(shape, str):
        shape = arch.shape(shape)
    cfg = arch.config
    if reduced:
        cfg = arch.reduced
        shape = REDUCED_SHAPES[arch.family][shape.kind]
    if arch.family == "lm":
        return _lm_cell(arch, shape, cfg, mesh)
    if arch.family == "diffusion":
        if isinstance(cfg, MMDiTConfig):
            return _mmdit_cell(arch, shape, cfg, mesh)
        return _dit_cell(arch, shape, cfg, mesh)
    if arch.family == "vision":
        return _vision_cell(arch, shape, cfg, mesh)
    raise ValueError(arch.family)


def init_concrete(cell: Cell, rng=None):
    """Real (initialised) arguments for executing a cell — used by the smoke
    tests and the examples. Only call on reduced cells (full configs are
    dry-run only)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    arch, shape, cfg = cell.arch, cell.shape, cell.config
    tcfg = arch.train
    kr, kb = jax.random.split(rng)

    if arch.family == "lm":
        params = T.init_params(cfg, kr)
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            state = TL.init_state(params, tcfg)
            batch = {"tokens": jax.random.randint(kb, (B, S), 0,
                                                  cfg.vocab_size, i32),
                     "labels": jax.random.randint(kb, (B, S), 0,
                                                  cfg.vocab_size, i32)}
            return (state, batch)
        caches = T.init_cache(cfg, B, S,
                              bf16 if cfg.dtype == "bfloat16" else f32)
        if shape.kind == "prefill":
            toks = jax.random.randint(kb, (B, S), 0, cfg.vocab_size, i32)
            return (params, toks, caches)
        tok = jax.random.randint(kb, (B, 1), 0, cfg.vocab_size, i32)
        pos = jnp.asarray(S // 2, i32)
        return (params, tok, caches, pos)

    if arch.family == "diffusion":
        B = shape.global_batch
        lr = cfg.latent_res(shape.img_res)
        dt = bf16 if cfg.dtype == "bfloat16" else f32
        lat = jax.random.normal(kb, (B, lr, lr, cfg.in_channels), dt)
        if isinstance(cfg, MMDiTConfig):
            params = mmdit.init_params(cfg, kr)
            txt = jax.random.normal(kb, (B, cfg.txt_len, cfg.d_txt), dt)
            pooled = jax.random.normal(kb, (B, cfg.d_pooled), dt)
            if shape.kind == "train":
                state = TL.init_state(params, tcfg)
                batch = {"latents": lat, "txt": txt, "pooled": pooled,
                         "t": jax.random.uniform(kb, (B,), f32),
                         "noise": jax.random.normal(kr, lat.shape, dt),
                         "guidance": jnp.full((B,), 3.5, f32)}
                return (state, batch)
            t = jnp.full((B,), 0.9, f32)
            return (params, lat, txt, pooled, t, t - 0.1,
                    jnp.full((B,), 3.5, f32))
        params = dit.init_params(cfg, kr)
        y = jax.random.randint(kb, (B,), 0, cfg.n_classes, i32)
        if shape.kind == "train":
            state = TL.init_state(params, tcfg)
            batch = {"latents": lat, "labels": y,
                     "t": jax.random.randint(kr, (B,), 0, 1000, i32),
                     "noise": jax.random.normal(kr, lat.shape, dt)}
            return (state, batch)
        t = jnp.full((B,), 500, i32)
        return (params, lat, t, t - 10, y)

    if arch.family == "vision":
        params, st = convnets.init_params(cfg, kr)
        B, R = shape.global_batch, shape.img_res
        img = jax.random.normal(kb, (B, R, R, 3), f32)
        if shape.kind == "train":
            state = TL.init_state(params, tcfg, extra=st)
            return (state, {"images": img,
                            "labels": jax.random.randint(
                                kr, (B,), 0, cfg.n_classes, i32)})
        return (params, st, img)
    raise ValueError(arch.family)


def concrete_inputs(cell: Cell, rng=None):
    """Materialise real (small!) inputs for smoke execution of a reduced
    cell: zeros for floats, uniform ints for token/label fields."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)

    def mk(path, s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            hi = 2
            p = path.lower()
            if "token" in p or "label" in p:
                hi = 8
            if p.endswith("t"):
                hi = 100
            return jax.random.randint(jax.random.fold_in(rng, hash(path) % 2**31),
                                      s.shape, 0, hi, s.dtype)
        return jnp.zeros(s.shape, s.dtype)

    out = []
    for i, a in enumerate(cell.abstract_args):
        from repro.common.treeutil import tree_map_with_path
        out.append(tree_map_with_path(lambda p, s: mk(f"{i}/{p}", s), a))
    return tuple(out)
