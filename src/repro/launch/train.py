"""Training driver.

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b --reduced \
      --steps 200 --ckpt /tmp/ckpt --resume auto

Full (non-reduced) configs target real TPU slices; this container runs the
reduced configs end-to-end on CPU, exercising the identical code path:
cell build -> sharded state -> jitted train step -> async checkpoints ->
crash-resume. ``--fail-at-step`` injects a hard failure to demonstrate
restart recovery (used by tests/test_fault_tolerance.py)."""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as C
from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.common.logging_util import log
from repro.data.images import synthetic_diffusion_batch, synthetic_image_batch
from repro.data.tokens import synthetic_lm_batch
from repro.launch import steps as S


def make_batch_fn(cell):
    arch, cfg, shape = cell.arch, cell.config, cell.shape

    def fn(step: int):
        rng = jax.random.fold_in(jax.random.PRNGKey(17), step)
        if arch.family == "lm":
            return synthetic_lm_batch(rng, shape.global_batch, shape.seq_len,
                                      cfg.vocab_size)
        if arch.family == "vision":
            return synthetic_image_batch(rng, shape.global_batch,
                                         shape.img_res, cfg.n_classes)
        lr = cfg.latent_res(shape.img_res)
        from repro.common.configs import MMDiTConfig
        mm = cfg if isinstance(cfg, MMDiTConfig) else None
        return synthetic_diffusion_batch(
            rng, shape.global_batch, lr, cfg.in_channels,
            getattr(cfg, "n_classes", 1000), mm)

    return fn


def train(arch_id: str, *, reduced: bool = True, steps: int = 100,
          ckpt_dir: str | None = None, resume: str = "auto",
          ckpt_every: int = 50, fail_at_step: int = -1, log_every: int = 10):
    arch = C.get(arch_id)
    if reduced:
        # smoke-scale models learn at smoke-scale hyperparameters
        import dataclasses
        arch = dataclasses.replace(
            arch, train=dataclasses.replace(
                arch.train, lr=min(arch.train.lr * 10, 1e-2),
                warmup_steps=10, microbatch=0))
    shape = next(s for s in arch.shapes if s.kind == "train")
    cell = S.build_cell(arch, shape, mesh=None, reduced=reduced)
    args = S.init_concrete(cell, jax.random.PRNGKey(0))
    state = args[0]

    start = 0
    ck = None
    if ckpt_dir:
        ck = AsyncCheckpointer(ckpt_dir)
        if resume == "auto":
            last = latest_step(ckpt_dir)
            if last is not None:
                state = restore_checkpoint(ckpt_dir, last, state)
                state = jax.tree.map(jnp.asarray, state)
                start = last
                log("resumed", step=last)

    step_fn = jax.jit(cell.step_fn, donate_argnums=(0,))
    batch_fn = make_batch_fn(cell)
    losses = []
    t0 = time.time()
    for step in range(start, steps):
        if step == fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")
        state, metrics = step_fn(state, batch_fn(step))
        if step % log_every == 0 or step == steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            log("train", arch=arch_id, step=step, loss=round(loss, 4),
                sps=round((step - start + 1) / (time.time() - t0), 2))
        if ck and (step + 1) % ckpt_every == 0:
            ck.save(step + 1, state)       # name = completed steps
            log("checkpoint", step=step + 1)
    if ck:
        ck.save(steps, state)
        ck.close()
    return state, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at-step", type=int, default=-1)
    a = ap.parse_args()
    _, losses = train(a.arch, reduced=a.reduced, steps=a.steps,
                      ckpt_dir=a.ckpt, resume=a.resume,
                      ckpt_every=a.ckpt_every, fail_at_step=a.fail_at_step)
    log("done", first_loss=losses[0] if losses else None,
        last_loss=losses[-1] if losses else None)


if __name__ == "__main__":
    main()
