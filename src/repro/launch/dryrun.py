import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes; record memory/cost/roofline artifacts.

  PYTHONPATH=src python -m repro.launch.dryrun                 # all 40 x 2
  PYTHONPATH=src python -m repro.launch.dryrun --arch dit-l2 --shape gen_1024
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi

The XLA_FLAGS line above MUST stay the first statement: jax locks the device
count at first init, and only the dry-run wants 512 placeholder devices.

Cost extraction: XLA's HloCostAnalysis counts while-loop bodies ONCE, so a
scanned model under-reports per-step FLOPs/collectives by ~n_layers x. Each
cell is compiled 1 + n_loop_tags times with one tagged loop's unroll bumped
per compile; the deltas solve exactly for each loop body's cost (see
repro.common.flags). The memory roofline term is analytic
(repro.roofline.memtraffic) because CPU-backend 'bytes accessed' reflects
unfused execution — both the XLA and analytic numbers are recorded.
"""

import argparse
import contextlib
import json
import time
import traceback

import jax

from repro import configs as C
from repro.common import flags
from repro.launch import steps as S
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import Roofline, collective_bytes
from repro.roofline.hw import V5E
from repro.roofline.memtraffic import cell_memory
from repro.roofline.model_flops import cell_model_flops


def _compile_once(cell_builder, mesh, unroll_map):
    # Rebuild the cell each time: jax caches traces on function identity, so
    # reusing one step_fn closure would ignore the unroll-flag change.
    flags.LAYER_UNROLL = dict(unroll_map)
    flags.UNROLL_SMALL = True
    try:
        cell = cell_builder()
        in_sh = cell.in_shardings(mesh)
        # jax >= 0.6 wants the mesh context for Auto-axis jit; older jax has
        # no set_mesh and takes the mesh purely from in_shardings
        ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") \
            else contextlib.nullcontext()
        with ctx:
            lowered = jax.jit(cell.step_fn, in_shardings=in_sh,
                              donate_argnums=cell.donate
                              ).lower(*cell.abstract_args)
            compiled = lowered.compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):           # jax <= 0.4.x: list of dicts
            ca = ca[0] if ca else {}
        coll = collective_bytes(compiled.as_text())
        return {
            "flops": float(ca.get("flops", 0.0)),
            "xla_bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll,
            "compiled": compiled,
        }
    finally:
        flags.LAYER_UNROLL = {}
        flags.UNROLL_SMALL = False


def _solve_totals(base, tag_runs, chains):
    """Linear trip-count solve; returns corrected totals for every metric."""
    metrics = ["flops", "xla_bytes"]
    coll_keys = set(base["coll"]) | {k for r in tag_runs.values()
                                     for k in r["run"]["coll"]}

    def get(run, m):
        if m in metrics:
            return run[m]
        return run["coll"].get(m, 0.0)

    out = {}
    for m in metrics + sorted(coll_keys):
        total = get(base, m)
        for chain in chains:
            # deltas outer->inner
            Ds = []
            for tag, trip in chain:
                u2 = flags.smallest_unroll(trip)
                d = (get(tag_runs[tag]["run"], m) - get(base, m)) / (u2 - 1)
                Ds.append(max(d, 0.0))
            Ds.append(0.0)
            mult = 1.0
            for i, (tag, trip) in enumerate(chain):
                body = max(Ds[i] - Ds[i + 1], 0.0)
                mult *= trip
                total += (mult - 1.0) * body
        out[m] = total
    return out


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, kv_dtype: str | None = None) -> dict:
    arch = C.get(arch_id)
    if kv_dtype:
        import dataclasses
        arch = dataclasses.replace(
            arch, config=dataclasses.replace(arch.config,
                                             kv_cache_dtype=kv_dtype))
    shape = arch.shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rec = {"arch": arch_id, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips}
    t0 = time.time()
    try:
        builder = lambda: S.build_cell(arch, shape, mesh)  # noqa: E731
        cell = builder()
        base = _compile_once(builder, mesh, {})
        tag_runs = {}
        for chain in cell.loops:
            for tag, trip in chain:
                if tag in tag_runs:
                    continue
                u2 = flags.smallest_unroll(trip)
                tag_runs[tag] = {"u2": u2,
                                 "run": _compile_once(builder, mesh,
                                                      {tag: u2})}
        solved = _solve_totals(base, tag_runs,
                               cell.loops) if cell.loops else {
            "flops": base["flops"], "xla_bytes": base["xla_bytes"],
            **base["coll"]}

        # --- roofline terms -------------------------------------------
        coll_total = solved.get("total", 0.0)
        mem = cell_memory(cell.config, shape, arch.train, chips,
                          param_shards=_param_shards(cell, mesh))
        rl = Roofline(solved["flops"], mem["traffic"]["total"] / chips,
                      coll_total, chips)
        rec.update(rl.as_dict())
        rec["collectives"] = {k: v for k, v in solved.items()
                              if k not in ("flops", "xla_bytes")}
        rec["xla_bytes_per_device_unfused"] = solved["xla_bytes"]
        rec["mem_traffic"] = mem["traffic"]
        rec["mem_capacity"] = mem["capacity"]
        rec["fits_hbm_analytic"] = bool(
            mem["capacity"]["total"] <= V5E.hbm_bytes)
        rec["hbm_frac_analytic"] = mem["capacity"]["total"] / V5E.hbm_bytes

        ma = base["compiled"].memory_analysis()
        rec["xla_memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        }

        mf = cell_model_flops(cell.config, shape)
        rec["model_flops"] = mf["model_flops"]
        hlo_total = solved["flops"] * chips
        rec["useful_flops_frac"] = mf["model_flops"] / hlo_total \
            if hlo_total else 0.0
        if shape.steps:
            rec["sampler_steps"] = shape.steps
        rec["n_compiles"] = 1 + len(tag_runs)
        rec["t_total_s"] = round(time.time() - t0, 1)
        rec["ok"] = True
        if verbose:
            print(f"[ok] {arch_id:17s} {shape_name:11s} {rec['mesh']:7s} "
                  f"comp={rec['t_compute_s']:.2e} mem={rec['t_memory_s']:.2e} "
                  f"coll={rec['t_collective_s']:.2e} dom={rec['dominant']:10s} "
                  f"hbm={rec['hbm_frac_analytic']*100:5.1f}% "
                  f"useful={rec['useful_flops_frac']*100:5.1f}% "
                  f"({rec['n_compiles']} compiles, {rec['t_total_s']}s)",
                  flush=True)
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        rec["t_total_s"] = round(time.time() - t0, 1)
        if verbose:
            print(f"[FAIL] {arch_id} {shape_name} {rec['mesh']}: "
                  f"{rec['error']}", flush=True)
    return rec


def _param_shards(cell, mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = sizes.get("model", 1)
    emb = cell.rules.as_dict().get("embed")
    if emb:  # FSDP over data(+pod) in addition to model TP
        n = model
        for ax in emb:
            n *= sizes.get(ax, 1)
        return n
    return model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--kv-dtype", default=None, choices=[None, "int8",
                                                         "bfloat16"])
    args = ap.parse_args()

    archs = C.ARCH_IDS if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_fail = 0
    for aid in archs:
        arch = C.get(aid)
        shapes = [s.name for s in arch.shapes] if args.shape == "all" \
            else [args.shape]
        for sname in shapes:
            for mp in meshes:
                rec = run_cell(aid, sname, mp, kv_dtype=args.kv_dtype)
                tag = f"{aid}__{sname}__{'multi' if mp else 'single'}"
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1, default=float)
                n_fail += 0 if rec["ok"] else 1
    print(f"dry-run complete; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
