"""Serving driver: the paper's fleet under a chosen policy.

  PYTHONPATH=src python -m repro.launch.serve --policy MO --users 15 \
      --requests 500 --mode real
"""

from __future__ import annotations

import argparse
import json

from repro.core.profiles import paper_fleet, synthetic_fleet
from repro.serving.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="MO")
    ap.add_argument("--gamma", type=float, default=0.5)
    ap.add_argument("--delta", type=float, default=20.0)
    ap.add_argument("--users", type=int, default=8)
    ap.add_argument("--requests", type=int, default=300)
    ap.add_argument("--mode", default="modelled", choices=["modelled", "real"])
    ap.add_argument("--online", action="store_true")
    ap.add_argument("--fleet", default="paper", choices=["paper", "synthetic"])
    ap.add_argument("--n-pairs", type=int, default=32)
    a = ap.parse_args()

    if a.fleet == "paper":
        prof = paper_fleet()
        tiers = ["ssd_v1", "ssd_lite", "yolo_s", "yolo_s", "ssd_v1"]
    else:
        import jax
        prof = synthetic_fleet(jax.random.PRNGKey(0), a.n_pairs)
        tiers = ["ssd_v1"] * prof.n_pairs

    eng = ServingEngine.build(prof, policy=a.policy, gamma=a.gamma,
                              delta=a.delta, n_streams=a.users, mode=a.mode,
                              tiers=tiers, online=a.online)
    recs = eng.run(n_requests=a.requests, concurrency=a.users)
    out = eng.summarize(recs)
    out.update(policy=a.policy, users=a.users, mode=a.mode)
    print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
