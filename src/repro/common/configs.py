"""Dataclass configuration system.

Every assigned architecture is described by one of the model-config dataclasses
below plus a set of :class:`ShapeSpec` cells. Configs are frozen (hashable) so
they can be closed over by jitted step functions without retracing hazards.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Mapping


@dataclass(frozen=True)
class ShapeSpec:
    """One (input-shape) cell of the assignment matrix.

    ``kind`` selects which step function is lowered:
      * ``train``    -> train_step (fwd + bwd + optimizer)
      * ``prefill``  -> serve_step over the full prompt, materialising KV
      * ``decode``   -> serve_step producing one token against a KV cache
      * ``serve``    -> plain batched forward (vision / diffusion sampling)
    """

    name: str
    kind: str
    global_batch: int
    seq_len: int = 0          # LM cells
    img_res: int = 0          # vision / diffusion cells
    steps: int = 0            # diffusion sampler steps (1 step lowered; total
                              # reported as steps x per-step in the roofline)

    def __post_init__(self) -> None:
        if self.kind not in ("train", "prefill", "decode", "serve"):
            raise ValueError(f"unknown shape kind {self.kind!r}")


@dataclass(frozen=True)
class LMConfig:
    """Decoder-only transformer LM (optionally MoE)."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0         # DeepSeek: always-on shared experts
    d_expert: int = 0                 # per-expert FFN width (0 -> d_ff)
    moe_dense_residual: bool = False  # Arctic: dense FFN residual in parallel
    capacity_factor: float = 1.25
    router_impl: str = "topk"         # topk | balanced (load-penalised; paper
                                      # -style multi-objective expert routing)
    dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"  # bfloat16 | int8 (per-(pos,head)-scaled
                                      # quantised cache; halves decode HBM
                                      # traffic — EXPERIMENTS.md §Perf it.3)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_exp(self) -> int:
        return self.d_expert or self.d_ff

    def n_params(self) -> int:
        """Total parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.hd
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        dense_ff = 0
        moe_ff = 0
        router = 0
        if self.moe:
            if self.n_shared_experts:
                dense_ff += 3 * d * (self.n_shared_experts * self.d_exp)
            if self.moe_dense_residual:
                dense_ff += 3 * d * self.d_ff
            moe_ff = self.n_experts * 3 * d * self.d_exp
            router = d * self.n_experts
        else:
            dense_ff = 3 * d * self.d_ff
        per_layer = attn + dense_ff + moe_ff + router + 2 * d
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + embed + d

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed top-k count)."""
        if not self.moe:
            return self.n_params()
        full = self.n_params()
        inactive = self.n_layers * (self.n_experts - self.top_k) \
            * 3 * self.d_model * self.d_exp
        return full - inactive


@dataclass(frozen=True)
class DiTConfig:
    """Diffusion transformer (DiT, adaLN-zero), class-conditional."""

    name: str
    img_res: int
    patch: int
    n_layers: int
    d_model: int
    n_heads: int
    in_channels: int = 4       # VAE latent channels
    vae_factor: int = 8        # image res -> latent res
    n_classes: int = 1000
    dtype: str = "bfloat16"

    def latent_res(self, img_res: int = 0) -> int:
        return (img_res or self.img_res) // self.vae_factor

    def n_tokens(self, img_res: int = 0) -> int:
        return (self.latent_res(img_res) // self.patch) ** 2

    def n_params(self) -> int:
        d = self.d_model
        per_layer = 4 * d * d + 8 * d * d + 6 * d * d  # attn + mlp(4x) + adaLN
        patch_dim = self.in_channels * self.patch ** 2
        io = patch_dim * d + d * patch_dim * 2  # patchify + final linear
        cond = 256 * d + d * d + self.n_classes * d
        return self.n_layers * per_layer + io + cond


@dataclass(frozen=True)
class MMDiTConfig:
    """Flux-style MMDiT: double-stream (img/txt) blocks + single-stream blocks,
    rectified-flow objective. The text encoder is a stub: ``input_specs``
    provides precomputed text-token embeddings (d_txt) and a pooled vector."""

    name: str
    img_res: int
    n_double_blocks: int
    n_single_blocks: int
    d_model: int
    n_heads: int
    patch: int = 2
    in_channels: int = 16
    vae_factor: int = 8
    d_txt: int = 4096          # T5 feature width (stubbed frontend)
    d_pooled: int = 768        # CLIP pooled vector width (stubbed frontend)
    txt_len: int = 512
    guidance_embed: bool = True
    dtype: str = "bfloat16"

    def latent_res(self, img_res: int = 0) -> int:
        return (img_res or self.img_res) // self.vae_factor

    def n_img_tokens(self, img_res: int = 0) -> int:
        return (self.latent_res(img_res) // self.patch) ** 2

    def n_params(self) -> int:
        d = self.d_model
        double = self.n_double_blocks * 2 * (4 * d * d + 8 * d * d + 6 * d * d)
        single = self.n_single_blocks * (4 * d * d + 8 * d * d + 3 * d * d)
        io = (self.in_channels * self.patch ** 2) * d * 2 \
            + self.d_txt * d + self.d_pooled * d + 256 * d + d * d
        return double + single + io


@dataclass(frozen=True)
class VisionConfig:
    """Convolutional vision backbone (ResNet / ConvNeXt / EfficientNet)."""

    name: str
    family: str                        # resnet | convnext | efficientnet
    img_res: int
    depths: tuple[int, ...] = ()
    dims: tuple[int, ...] = ()
    width: int = 64                    # resnet stem width
    bottleneck: int = 4                # resnet bottleneck expansion
    width_mult: float = 1.0            # efficientnet compound scaling
    depth_mult: float = 1.0
    n_classes: int = 1000
    norm: str = "batchnorm"            # batchnorm | layernorm
    dtype: str = "bfloat16"

    def n_params(self) -> int:
        # filled by the model builders (architecture-dependent); use the
        # analytic counter in models.convnets.count_params instead.
        from repro.models import convnets

        return convnets.count_params(self)


@dataclass(frozen=True)
class TrainingConfig:
    """Optimizer / schedule / parallelism knobs for train cells."""

    optimizer: str = "adamw"           # adamw | adafactor | sgdm
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    microbatch: int = 0                # 0 -> no gradient accumulation
    remat: str = "full"                # none | dots | full
    grad_compression: str = "none"     # none | int8 (cross-pod all-reduce)
    label_smoothing: float = 0.0


def asdict(cfg: Any) -> dict:
    return dataclasses.asdict(cfg)


def replace(cfg: Any, **kw: Any) -> Any:
    return dataclasses.replace(cfg, **kw)


def from_dict(cls: type, d: Mapping[str, Any]) -> Any:
    names = {f.name for f in dataclasses.fields(cls)}
    return cls(**{k: v for k, v in d.items() if k in names})
