"""Trace-time loop-unroll controls for the dry-run cost solve.

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
count, so scanned models under-report FLOPs and collective bytes by ~L x.
Full unrolling is exact but blows up compile time (10+ min for the 35-layer
MoE configs on this 1-core box). Instead the dry-run compiles each cell
several times, bumping ONE tagged loop's unroll factor per compile, and
solves linearly for each body's cost:

    D_tag = (F[u_tag=k] - F[u=1]) / (k - 1)      (= body + its inner loops)
    body_tag = D_tag - D_inner_tag
    total = F_base - sum(bodies) + sum(prod(trips up to tag) * body_tag)

Tags: "layers" (transformer/DiT stacks), "double"/"single" (MMDiT),
"micro" (gradient-accumulation), "attn" (chunked-attention streaming loop).
Small fixed-trip loops (vocab-chunked xent) unroll fully when UNROLL_SMALL
is set — they're cheap and then counted exactly.
"""

LAYER_UNROLL: dict[str, int] = {}
UNROLL_SMALL = False


def layer_unroll(tag: str) -> int:
    return LAYER_UNROLL.get(tag, 1)


def scan_unroll(length: int) -> int:
    """Unroll amount for small (cheap-body) scans."""
    return length if UNROLL_SMALL else 1


def smallest_unroll(n: int) -> int:
    """Smallest divisor >= 2 of n (n itself if prime)."""
    for d in range(2, int(n ** 0.5) + 1):
        if n % d == 0:
            return d
    return n
