"""Pytree helpers shared across training/checkpointing/distribution."""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def tree_bytes(tree: Any) -> int:
    """Total bytes of all leaves (works on ShapeDtypeStruct and arrays)."""
    leaves = jax.tree.leaves(tree)
    return sum(int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize for l in leaves)


def tree_params(tree: Any) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


def tree_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def tree_any_nan(tree: Any) -> jax.Array:
    leaves = [jnp.any(~jnp.isfinite(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree) if jnp.issubdtype(l.dtype, jnp.floating)]
    if not leaves:
        return jnp.asarray(False)
    return jnp.any(jnp.stack(leaves))


def tree_cast(tree: Any, dtype) -> Any:
    def cast(l):
        if jnp.issubdtype(l.dtype, jnp.floating):
            return l.astype(dtype)
        return l
    return jax.tree.map(cast, tree)


def tree_zeros_like(tree: Any) -> Any:
    return jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype), tree)


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """Map with '/'-joined string key paths (stable across dict/dataclass)."""

    def to_name(p) -> str:
        out = []
        for e in p:
            if hasattr(e, "key"):
                out.append(str(e.key))
            elif hasattr(e, "idx"):
                out.append(str(e.idx))
            elif hasattr(e, "name"):
                out.append(str(e.name))
            else:
                out.append(str(e))
        return "/".join(out)

    return jax.tree_util.tree_map_with_path(lambda p, l: fn(to_name(p), l), tree)


def flatten_with_names(tree: Any) -> list[tuple[str, Any]]:
    out: list[tuple[str, Any]] = []
    tree_map_with_path(lambda n, l: out.append((n, l)) or l, tree)
    return out
