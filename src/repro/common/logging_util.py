"""Tiny structured logger (stdout, rank-0 aware)."""

from __future__ import annotations

import json
import sys
import time
from typing import Any

_T0 = time.time()


def log(event: str, **fields: Any) -> None:
    rec = {"t": round(time.time() - _T0, 3), "event": event}
    rec.update(fields)
    try:
        sys.stdout.write(json.dumps(rec, default=str) + "\n")
    except TypeError:
        sys.stdout.write(str(rec) + "\n")
    sys.stdout.flush()


class Timer:
    def __init__(self, name: str):
        self.name = name

    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *exc):
        self.dt = time.time() - self.t0
        log("timer", name=self.name, seconds=round(self.dt, 3))
        return False
