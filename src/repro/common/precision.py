"""Mixed-precision policy.

Production posture: params stored bf16 (with fp32 master copies owned by the
optimizer where applicable), compute in bf16 with fp32 softmax/normalisation
accumulation, losses/metrics in fp32.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp


_DTYPES = {
    "bfloat16": jnp.bfloat16,
    "float32": jnp.float32,
    "float16": jnp.float16,
}


def parse_dtype(name: str):
    return _DTYPES[name]


@dataclass(frozen=True)
class Policy:
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    accum_dtype: str = "float32"

    @property
    def pdt(self):
        return parse_dtype(self.param_dtype)

    @property
    def cdt(self):
        return parse_dtype(self.compute_dtype)

    @property
    def adt(self):
        return parse_dtype(self.accum_dtype)

    def cast_compute(self, x):
        return x.astype(self.cdt)

    def cast_accum(self, x):
        return x.astype(self.adt)


DEFAULT_POLICY = Policy()
FP32_POLICY = Policy("float32", "float32", "float32")
