"""Common substrate: configs, precision policy, tree and logging utilities."""

from repro.common.configs import (
    LMConfig,
    DiTConfig,
    MMDiTConfig,
    VisionConfig,
    ShapeSpec,
    TrainingConfig,
)
from repro.common.precision import Policy, DEFAULT_POLICY
from repro.common import treeutil

__all__ = [
    "LMConfig",
    "DiTConfig",
    "MMDiTConfig",
    "VisionConfig",
    "ShapeSpec",
    "TrainingConfig",
    "Policy",
    "DEFAULT_POLICY",
    "treeutil",
]
