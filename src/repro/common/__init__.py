"""Common substrate: configs, precision policy, tree and logging utilities."""

from repro.common import treeutil
from repro.common.configs import (
    DiTConfig,
    LMConfig,
    MMDiTConfig,
    ShapeSpec,
    TrainingConfig,
    VisionConfig,
)
from repro.common.precision import DEFAULT_POLICY, Policy

__all__ = [
    "LMConfig",
    "DiTConfig",
    "MMDiTConfig",
    "VisionConfig",
    "ShapeSpec",
    "TrainingConfig",
    "Policy",
    "DEFAULT_POLICY",
    "treeutil",
]
