"""Collective helpers used by distributed serving / training paths.

The headline piece is :func:`seq_sharded_decode` -- flash-decoding adapted to
the ICI domain: the KV cache is sequence-sharded across the mesh, every device
computes a *partial* attention (numerator, logsumexp) over its shard, and the
partials are combined with a single small ``psum`` (two scalars + one vector
per head), instead of all-gathering the 100+ GB cache.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _partial_attention(q, k, v, scale):
    """q: (B,H,hd); k/v: (B,S_loc,KV,hd). Returns partial (o, lse) in fp32."""
    b, h, hd = q.shape
    kv = k.shape[2]
    groups = h // kv
    qg = q.reshape(b, kv, groups, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # scores: (B, KV, G, S_loc)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, kf) * scale
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    l = jnp.sum(e, axis=-1, keepdims=True)
    # normalised partial: the LSE-combine weights exp(lse_i - LSE) then sum
    # to exactly 1 across shards
    o = jnp.einsum("bkgs,bskd->bkgd", e, vf) / jnp.maximum(l, 1e-30)
    lse = (jnp.log(l) + m)[..., 0]           # (B,KV,G)
    return o, lse


def seq_sharded_decode(mesh: Mesh, kv_axes: Sequence[str]):
    """Build a shard_map'ed decode-attention over a KV cache whose sequence
    dim is sharded across ``kv_axes``.

    Returns fn(q (B,H,hd), k (B,S,KV,hd), v (B,S,KV,hd)) -> (B,H,hd).
    """
    axes = tuple(kv_axes)

    def local(q, k, v):
        scale = 1.0 / (q.shape[-1] ** 0.5)
        o, lse = _partial_attention(q, k, v, scale)
        # Combine partials across the sequence shards: softmax re-weighting.
        g_max = jax.lax.pmax(lse, axes)
        w = jnp.exp(lse - g_max)                      # (B,KV,G)
        num = jax.lax.psum(o * w[..., None], axes)
        den = jax.lax.psum(w, axes)
        out = num / den[..., None]
        b, kv, g, hd = out.shape
        return out.reshape(b, kv * g, hd)

    def fn(q, k, v):
        qspec = P(None, None, None)
        kvspec = P(None, axes if len(axes) > 1 else axes[0], None, None)
        return shard_map(
            local, mesh=mesh,
            in_specs=(qspec, kvspec, kvspec),
            out_specs=qspec,
            check_rep=False,
        )(q, k, v)

    return fn


def psum_scatter_mean(x, axis_name: str):
    """reduce-scatter based mean (collective-friendly gradient averaging)."""
    n = jax.lax.psum(1, axis_name)
    return jax.lax.psum_scatter(x, axis_name, tiled=True) / n


@functools.partial(jax.jit, static_argnames=("axis",))
def interleave_halo(x, axis: int = 1):
    """Halo-exchange helper for spatially-partitioned convs (used in tests to
    validate XLA's own halo logic against a manual ring exchange)."""
    left = jnp.roll(x, 1, axis)
    right = jnp.roll(x, -1, axis)
    return jnp.concatenate([left, x, right], axis)
