"""Logical-axis sharding rules (MaxText-style).

Model code annotates every parameter / activation with *logical* axis names;
this module maps them onto the physical mesh axes.  One set of rules serves
all 10 assigned architectures; per-arch or per-shape overrides are plain
``dict`` updates.

Physical mesh axes:
  * ``pod``   (multi-pod only) -- outermost data-parallel axis across pods
  * ``data``  -- data parallel + FSDP (ZeRO-3 parameter/optimizer sharding)
  * ``model`` -- tensor parallel (heads / mlp / vocab) and expert parallel
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Logical axis vocabulary -------------------------------------------------
# batch      activation batch dim
# seq        activation sequence dim (sharded only in sequence-parallel paths)
# seq_kv     KV-cache sequence dim (sharded for long-context decode)
# embed      d_model dims of weights (FSDP target)
# heads      attention head (q) projection dim
# kv_heads   attention kv projection dim (often too small to TP-shard)
# mlp        FFN hidden dim
# expert     MoE expert dim (expert parallelism)
# vocab      embedding/logits vocabulary dim
# layer      stacked-layer leading dim of scanned weights (never sharded)
# spatial_h / spatial_w   conv feature maps (spatial partitioning)
# channels   conv channel dim (TP for convnets)
# user       per-user stream state (queue counts, workload draws) — data
#            parallel like batch: users are independent streams
# none       explicitly replicated


@dataclass(frozen=True)
class AxisRules:
    rules: tuple[tuple[str, tuple[str, ...] | None], ...]

    def as_dict(self) -> dict[str, tuple[str, ...] | None]:
        return {k: v for k, v in self.rules}

    def override(self, **kw: Any) -> "AxisRules":
        d = self.as_dict()
        for k, v in kw.items():
            if v is None or v == ():
                d[k] = None
            elif isinstance(v, str):
                d[k] = (v,)
            else:
                d[k] = tuple(v)
        return AxisRules(tuple(d.items()))


def _mk(d: Mapping[str, Any]) -> AxisRules:
    out = []
    for k, v in d.items():
        if v is None:
            out.append((k, None))
        elif isinstance(v, str):
            out.append((k, (v,)))
        else:
            out.append((k, tuple(v)))
    return AxisRules(tuple(out))


# Default rules: FSDP over data(+pod), TP/EP over model.
DEFAULT_RULES = _mk({
    "batch": ("pod", "data"),
    "seq": None,
    "seq_kv": None,
    "embed": ("data",),
    "embed_nofsdp": None,
    "heads": ("model",),
    "kv_heads": None,
    "mlp": ("model",),
    "expert": ("model",),
    "expert_mlp": None,
    "vocab": ("model",),
    "layer": None,
    "norm": None,
    "rep": None,      # force-replicated even in constraint() (vs None ->
                      # UNCONSTRAINED); pins remat-saved activations
    "user": ("pod", "data"),
    "spatial_h": None,
    "spatial_w": None,
    "channels": ("model",),
    "channels_in": None,
    "classes": None,
    "cond": None,
})

# Long-context decode: KV sequence sharded across the *whole* mesh (split-K
# decode with cross-device LSE combine); params FSDP as usual.
LONG_DECODE_RULES = DEFAULT_RULES.override(
    seq_kv=("data", "model"),
    batch=None,           # batch=1: cannot shard
)

# Inference (no FSDP gather per layer wanted at small batch): keep params
# sharded over model only, replicate over data.
SERVE_RULES = DEFAULT_RULES.override(embed=None)


def mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def config_axis_spec(mesh: Mesh) -> P:
    """PartitionSpec sharding an array's leading dim over EVERY mesh axis.

    Used by the sharded sweep engine: a flat batch axis (the config axis of
    a ``ConfigGrid``) has no preferred mesh factorisation, so it is split
    across the product of all axes — a 1-D ``('config',)`` sweep mesh and a
    2-D ``('data', 'model')`` serving mesh shard it equally well. Trailing
    dims are replicated. User-blocked grids
    (``repro.core.simulator._make_user_grid``) put each config's
    balancer-replica block rows on this same axis, so sharding the config
    axis IS sharding per-user queue/workload state across devices — no
    separate user spec needed.
    """
    return P(mesh.axis_names)


def pad_leading(tree: Any, multiple: int) -> tuple[Any, int]:
    """Pad every leaf's leading dim up to a multiple of ``multiple`` by
    repeating the first row; returns ``(padded_tree, original_length)``.

    All leaves must agree on the leading dim. Repeating a *valid* row (not
    zeros) keeps the padded rows on the exact code path of real ones, so
    padding can never introduce NaNs/infs that would trip XLA debug checks;
    callers slice the result back to ``original_length``.
    """
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return tree, 0
    n = {int(leaf.shape[0]) for leaf in leaves}
    if len(n) != 1:
        raise ValueError(f"pad_leading: leaves disagree on leading dim: "
                         f"{sorted(n)}")
    (n,) = n
    pad = (-n) % multiple
    if pad == 0:
        return tree, n
    padded = jax.tree.map(
        lambda x: np.concatenate(
            [np.asarray(x), np.repeat(np.asarray(x[:1]), pad, axis=0)]),
        tree)
    return padded, n


def _present(mesh: Mesh, axes: Sequence[str] | None) -> tuple[str, ...] | None:
    """Drop mesh axes that don't exist (e.g. 'pod' on the single-pod mesh)."""
    if axes is None:
        return None
    kept = tuple(a for a in axes if a in mesh.axis_names)
    return kept or None


def logical_to_mesh(mesh: Mesh, rules: AxisRules,
                    logical: Sequence[str | None],
                    shape: Sequence[int] | None = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec.

    If ``shape`` is given, any mapping whose axis-size product does not divide
    the dimension is dropped (e.g. kv_heads=8 on a 16-way model axis).
    A mesh axis may appear at most once in the spec; first logical dim wins.
    """
    d = rules.as_dict()
    sizes = mesh_axis_sizes(mesh)
    used: set[str] = set()
    spec: list[Any] = []
    for i, name in enumerate(logical):
        if name is None or name == "none":
            spec.append(None)
            continue
        if name not in d:
            raise KeyError(f"unknown logical axis {name!r}")
        axes = _present(mesh, d[name])
        if axes is None:
            spec.append(None)
            continue
        axes = tuple(a for a in axes if a not in used)
        if not axes:
            spec.append(None)
            continue
        if shape is not None:
            prod = int(np.prod([sizes[a] for a in axes]))
            while axes and shape[i] % prod != 0:
                axes = axes[:-1]
                prod = int(np.prod([sizes[a] for a in axes])) if axes else 1
            if not axes:
                spec.append(None)
                continue
        used.update(axes)
        spec.append(axes[0] if len(axes) == 1 else tuple(axes))
    return P(*spec)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def shard_tree(mesh: Mesh, rules: AxisRules, logical_tree: Any,
               shape_tree: Any = None) -> Any:
    """Map a pytree of logical-axis tuples to NamedShardings.

    ``shape_tree`` (same structure, of ShapeDtypeStruct) enables the
    divisibility fallback.
    """
    if shape_tree is None:
        return jax.tree.map(
            lambda lg: named(mesh, logical_to_mesh(mesh, rules, lg)),
            logical_tree, is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree.map(
        lambda lg, sd: named(mesh, logical_to_mesh(mesh, rules, lg, sd.shape)),
        logical_tree, shape_tree, is_leaf=lambda x: isinstance(x, tuple))


def batch_spec(mesh: Mesh, rules: AxisRules, ndim: int,
               batch_dim: int = 0) -> P:
    logical = [None] * ndim
    logical[batch_dim] = "batch"
    return logical_to_mesh(mesh, rules, logical)


_RULES_STACK: list[AxisRules] = []


class use_rules:
    """Context manager installing the active rules for ``constraint`` calls
    made inside jitted model code (read at trace time)."""

    def __init__(self, rules: AxisRules):
        self.rules = rules

    def __enter__(self):
        _RULES_STACK.append(self.rules)
        return self.rules

    def __exit__(self, *exc):
        _RULES_STACK.pop()
        return False


def active_rules() -> AxisRules:
    return _RULES_STACK[-1] if _RULES_STACK else DEFAULT_RULES


def constraint(x, logical: Sequence[str | None], rules: AxisRules | None = None):
    """with_sharding_constraint using logical names; unspecified (None) dims
    are left UNCONSTRAINED so XLA propagation can still shard them; a no-op
    outside a mesh context or when the mesh is trivial."""
    rules = rules or active_rules()
    try:
        mesh = jax.sharding.get_abstract_mesh()  # type: ignore[attr-defined]
        if mesh is None or mesh.empty or np.prod(mesh.axis_sizes) == 1:
            return x
        spec = logical_to_mesh_abstract(mesh, rules, logical, x.shape)
        uspec = P(*(
            (None if name == "rep" else P.UNCONSTRAINED) if s is None else s
            for s, name in zip(spec, logical)))
        return jax.lax.with_sharding_constraint(x, uspec)
    except Exception:
        return x


def logical_to_mesh_abstract(mesh, rules: AxisRules,
                             logical: Sequence[str | None],
                             shape: Sequence[int]) -> P:
    """Same as logical_to_mesh but for AbstractMesh (inside jit)."""
    d = rules.as_dict()
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    used: set[str] = set()
    spec: list[Any] = []
    for i, name in enumerate(logical):
        if name is None or name == "none":
            spec.append(None)
            continue
        axes = d.get(name)
        if axes is None:
            spec.append(None)
            continue
        axes = tuple(a for a in axes if a in sizes and a not in used)
        if shape is not None:
            prod = int(np.prod([sizes[a] for a in axes])) if axes else 1
            while axes and shape[i] % prod != 0:
                axes = axes[:-1]
                prod = int(np.prod([sizes[a] for a in axes])) if axes else 1
        if not axes:
            spec.append(None)
            continue
        used.update(axes)
        spec.append(axes[0] if len(axes) == 1 else tuple(axes))
    return P(*spec)
