from repro.distributed.sharding import (
    DEFAULT_RULES,
    AxisRules,
    batch_spec,
    logical_to_mesh,
    shard_tree,
)

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "logical_to_mesh",
    "shard_tree",
    "batch_spec",
]
