from repro.distributed.sharding import (
    AxisRules,
    DEFAULT_RULES,
    logical_to_mesh,
    shard_tree,
    batch_spec,
)

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "logical_to_mesh",
    "shard_tree",
    "batch_spec",
]
